"""Data pipeline: deterministic synthetic corpora + dry-run input specs.

``make_batch`` produces real arrays for CPU smoke/examples;
``input_specs`` produces ShapeDtypeStructs for the dry-run (weak-type
correct, no allocation) for every (arch x input shape) combination —
training batches, prefill request batches, or decode (token + ServeState)
per the shape's kind.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.serve import engine as serve_engine


def _split_train_seq(cfg: ModelConfig, seq_len: int):
    """audio: seq budget split between encoder frames and decoder tokens;
    vlm: patch tokens carved out of the sequence."""
    if cfg.arch_type == "audio":
        return seq_len // 2, seq_len // 2
    if cfg.arch_type == "vlm":
        return cfg.n_frontend_tokens, seq_len - cfg.n_frontend_tokens
    return 0, seq_len


def make_batch(cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0):
    """Synthetic batch (markov-ish token stream so loss can decrease)."""
    rng = np.random.default_rng(seed)
    front, txt = _split_train_seq(cfg, seq_len)
    # order-0 markov stream with skewed unigram distribution
    probs = rng.dirichlet(np.full(min(cfg.vocab, 4096), 0.5))
    ids = rng.choice(len(probs), size=(batch, txt + 1), p=probs)
    tokens = jnp.asarray(ids[:, :-1], jnp.int32)
    labels = jnp.asarray(ids[:, 1:], jnp.int32)
    out = {"tokens": tokens, "labels": labels}
    if cfg.arch_type == "audio":
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, front, cfg.d_model)), jnp.float32)
    elif cfg.arch_type == "vlm":
        out["patches"] = jnp.asarray(
            rng.normal(size=(batch, front, cfg.d_model)), jnp.float32)
    return out


# ----------------------------------------------------------------------
# Dry-run specs (ShapeDtypeStruct only)
# ----------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    front, txt = _split_train_seq(cfg, s)
    out = {
        "tokens": _sds((b, txt), jnp.int32),
        "labels": _sds((b, txt), jnp.int32),
    }
    if cfg.arch_type == "audio":
        out["frames"] = _sds((b, front, cfg.d_model), jnp.float32)
    elif cfg.arch_type == "vlm":
        out["patches"] = _sds((b, front, cfg.d_model), jnp.float32)
    return out


def decode_input_specs(cfg: ModelConfig, shape: InputShape):
    """(token, ServeState) ShapeDtypeStructs for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    cache = serve_engine.init_cache  # reuse the real structure via eval_shape
    state = jax.eval_shape(lambda: cache(cfg, b, s))
    token = _sds((b, 1), jnp.int32)
    return token, state


def param_specs_struct(cfg: ModelConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the full parameter pytree (no allocation)."""
    from repro.models import model as M
    return jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg, dtype))


class MutationBatch(NamedTuple):
    """One timestamped batch of live-graph mutation traffic (DESIGN.md
    §13): ``edges`` to insert plus ``touch`` — vertex ids whose data the
    driver should rewrite (the app decides the payload).  ``queries`` are
    vertex ids to read back between recompute rounds."""
    t: int
    edges: np.ndarray            # [k, 2] int64, deduped, no self-loops
    touch: np.ndarray            # [m] int64 vertex ids for data updates
    queries: np.ndarray          # [q] int64 vertex ids to read


def edge_stream(n_vertices: int, rate: float = 8.0, seed: int = 0,
                n_batches: int = 16, alpha: float = 2.0,
                update_frac: float = 0.5, query_rate: float = 4.0):
    """Deterministic stream of ``MutationBatch``es for online serving.

    Per batch ``t``: ``k ~ Poisson(rate)`` candidate edge inserts with
    Zipf(``alpha``)-skewed endpoints (hot vertices keep getting hotter,
    matching the power-law graphs the paper's workloads use), deduped and
    self-loop-free; ``~update_frac * k`` vertex-data touches drawn from
    the same skew; ``~Poisson(query_rate)`` uniform read queries.  Same
    ``(n_vertices, rate, seed, ...)`` -> bitwise-identical stream, so
    traces are replayable across the incremental and rebuild paths.
    """
    rng = np.random.default_rng(seed)
    weights = 1.0 / np.arange(1, n_vertices + 1, dtype=np.float64) ** alpha
    weights /= weights.sum()
    for t in range(n_batches):
        k = int(rng.poisson(rate))
        pairs: list[tuple[int, int]] = []
        seen: set[tuple[int, int]] = set()
        for _ in range(k):
            u = int(rng.choice(n_vertices, p=weights))
            v = int(rng.choice(n_vertices, p=weights))
            if u == v:
                continue
            key = (min(u, v), max(u, v))
            if key in seen:
                continue
            seen.add(key)
            pairs.append(key)
        edges = (np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
                 if pairs else np.zeros((0, 2), np.int64))
        m = int(round(update_frac * len(pairs)))
        touch = (rng.choice(n_vertices, size=m, p=weights)
                 .astype(np.int64) if m else np.zeros(0, np.int64))
        q = int(rng.poisson(query_rate))
        queries = (rng.integers(0, n_vertices, size=q).astype(np.int64)
                   if q else np.zeros(0, np.int64))
        yield MutationBatch(t=t, edges=edges, touch=np.unique(touch),
                            queries=queries)
