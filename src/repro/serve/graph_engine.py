"""Online graph serving: live mutations + dirty-scope incremental
recompute + snapshot-isolated query traffic (DESIGN.md §13).

The batch half of the repo runs ``api.run`` over a frozen
``from_edges`` graph; this module is the inference half the paper's
abstraction was built to serve — a long-lived :class:`ServingEngine`
wrapping any registered scheduler, driven as::

    serving = api.serve(graph, update, syncs=syncs, scheduler="locking")
    serving.recompute()                      # initial convergence
    eid = serving.add_edge(u, v, w=0.3)      # mutations ...
    serving.update_vertex_data([v], {"rank": [1.0]})
    serving.recompute()                      # ... dirty scopes only
    serving.top_k("rank", 10)                # queries (snapshot reads)

Three moving parts:

* **Mutation log onto slack storage.**  Mutations apply to a private
  working graph immediately — ``add_edges`` lands in the reserved
  slack slots of ``from_edges(slack=...)`` storage via
  ``core.graph.insert_edges`` (no rebuild, no shape change, no
  recompile); data writes are ``.at[].set`` row updates.  Every stored
  array is replaced, never mutated, which is what makes published
  snapshots immutable for free.  When a bucket row or the reserved
  edge rows run out, the engine falls back to a compaction rebuild
  (``rebuild_compacted``) that re-reserves slack and preserves
  input-order edge ids; readers never block on it — they keep serving
  the last published snapshot.

* **Dirty-scope tracking -> scheduler task set.**  Each mutation
  records the vertices whose update inputs it invalidated (DESIGN.md
  §13: vertex write -> 1-hop closure of the vertex; edge write -> the
  two endpoints; insert -> 1-hop closure of both endpoints).
  ``recompute`` seeds the scheduler's ``active=`` set with exactly
  that mask, so convergence reuses the ordinary task-set algebra —
  and, under the window schedulers, the PR-4 ``[B, W]`` batch dispatch
  path — instead of full-graph sweeps.  Steady-state supersteps run
  through ``ExecutorCore.step_on``: graph structure is a traced
  argument, so slack inserts never recompile.

* **Snapshot isolation for reads.**  Queries read a
  :class:`GraphSnapshot` published only at recompute boundaries
  (superstep boundaries are globally consistent cuts, paper §8; set
  ``publish_every=`` to also publish mid-recompute cuts during long
  convergences).  A held snapshot handle stays bitwise-stable across
  any later mutations or compactions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coloring import greedy_coloring
from repro.core.exec import dirty_scope_mask, init_engine_state
from repro.core.graph import (DataGraph, input_order_edges, insert_edges,
                              rebuild_compacted)

PyTree = Any


# ----------------------------------------------------------------------
# GraphSnapshot: the immutable published read view
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GraphSnapshot:
    """A consistent, immutable view of converged data for queries.

    Published at recompute boundaries; every array here is a pinned
    reference that no later mutation rewrites (mutations replace
    arrays).  ``edge_inv_perm``/``n_edges`` are captured with the data
    so edge reads stay correct across later inserts and compactions;
    the edge index dict is shared (it is append-only, and entries past
    ``n_edges`` are ignored here).
    """
    vertex_data: PyTree
    edge_data: PyTree
    globals: dict
    n_vertices: int
    n_edges: int
    round: int                 # recompute round that published this view
    superstep: int             # cumulative supersteps at publish time
    _edge_inv_perm: np.ndarray = dataclasses.field(repr=False)
    _edge_index: dict = dataclasses.field(repr=False)

    # -- queries -------------------------------------------------------
    def read_vertex(self, ids, field: str | None = None):
        """Vertex data rows at ``ids`` (a field, or the whole tree)."""
        ids = np.asarray(ids)
        if field is not None:
            return np.asarray(self.vertex_data[field])[ids]
        return jax.tree.map(lambda a: np.asarray(a)[ids], self.vertex_data)

    def find_edge(self, u: int, v: int) -> int | None:
        """Input-order edge id of ``{u, v}`` in this view, or None."""
        eid = self._edge_index.get((min(int(u), int(v)), max(int(u), int(v))))
        return eid if eid is not None and eid < self.n_edges else None

    def read_edge(self, u: int, v: int, field: str | None = None):
        """Edge data of ``{u, v}``; ``KeyError`` if absent in this view."""
        eid = self.find_edge(u, v)
        if eid is None:
            raise KeyError(f"no edge {{{u}, {v}}} in snapshot "
                           f"(round {self.round})")
        row = int(self._edge_inv_perm[eid])
        if field is not None:
            return np.asarray(self.edge_data[field])[row]
        return jax.tree.map(lambda a: np.asarray(a)[row], self.edge_data)

    def top_k(self, field: str, k: int, largest: bool = True):
        """Top-``k`` vertices by a scalar vertex field: ``(ids, values)``."""
        vals = np.asarray(self.vertex_data[field])
        if vals.ndim != 1:
            raise ValueError(f"top_k needs a scalar field, {field!r} has "
                             f"shape {vals.shape[1:]} per vertex")
        order = np.argsort(-vals if largest else vals, kind="stable")[:k]
        return order, vals[order]


# ----------------------------------------------------------------------
# ServingEngine
# ----------------------------------------------------------------------

class ServingEngine:
    """Long-lived mutate/recompute/query loop over one scheduler.

    Construct through :func:`repro.api.serve` (which validates the
    scheduler configuration and ensures slack storage).  ``spec`` is
    the validated ``api.EngineSpec``; ``partition=`` is forwarded to
    distributed builds (``n_shards > 1``), which rebuild their engine
    every recompute round (the ShardPlan depends on structure) and
    require updates that write vertex data only — there is no
    edge-data backflow from shards, the host copy stays authoritative.
    """

    def __init__(self, graph: DataGraph, update_fn, syncs: Sequence = (),
                 *, spec, partition=None, publish_every: int | None = None):
        if graph.slack <= 0:
            raise ValueError(
                "ServingEngine needs mutable storage: build the graph "
                "with slack (api.serve does this automatically)")
        self._graph = graph
        self._update = update_fn
        self._syncs = tuple(syncs)
        self._spec = spec
        self._partition = partition
        self.publish_every = publish_every
        # colors are only *maintained* when the scheduler consumes them
        # (chromatic): a recolor bumps the engine-cache key and forces a
        # retrace, which schedulers that ignore colors shouldn't pay
        self._track_colors = (graph.colors is not None
                              and getattr(spec.entry, "needs_colors", False))
        self._colors = (np.asarray(graph.colors).copy()
                        if self._track_colors else None)
        self._colors_version = 0
        self._struct_version = 0
        self._engines: dict = {}       # (colors_version, ell meta) -> engine
        edges_in, _ = input_order_edges(graph)
        self._edge_index: dict[tuple[int, int], int] = {
            (min(int(u), int(v)), max(int(u), int(v))): i
            for i, (u, v) in enumerate(edges_in)}
        # dirty bookkeeping: closure seeds get their 1-hop scope mask,
        # exact seeds only themselves (DESIGN.md §13)
        self._dirty_closure: set[int] = set()
        self._dirty_exact: set[int] = set()
        self._round = 0
        self._supersteps = 0
        self._snapshot: GraphSnapshot | None = None
        self._last_state = None
        self.last_launches: list[dict] | None = None
        self.stats = {
            "edges_inserted": 0, "slack_inserts": 0, "compactions": 0,
            "vertex_updates": 0, "edge_updates": 0, "recolors": 0,
            "rounds": 0, "supersteps": 0, "updates": 0,
        }
        self._publish()

    # -- introspection (working graph, not the snapshot) ---------------
    @property
    def graph(self) -> DataGraph:
        """The current working graph (mutations applied, possibly not
        yet reconverged).  Queries should go through ``snapshot()``."""
        return self._graph

    @property
    def n_edges(self) -> int:
        return self._graph.n_edges

    def degrees(self) -> np.ndarray:
        return np.asarray(self._graph.degree)

    def neighbors(self, v: int):
        """Current neighbors of ``v``: ``(nbr_ids, edge_input_ids)``."""
        rows = self._graph.struct_rows(jnp.asarray([int(v)], jnp.int32))
        m = np.asarray(rows.nbr_mask[0])
        nbrs = np.asarray(rows.nbrs[0])[m]
        eids = np.asarray(self._graph.edge_perm)[
            np.asarray(rows.edge_ids[0])[m]]
        return nbrs, eids

    def find_edge(self, u: int, v: int) -> int | None:
        eid = self._edge_index.get((min(int(u), int(v)), max(int(u), int(v))))
        return eid if eid is not None and eid < self._graph.n_edges else None

    # -- mutations ------------------------------------------------------
    def add_edges(self, edges, edge_data: Mapping | None = None) -> np.ndarray:
        """Insert undirected edges; returns their input-order edge ids.

        Fast path fills slack slots in place (no rebuild, no shape
        change); on slack exhaustion falls back to a compaction rebuild
        that re-reserves headroom — readers keep the last snapshot
        either way.  Duplicate edges raise (update the existing edge's
        data with ``update_edge_data`` instead).
        """
        edges = np.asarray(edges, np.int64).reshape(-1, 2)
        if len(edges) == 0:
            return np.empty((0,), np.int64)
        keys = [(min(int(u), int(v)), max(int(u), int(v))) for u, v in edges]
        for key in keys:
            if self.find_edge(*key) is not None:
                raise ValueError(
                    f"edge {{{key[0]}, {key[1]}}} already exists; use "
                    "update_edge_data to change its data")
        if len(set(keys)) != len(keys):
            raise ValueError("duplicate edges within one add_edges batch")
        ne = self._graph.n_edges
        g2 = insert_edges(self._graph, edges, edge_data)
        if g2 is None:
            self._graph = rebuild_compacted(self._graph, extra_edges=edges,
                                            extra_edge_data=edge_data)
            self._struct_version += 1
            self.stats["compactions"] += 1
            if self._colors is not None:
                ein, _ = input_order_edges(self._graph)
                self._set_colors(greedy_coloring(self._graph.n_vertices, ein))
        else:
            self._graph = g2
            self.stats["slack_inserts"] += len(edges)
            if self._colors is not None:
                self._fix_colors(edges)
        if self._colors is not None:
            self._graph = self._graph.with_colors(self._colors)
        new_ids = np.arange(ne, ne + len(edges), dtype=np.int64)
        for key, eid in zip(keys, new_ids):
            self._edge_index[key] = int(eid)
        self._dirty_closure.update(int(x) for x in edges.reshape(-1))
        self.stats["edges_inserted"] += len(edges)
        return new_ids

    def add_edge(self, u: int, v: int, **fields) -> int:
        data = ({k: np.asarray([val]) for k, val in fields.items()}
                if fields else None)
        return int(self.add_edges(np.asarray([[u, v]]), data)[0])

    def update_vertex_data(self, ids, values: Mapping) -> None:
        """Write vertex-data rows: ``values`` maps field -> ``[m, ...]``
        rows for the ``m`` vertices in ``ids``.  Dirties the 1-hop
        scopes of the written vertices."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= self._graph.n_vertices):
            raise ValueError(
                f"vertex ids must be in [0, {self._graph.n_vertices})")
        rows = jnp.asarray(ids)
        vdata = dict(self._graph.vertex_data)
        for field, vals in values.items():
            if field not in vdata:
                raise KeyError(
                    f"unknown vertex field {field!r}; graph has "
                    f"{sorted(vdata)}")
            vdata[field] = vdata[field].at[rows].set(
                jnp.asarray(vals, vdata[field].dtype))
        self._graph = dataclasses.replace(self._graph, vertex_data=vdata)
        self._dirty_closure.update(int(x) for x in ids)
        self.stats["vertex_updates"] += int(ids.size)

    def update_edge_data(self, edge_ids, values: Mapping) -> None:
        """Write edge-data rows by input-order edge id (from
        ``find_edge``/``add_edges``/``neighbors``).  Dirties exactly the
        edges' endpoints — the only scopes that can read edge data."""
        edge_ids = np.asarray(edge_ids, np.int64).reshape(-1)
        if edge_ids.size == 0:
            return
        if edge_ids.min() < 0 or edge_ids.max() >= self._graph.n_edges:
            raise ValueError(
                f"edge ids must be in [0, {self._graph.n_edges})")
        stored = np.asarray(self._graph.edge_inv_perm)[edge_ids]
        rows = jnp.asarray(stored)
        edata = dict(self._graph.edge_data)
        for field, vals in values.items():
            if field not in edata:
                raise KeyError(
                    f"unknown edge field {field!r}; graph has "
                    f"{sorted(edata)}")
            edata[field] = edata[field].at[rows].set(
                jnp.asarray(vals, edata[field].dtype))
        self._graph = dataclasses.replace(self._graph, edge_data=edata)
        self._dirty_exact.update(
            int(x) for x in self._graph.edges_np[stored].reshape(-1))
        self.stats["edge_updates"] += int(edge_ids.size)

    def update_edge(self, u: int, v: int, **fields) -> None:
        eid = self.find_edge(u, v)
        if eid is None:
            raise KeyError(f"no edge {{{u}, {v}}}; add_edge it first")
        self.update_edge_data(
            [eid], {k: np.asarray([val]) for k, val in fields.items()})

    # -- chromatic upkeep ----------------------------------------------
    def _set_colors(self, colors: np.ndarray) -> None:
        self._colors = np.asarray(colors, np.int32)
        self._colors_version += 1
        self.stats["recolors"] += 1

    def _fix_colors(self, new_edges: np.ndarray) -> None:
        """Local greedy repair: an insert joining same-colored endpoints
        moves one endpoint to the smallest color free in its (new)
        neighborhood.  Keeps the coloring proper — color count may grow."""
        changed = False
        for u, v in new_edges:
            u, v = int(u), int(v)
            if self._colors[u] != self._colors[v]:
                continue
            nbrs, _ = self.neighbors(u)
            used = set(int(self._colors[n]) for n in nbrs)
            c = 0
            while c in used:
                c += 1
            self._colors = self._colors.copy()
            self._colors[u] = c
            changed = True
        if changed:
            self._colors_version += 1
            self.stats["recolors"] += 1

    # -- recompute ------------------------------------------------------
    def dirty_mask(self) -> np.ndarray:
        """The ``[Nv]`` bool task-set seed the next recompute will use."""
        mask = np.zeros((self._graph.n_vertices,), bool)
        if self._dirty_closure:
            mask |= np.asarray(dirty_scope_mask(
                self._graph, np.fromiter(self._dirty_closure, np.int32)))
        if self._dirty_exact:
            mask[np.fromiter(self._dirty_exact, np.int64)] = True
        return mask

    def _engine(self):
        ell = self._graph.ell
        key = (self._colors_version, ell.widths, tuple(ell.starts),
               ell.n_rows, ell.pad_edge)
        eng = self._engines.get(key)
        if eng is None:
            eng = self._spec.build(self._graph, self._update, self._syncs)
            self._engines[key] = eng
        return eng

    def recompute(self, *, full: bool | None = None,
                  max_supersteps: int | None = None,
                  track_launches: bool = False) -> dict:
        """Re-converge the dirty scopes; publish a fresh snapshot.

        ``full=`` seeds every vertex instead of the dirty mask
        (``None`` auto-selects full for the first round, when nothing
        has converged yet).  ``track_launches=True`` records the launch
        shape of each superstep's first phase (eager probe, costs one
        selection pass per superstep) into the returned stats and
        ``self.last_launches``.  Returns ``{"round", "supersteps",
        "updates", "dirty", "launches"}``.
        """
        if full is None:
            full = self._round == 0
        if full:
            mask = np.ones((self._graph.n_vertices,), bool)
        else:
            mask = self.dirty_mask()
        self._dirty_closure.clear()
        self._dirty_exact.clear()
        n_dirty = int(mask.sum())
        if n_dirty == 0:
            self._publish()
            return {"round": self._round, "supersteps": 0, "updates": 0,
                    "dirty": 0, "launches": []}
        if self._spec.distributed(self._partition):
            return self._recompute_distributed(mask, max_supersteps)
        engine = self._engine()
        state = init_engine_state(
            self._graph.vertex_data, self._graph.edge_data,
            self._graph.n_vertices, self._syncs, active=jnp.asarray(mask))
        cap = max_supersteps or engine.max_supersteps
        launches: list[dict] = []
        steps = 0
        while bool(state.active.any()) and steps < cap:
            if track_launches:
                launches.append(engine.probe_on(self._graph, state))
            state = engine.step_on(self._graph, state)
            steps += 1
            if (self.publish_every and steps % self.publish_every == 0
                    and bool(state.active.any())):
                self._fold(state)
                self._publish(superstep_delta=steps)
        self._fold(state)
        self._last_state = state
        self._round += 1
        self._supersteps += steps
        self.stats["rounds"] += 1
        self.stats["supersteps"] += steps
        self.stats["updates"] += int(state.n_updates)
        self.last_launches = launches if track_launches else None
        self._publish()
        return {"round": self._round, "supersteps": steps,
                "updates": int(state.n_updates), "dirty": n_dirty,
                "launches": launches}

    def _recompute_distributed(self, mask: np.ndarray,
                               max_supersteps: int | None) -> dict:
        spec = self._spec
        if max_supersteps is not None:
            spec = dataclasses.replace(spec, max_supersteps=max_supersteps)
        engine = spec.build(self._graph, self._update, self._syncs,
                            partition=self._partition)
        out = engine.run(active=jnp.asarray(mask))
        vdata = jax.tree.map(jnp.asarray, out["vertex_data"])
        self._graph = dataclasses.replace(self._graph, vertex_data=vdata)
        self._round += 1
        steps = int(out["supersteps"])
        self._supersteps += steps
        self.stats["rounds"] += 1
        self.stats["supersteps"] += steps
        self.stats["updates"] += int(out["n_updates"])
        self.last_launches = None
        self._publish(globals_=out["globals"])
        return {"round": self._round, "supersteps": steps,
                "updates": int(out["n_updates"]),
                "dirty": int(mask.sum()), "launches": []}

    def _fold(self, state) -> None:
        """Fold a converged EngineState back into the working graph —
        after this, ``graph.vertex_data``/``edge_data`` *are* the
        authoritative serving values."""
        self._graph = dataclasses.replace(
            self._graph, vertex_data=state.vertex_data,
            edge_data=state.edge_data)

    def _publish(self, globals_: dict | None = None,
                 superstep_delta: int = 0) -> None:
        if globals_ is None:
            globals_ = {s.key: s.run(self._graph.vertex_data)
                        for s in self._syncs}
        self._snapshot = GraphSnapshot(
            vertex_data=self._graph.vertex_data,
            edge_data=self._graph.edge_data,
            globals=globals_,
            n_vertices=self._graph.n_vertices,
            n_edges=self._graph.n_edges,
            round=self._round,
            superstep=self._supersteps + superstep_delta,
            _edge_inv_perm=np.asarray(self._graph.edge_inv_perm),
            _edge_index=self._edge_index)

    # -- queries (delegate to the published snapshot) ------------------
    def snapshot(self) -> GraphSnapshot:
        """Pin the current published view: later mutations/recomputes
        never change what this handle reads."""
        return self._snapshot

    def read_vertex(self, ids, field: str | None = None):
        return self._snapshot.read_vertex(ids, field)

    def read_edge(self, u: int, v: int, field: str | None = None):
        return self._snapshot.read_edge(u, v, field)

    def top_k(self, field: str, k: int, largest: bool = True):
        return self._snapshot.top_k(field, k, largest)

    # -- persistence ----------------------------------------------------
    def save_snapshot(self, path: str) -> None:
        """Persist the last converged EngineState (single-device rounds)
        through ``repro.train.checkpoint.snapshot_engine_state``."""
        if self._last_state is None:
            raise ValueError("nothing to save: run recompute() first "
                             "(distributed rounds keep state sharded)")
        from repro.train.checkpoint import snapshot_engine_state
        snapshot_engine_state(path, self._last_state)
