from repro.serve.engine import decode_step, init_cache, cache_width, ServeState
