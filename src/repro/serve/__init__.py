from repro.serve.engine import decode_step, init_cache, cache_width, ServeState
from repro.serve.graph_engine import GraphSnapshot, ServingEngine
