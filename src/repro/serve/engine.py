"""Batched serving: KV-cache decode step for every arch family.

Cache policy:
  * attention layers: ring buffer of width ``W`` — full ``seq_len`` for
    decode_32k, ``cfg.serve_window`` for the long_500k sliding-window
    serving path of dense/vlm archs (the sub-quadratic variant DESIGN.md
    §5 commits to).  Entries are roped at absolute positions on insert.
  * mamba layers: O(1) recurrent state [B, d_inner, d_state] + conv tail.
  * audio (enc-dec): precomputed cross-attention K/V over the encoder
    memory (the decode_32k/long_500k "context" for enc-dec archs) plus a
    small self-attention ring.

``decode_step`` consumes ONE token per request and returns (logits,
new_state) — the decode_32k / long_500k dry-run entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, mamba
from repro.models import moe as moe_lib
from repro.models import model as model_lib
from repro.models.layers import rmsnorm

PyTree = Any

_SELF_RING_ENCDEC = 1024      # decoder self-attention ring for enc-dec


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ServeState:
    cache_k: PyTree      # [L, B, W, Hkv, dh] (or per-period dict; {} if ssm)
    cache_v: PyTree
    cache_len: jax.Array          # [B] absolute position counter
    mamba_state: PyTree           # stacked mamba states ({} if none)
    mem_k: PyTree                 # cross-attn K [L, B, T, Hkv, dh] ({} if not enc-dec)
    mem_v: PyTree


def _n_attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for i in range(cfg.n_layers) if cfg.is_attn_layer(i))


def _n_mamba_layers(cfg: ModelConfig) -> int:
    return cfg.n_layers - _n_attn_layers(cfg)


def cache_width(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.arch_type == "audio":
        return _SELF_RING_ENCDEC
    if cfg.serve_window is not None and seq_len > 32_768:
        return cfg.serve_window
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> ServeState:
    w = cache_width(cfg, seq_len)
    la = _n_attn_layers(cfg)
    kv = (cfg.n_kv_heads, cfg.dh)
    ck = cv = {}
    if la:
        ck = jnp.zeros((la, batch, w) + kv, dtype)
        cv = jnp.zeros((la, batch, w) + kv, dtype)
    ms: PyTree = {}
    lm = _n_mamba_layers(cfg)
    if lm:
        one = mamba.init_decode_state(cfg, batch)
        ms = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (lm,) + a.shape), one)
    mk = mv = {}
    if cfg.enc_dec:
        mk = jnp.zeros((cfg.n_layers, batch, seq_len) + kv, dtype)
        mv = jnp.zeros((cfg.n_layers, batch, seq_len) + kv, dtype)
    # attention caches start "full" (seq_len context); enc-dec self
    # ring starts empty (context lives in the cross-attention memory)
    start = jnp.full((batch,), 0 if cfg.enc_dec else seq_len, jnp.int32)
    return ServeState(ck, cv, start, ms, mk, mv)


# ----------------------------------------------------------------------

def _ring_insert(cache, new, slot):
    """cache [B,W,H,dh]; new [B,1,H,dh]; slot [B]."""
    b = cache.shape[0]
    return cache.at[jnp.arange(b), slot].set(new[:, 0])


def _decode_layer(lp, cfg, x, ck, cv, clen, w):
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    # insert-then-attend (cache update happens inside decode_attention)
    out, ck, cv = attention.decode_attention(lp["mix"], cfg, h, ck, cv,
                                             clen)
    x = x + out
    if "ffn" in lp:
        h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        if cfg.moe is not None and "router" in lp["ffn"]:
            y, _ = moe_lib.apply(lp["ffn"], cfg, h2)
        else:
            y = model_lib._mlp_apply(lp["ffn"], cfg, h2)
        x = x + y
    return x, ck, cv


def _decode_mamba_layer(lp, cfg, x, mstate):
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    y, mstate = mamba.apply_decode(lp["mix"], cfg, h, mstate)
    x = x + y
    if "ffn" in lp:
        h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        if cfg.moe is not None and "router" in lp["ffn"]:
            y2, _ = moe_lib.apply(lp["ffn"], cfg, h2)
        else:
            y2 = model_lib._mlp_apply(lp["ffn"], cfg, h2)
        x = x + y2
    return x, mstate


def decode_step(params, cfg: ModelConfig, token: jax.Array,
                state: ServeState):
    """token: [B, 1] int32 -> (logits [B, vocab_padded], new_state)."""
    x = params["embed"][token]
    clen = state.cache_len
    w = state.cache_k.shape[2] if not isinstance(state.cache_k, dict) else 0

    if cfg.arch_type in ("dense", "moe", "vlm"):
        def body(carry, xs):
            x, = carry
            lp, ck, cv = xs
            x, ck, cv = _decode_layer(lp, cfg, x, ck, cv, clen, w)
            return (x,), (ck, cv)
        (x,), (nck, ncv) = jax.lax.scan(
            body, (x,), (params["layers"], state.cache_k, state.cache_v))
        new_state = dataclasses.replace(
            state, cache_k=nck, cache_v=ncv, cache_len=clen + 1)

    elif cfg.arch_type == "ssm":
        def body(carry, xs):
            x, = carry
            lp, ms = xs
            x, ms = _decode_mamba_layer(lp, cfg, x, ms)
            return (x,), ms
        (x,), nms = jax.lax.scan(
            body, (x,), (params["layers"], state.mamba_state))
        new_state = dataclasses.replace(
            state, mamba_state=nms, cache_len=clen + 1)

    elif cfg.arch_type == "hybrid":
        period = cfg.attn_every
        n_periods = cfg.n_layers // period

        def body(carry, xs):
            x, = carry
            pp, ck, cv, ms = xs          # ck/cv: [1,B,W,..]; ms leading 7
            mi = 0
            for j in range(period):
                lp = pp[f"l{j}"]
                if j % period == 0:
                    x, ck_j, cv_j = _decode_layer(
                        lp, cfg, x, ck[0], cv[0], clen, w)
                    ck = ck.at[0].set(ck_j)
                    cv = cv.at[0].set(cv_j)
                else:
                    ms_j = jax.tree.map(lambda a: a[mi], ms)
                    x, ms_j = _decode_mamba_layer(lp, cfg, x, ms_j)
                    ms = jax.tree.map(lambda a, b: a.at[mi].set(b), ms, ms_j)
                    mi += 1
            return (x,), (ck, cv, ms)

        la = _n_attn_layers(cfg)
        lm = _n_mamba_layers(cfg)
        ck_p = state.cache_k.reshape((n_periods, la // n_periods)
                                     + state.cache_k.shape[1:])
        cv_p = state.cache_v.reshape((n_periods, la // n_periods)
                                     + state.cache_v.shape[1:])
        ms_p = jax.tree.map(
            lambda a: a.reshape((n_periods, lm // n_periods) + a.shape[1:]),
            state.mamba_state)
        (x,), (nck, ncv, nms) = jax.lax.scan(
            body, (x,), (params["layers"], ck_p, cv_p, ms_p))
        new_state = dataclasses.replace(
            state,
            cache_k=nck.reshape(state.cache_k.shape),
            cache_v=ncv.reshape(state.cache_v.shape),
            mamba_state=jax.tree.map(
                lambda a, ref: a.reshape(ref.shape), nms, state.mamba_state),
            cache_len=clen + 1)

    elif cfg.arch_type == "audio":
        def body(carry, xs):
            x, = carry
            lp, ck, cv, mk, mv = xs
            h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
            # self-attention ring counts generated tokens; the
            # cross-attention memory holds the seq_len context.
            out, ck, cv = attention.decode_attention(
                lp["mix"], cfg, h, ck, cv, clen)
            x = x + out
            hx = rmsnorm(x, lp["norm_x"], cfg.norm_eps)
            mmask = jnp.ones((mk.shape[0], mk.shape[1]), bool)   # [B, T]
            x = x + attention.cross_attention(
                lp["cross"], cfg, hx, mk, mv, mmask)
            if "ffn" in lp:
                h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
                x = x + model_lib._mlp_apply(lp["ffn"], cfg, h2)
            return (x,), (ck, cv)
        (x,), (nck, ncv) = jax.lax.scan(
            body, (x,), (params["layers"], state.cache_k, state.cache_v,
                         state.mem_k, state.mem_v))
        new_state = dataclasses.replace(
            state, cache_k=nck, cache_v=ncv, cache_len=clen + 1)
    else:
        raise ValueError(cfg.arch_type)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)   # x: [B, 1, d]
    return model_lib._logits(params, cfg, x)[:, 0], new_state
