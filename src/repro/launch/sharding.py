"""Sharding rules: param / batch / cache PartitionSpecs (FSDP x TP).

Baseline layout (the §Perf baseline):
  * weights: FSDP over the "data" axis bundle on the d_model-ish dim,
    tensor parallel over "model" on heads / ffn-hidden / experts,
  * activations: batch over the data bundle, GSPMD propagates the rest,
  * KV caches: batch over data, cache rows over "model" when the batch
    axis alone cannot hold them (decode_32k) or batch is 1 (long_500k).

Rules are path-based over the param pytree; stacked layer axes (from the
scan-over-layers representation) are transparently skipped by padding
specs with leading None.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig

PyTree = Any


def _rule(path: str, ndim: int, F, T):
    """Returns the spec for the *trailing logical dims* of the param."""
    if "norm" in path or path.endswith(("conv_b", "dt_bias", "D")):
        return ()
    if "embed" in path or path.endswith("out"):
        return (T, F)
    if path.endswith(("wq", "wk", "wv")):
        return (F, T)
    if path.endswith("wo"):
        return (T, F)
    if path.endswith("router"):
        return (F, None)
    if path.endswith(("w_gate", "w_up")):
        return (F, T)
    if path.endswith("w_down"):
        return (T, F)
    if path.endswith("in_proj"):
        return (F, T)
    if path.endswith("out_proj"):
        return (T, F)
    if path.endswith("x_proj"):
        return (T, None)
    if path.endswith("dt_proj"):
        return (None, T)
    if path.endswith("conv_w"):
        return (None, T)
    if path.endswith("A_log"):
        return (T, None)
    if path.endswith(("w1", "w2")):       # vlm projector
        return (F, T) if path.endswith("w1") else (T, F)
    return None   # replicate


_MOE_KEYS = ("w_gate", "w_up", "w_down")


def param_specs(params_struct: PyTree, cfg: ModelConfig, mesh,
                fsdp: bool = True) -> PyTree:
    """fsdp=False is the *serving* layout: weights resident, sharded over
    the model axis only (no per-step FSDP all-gathers) — the §Perf
    optimization for decode shapes."""
    F = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    F = (F if len(F) > 1 else (F[0] if F else None)) if fsdp else None
    T = "model" if "model" in mesh.axis_names else None

    def spec_for(path_elems, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path_elems)
        ndim = leaf.ndim
        # expert weights are logically rank-3 ([E, d, dff]); stacked
        # layer axis makes them rank-4.  Dense MLP weights are rank-2/3.
        logical_moe = any(path.endswith(k) for k in _MOE_KEYS) and ndim >= 4
        base = _rule(path, ndim, F, T)
        if base is None:
            return P()
        # expert weights: logical rank 3
        if logical_moe:
            base = {"w_gate": ("model", F, None), "w_up": ("model", F, None),
                    "w_down": ("model", F, None)}[path.split("/")[-1]]
        lead = ndim - len(base)
        spec = (None,) * lead + tuple(base)
        # guard: divisibility — drop axes that do not divide
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        fixed = []
        for dim, ax in zip(leaf.shape[lead:] if lead >= 0 else leaf.shape,
                           base):
            if ax is None:
                fixed.append(None)
                continue
            axsz = (sizes[ax] if isinstance(ax, str)
                    else int(jnp.prod(jnp.asarray([sizes[a] for a in ax]))))
            fixed.append(ax if dim % axsz == 0 else None)
        spec = (None,) * lead + tuple(fixed)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, params_struct)


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh,
                batch_struct: dict) -> dict:
    D = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsz = 1
    for a in D:
        dsz *= sizes[a]
    D = D if shape.global_batch % dsz == 0 else \
        (("data",) if shape.global_batch % sizes.get("data", 1) == 0
         else ())
    Dspec = D if len(D) != 1 else D[0]
    out = {}
    for k, v in batch_struct.items():
        spec = [Dspec if D else None] + [None] * (v.ndim - 1)
        out[k] = P(*spec)
    return out


def serve_state_specs(cfg: ModelConfig, shape: InputShape, mesh,
                      state_struct) -> Any:
    """Specs matching the ServeState structure (see serve.engine)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    D = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsz = 1
    for a in D:
        dsz *= sizes[a]
    b = shape.global_batch
    if b % dsz != 0:
        D = ("data",) if b % sizes.get("data", 1) == 0 else ()
    Dspec = (D if len(D) != 1 else D[0]) if D else None
    T = "model" if "model" in mesh.axis_names else None

    def kv_spec(leaf):
        # [L, B, W, Hkv, dh]
        l_, bb, w = leaf.shape[:3]
        spec = [None, Dspec, None, None, None]
        tsz = sizes.get("model", 1)
        if w % tsz == 0 and w >= 4096:
            spec[2] = T
        if bb == 1 and Dspec is not None:
            spec[1] = None
        if bb == 1:
            # long_500k: shard cache rows over everything that divides
            full = tuple(mesh.axis_names)
            fsz = 1
            for a in full:
                fsz *= sizes[a]
            if w % fsz == 0:
                spec[2] = full
        return P(*spec)

    def generic(leaf):
        if leaf.ndim == 0:
            return P()
        spec = [None] * leaf.ndim
        # leading dims: [L, B, ...] or [B]
        if leaf.ndim == 1:       # cache_len [B]
            spec[0] = Dspec if leaf.shape[0] > 1 else None
            return P(*spec)
        if leaf.shape[1] == shape.global_batch and shape.global_batch > 1:
            spec[1] = Dspec
        # mamba h: [L, B, di, ds] — di over model
        tsz = sizes.get("model", 1)
        if leaf.ndim >= 3 and leaf.shape[-2] % tsz == 0 \
                and leaf.shape[-2] >= 1024:
            spec[-2] = T
        elif leaf.ndim >= 3 and leaf.shape[-1] % tsz == 0 \
                and leaf.shape[-1] >= 1024:
            spec[-1] = T
        return P(*spec)

    from repro.serve.engine import ServeState
    return ServeState(
        cache_k=jax.tree.map(kv_spec, state_struct.cache_k),
        cache_v=jax.tree.map(kv_spec, state_struct.cache_v),
        cache_len=jax.tree.map(generic, state_struct.cache_len),
        mamba_state=jax.tree.map(generic, state_struct.mamba_state),
        mem_k=jax.tree.map(kv_spec, state_struct.mem_k),
        mem_v=jax.tree.map(kv_spec, state_struct.mem_v),
    )
