"""Serving launcher: batched greedy decoding against a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --batch 4 --context 64 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as model_lib
from repro.serve import engine as serve_engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    state = serve_engine.init_cache(cfg, args.batch, args.context)
    step = jax.jit(
        lambda p, t, s: serve_engine.decode_step(p, cfg, t, s))
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, 1)),
                      jnp.int32)
    # warmup/compile
    logits, state = step(params, tok, state)
    t0 = time.time()
    out_tokens = [tok]
    for _ in range(args.tokens - 1):
        tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1)[:, None] \
            .astype(jnp.int32)
        logits, state = step(params, tok, state)
        out_tokens.append(tok)
    dt = time.time() - t0
    seqs = jnp.concatenate(out_tokens, axis=1)
    tput = args.batch * (args.tokens - 1) / max(dt, 1e-9)
    print(f"{cfg.name}: batch={args.batch} context={args.context} "
          f"-> {args.tokens} tokens/request")
    print(f"throughput {tput:.1f} tok/s (CPU, reduced config)")
    print("sampled ids:", np.asarray(seqs)[:, :10])


if __name__ == "__main__":
    main()
