import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh).

For each combination this builds the production mesh, constructs
ShapeDtypeStruct stand-ins for params/optimizer/batch (or token +
ServeState for decode shapes), lowers the jitted step with explicit
in/out shardings, compiles, and reports:

  * memory_analysis()    — proves the step fits per-chip HBM
  * cost_analysis()      — FLOPs / bytes for the roofline terms
  * collective bytes     — parsed from the optimized HLO

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import gzip
import json
import os as _os
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import INPUT_SHAPES
from repro.data import pipeline
from repro.launch import sharding, shardctx
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw
from repro.roofline import analysis, hlo_parse
from repro.train.steps import (make_prefill_step, make_serve_step,
                               make_train_step)


def dryrun_one(arch: str, shape_name: str, multi_pod: bool = False,
               verbose: bool = True, serve_tp: bool = False,
               tag: str = "") -> dict:
    cfg = configs.get(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    t0 = time.time()
    params_s = pipeline.param_specs_struct(cfg)
    # serving layout (pure TP, resident weights) only makes sense for
    # inference shapes; training always uses FSDP x TP.
    use_fsdp = not (serve_tp and shape.kind in ("decode", "prefill"))
    pspecs = sharding.param_specs(params_s, cfg, mesh, fsdp=use_fsdp)

    def ns(spec_tree):
        return jax.tree.map(
            lambda sp: NamedSharding(mesh, sp), spec_tree,
            is_leaf=lambda x: isinstance(x, P))
    shard = lambda tree, specs: jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        tree, specs)

    shardctx.set_mesh(mesh)
    with mesh:
        if shape.kind == "train":
            batch_s = pipeline.train_input_specs(cfg, shape)
            bspecs = sharding.batch_specs(cfg, shape, mesh, batch_s)
            opt_s = jax.eval_shape(adamw.init, params_s)
            ospecs = type(opt_s)(
                m=pspecs, v=pspecs, step=P())
            step = make_train_step(cfg, adamw.AdamWConfig())
            fn = jax.jit(
                step,
                in_shardings=(ns(pspecs), ns(ospecs), ns(bspecs)),
                out_shardings=(ns(pspecs), ns(ospecs), None),
            )
            args = (shard(params_s, pspecs),
                    type(opt_s)(m=shard(opt_s.m, pspecs),
                                v=shard(opt_s.v, pspecs),
                                step=opt_s.step),
                    shard(batch_s, bspecs))
        elif shape.kind == "prefill":
            batch_s = pipeline.train_input_specs(cfg, shape)
            batch_s.pop("labels")
            bspecs = sharding.batch_specs(cfg, shape, mesh, batch_s)
            step = make_prefill_step(cfg)
            fn = jax.jit(step, in_shardings=(ns(pspecs), ns(bspecs)),
                         out_shardings=None)
            args = (shard(params_s, pspecs), shard(batch_s, bspecs))
        else:  # decode
            token_s, state_s = pipeline.decode_input_specs(cfg, shape)
            sspecs = sharding.serve_state_specs(cfg, shape, mesh, state_s)
            tspec = sharding.batch_specs(cfg, shape, mesh,
                                         {"t": token_s})["t"]
            step = make_serve_step(cfg)
            fn = jax.jit(step, in_shardings=(ns(pspecs), ns(tspec), ns(sspecs)),
                         out_shardings=(None, ns(sspecs)))
            args = (shard(params_s, pspecs),
                    jax.ShapeDtypeStruct(
                        token_s.shape, token_s.dtype,
                        sharding=NamedSharding(mesh, tspec)),
                    shard(state_s, sspecs))

        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    # cache the optimized HLO so the roofline walker can be re-run
    # without recompiling (repro.roofline.reanalyze)
    _os.makedirs("results/hlo", exist_ok=True)
    hlo_path = (f"results/hlo/{arch}__{shape_name}__{mesh_name}"
                f"{('__' + tag) if tag else ''}.txt.gz")
    with gzip.open(hlo_path, "wt") as f:
        f.write(hlo)
    # proper accounting: walk the HLO with while-loop trip counts
    # (cost_analysis visits scan bodies once — useless for scanned layers)
    walked = hlo_parse.analyze(hlo)
    # walker works on post-SPMD per-device shapes; the spec's formulas
    # divide GLOBAL totals by chip count, so scale up.
    flops = walked.flops * chips
    bytes_ = walked.bytes * chips
    coll_total = walked.coll_bytes * chips
    mf = analysis.model_flops(cfg, shape)
    bytes_per_chip = analysis.parse_memory_analysis(mem)

    rf = analysis.Roofline(
        name=f"{arch}:{shape_name}", mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=bytes_, coll_bytes=coll_total,
        model_flops=mf, bytes_per_chip=bytes_per_chip)
    row = rf.row()
    row.update({
        # walker counts in the shared trace schema (flops / hbm_bytes /
        # coll_bytes / coll_breakdown) — repro.profile.trace.hlo_counts
        "hlo": walked.scaled(chips).counts(),
        "xla_cost_analysis": {
            "flops_body_once": float(cost.get("flops", 0.0)),
            "bytes_body_once": float(cost.get("bytes accessed", 0.0)),
        },
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
            "output_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
            "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
            "alias_gb": getattr(mem, "alias_size_in_bytes", 0) / 1e9,
        },
    })
    if verbose:
        ma = row["memory_analysis"]
        print(f"[{arch} x {shape_name} @ {mesh_name}] "
              f"compile {t_compile:.0f}s | "
              f"args {ma['argument_gb']:.2f}GB out {ma['output_gb']:.2f}GB "
              f"temp {ma['temp_gb']:.2f}GB | "
              f"Tc {row['t_compute_s']:.3e} Tm {row['t_memory_s']:.3e} "
              f"Tx {row['t_collective_s']:.3e} -> {row['bottleneck']} | "
              f"useful {row['usefulness']:.2f}")
        sys.stdout.flush()
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--serve-tp", action="store_true",
                    help="serving param layout (pure TP) for decode/prefill")
    ap.add_argument("--tag", default="", help="HLO cache suffix")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-sharded residual stream (B3)")
    ap.add_argument("--all", action="store_true",
                    help="all (arch x shape) on the chosen mesh")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in configs.ARCHS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    results = []
    for arch, shape in combos:
        try:
            if args.seq_shard:
                shardctx.set_residual_layout("seq")
            row = dryrun_one(arch, shape, args.multi_pod,
                             serve_tp=args.serve_tp, tag=args.tag)
        except Exception as e:
            row = {"name": f"{arch}:{shape}",
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "error": f"{type(e).__name__}: {e}"}
            print(f"[{arch} x {shape}] FAILED: {row['error']}")
            traceback.print_exc()
        results.append(row)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(row) + "\n")
    n_fail = sum(1 for r in results if "error" in r)
    print(f"\n{len(results) - n_fail}/{len(results)} combinations lowered "
          f"and compiled successfully")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
