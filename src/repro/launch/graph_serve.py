"""Online graph serving driver (DESIGN.md §13).

Replays a deterministic ``edge_stream`` mutation/query trace against a
long-lived ``ServingEngine``: each batch inserts edges into the slack
slots, rewrites touched vertex data, answers read queries from the
published snapshot (never blocking on the recompute), then seeds the
scheduler with the dirty scope and re-converges incrementally.

    PYTHONPATH=src python -m repro.launch.graph_serve \
        [--vertices 1000] [--batches 8] [--rate 8] [--scheduler locking]
"""
import argparse
import time

import numpy as np

from repro import api
from repro.apps import pagerank
from repro.core.graph import zipf_edges
from repro.data.pipeline import edge_stream


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=1000)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--scheduler", default="chromatic",
                    choices=["chromatic", "locking"])
    ap.add_argument("--slack", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-launches", action="store_true")
    args = ap.parse_args()

    nv = args.vertices
    edges = zipf_edges(nv, seed=args.seed)
    graph, update, syncs = pagerank.build(edges, nv, slack=args.slack)
    kwargs = {"dispatch": "batch", "max_pending": 64} \
        if args.scheduler == "locking" else {}
    serving = api.serve(graph, update, syncs=syncs,
                        scheduler=args.scheduler, slack=args.slack,
                        **kwargs)
    t0 = time.time()
    r = serving.recompute()
    print(f"graph: {nv} vertices, {len(edges)} edges; initial converge "
          f"{r['supersteps']} supersteps in {time.time() - t0:.2f}s")

    for batch in edge_stream(nv, rate=args.rate, seed=args.seed + 1,
                             n_batches=args.batches):
        t0 = time.time()
        inserted = 0
        fresh = np.asarray([e for e in batch.edges
                            if serving.find_edge(*e) is None],
                           np.int64).reshape(-1, 2)
        if len(fresh):
            ids = serving.add_edges(
                fresh, {"w": np.zeros(len(fresh), np.float32)})
            inserted = len(ids)
            touched = np.unique(fresh.ravel())
            eids, vals = pagerank.refreshed_weights(serving, touched)
            serving.update_edge_data(eids, vals)
        if len(batch.touch):
            # query traffic that writes: re-seed the touched ranks
            serving.update_vertex_data(
                batch.touch,
                {"rank": np.ones(len(batch.touch), np.float32)})
        # reads are served from the pinned snapshot, pre-recompute
        snap = serving.snapshot()
        ranks = snap.read_vertex(batch.queries, "rank")
        r = serving.recompute(track_launches=args.trace_launches)
        dt = time.time() - t0
        line = (f"[t={batch.t}] +{inserted} edges, "
                f"{len(batch.touch)} touches, {len(batch.queries)} reads "
                f"(mean rank {float(np.mean(ranks)) if len(ranks) else 0:.3f}) "
                f"| dirty={r['dirty']} supersteps={r['supersteps']} "
                f"updates={r['updates']} {dt:.2f}s")
        if args.trace_launches and r["launches"]:
            rows = [l["rows"] for l in r["launches"] if "rows" in l]
            line += f" launches={len(r['launches'])} max_rows={max(rows or [0])}"
        print(line)

    snap = serving.snapshot()
    ids, vals = snap.top_k("rank", 5)
    print(f"final: {serving.n_edges} edges "
          f"(+{serving.stats['edges_inserted']} live, "
          f"{serving.stats['compactions']} compactions); top-5 rank: "
          + ", ".join(f"v{int(i)}={float(v):.3f}" for i, v in zip(ids, vals)))


if __name__ == "__main__":
    main()
