"""Mesh context for activation-sharding hints inside model code.

The launcher (dryrun / train driver) installs the mesh here before
tracing; model code then emits ``with_sharding_constraint`` with concrete
``NamedSharding``s (which do not require an ambient mesh context).  When
unset — CPU smoke tests, unit tests — every hint is a no-op.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH: Any = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def hint(x, *spec):
    """Apply a sharding constraint if a mesh is installed.

    Axis names that do not exist on the mesh, or that do not divide the
    corresponding dimension, are dropped (so one rule covers single-pod,
    multi-pod, and reduced smoke configurations).
    """
    mesh = _MESH
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axs = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                    if a in names)
        n = 1
        for a in axs:
            n *= sizes[a]
        if axs and dim % n == 0:
            fixed.append(axs if len(axs) > 1 else axs[0])
        else:
            fixed.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


DP = ("pod", "data")    # batch/FSDP axis bundle
TP = "model"

# residual-stream layout between layers: "d" shards d_model over TP
# (baseline), "seq" shards the sequence axis instead (Megatron-SP style;
# §Perf iteration B3).
RESIDUAL_LAYOUT = "d"


def set_residual_layout(kind: str) -> None:
    global RESIDUAL_LAYOUT
    assert kind in ("d", "seq")
    RESIDUAL_LAYOUT = kind


def residual_hint(x):
    """Apply the configured residual-stream sharding to [B, S, d]."""
    if RESIDUAL_LAYOUT == "seq":
        return hint(x, DP, TP, None)
    return hint(x, DP, None, TP)
