"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --steps 50 --batch 4 --seq 128 [--reduced] [--ckpt path.npz]

``--reduced`` (default on CPU) trains the smoke-sized variant; the full
configs are for real pods — their distribution plan is validated by
``repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse
import dataclasses

from repro import configs
from repro.optim import adamw
from repro.train import trainer as trainer_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="full config (pod-scale; default is reduced)")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    pc = cfg.param_count()
    print(f"{cfg.name} ({'full' if args.full else 'reduced'}): "
          f"{pc['total'] / 1e6:.1f}M params")
    tcfg = trainer_lib.TrainerConfig(
        steps=args.steps, batch=args.batch, seq_len=args.seq,
        ckpt_path=args.ckpt,
        opt=adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps))
    trainer_lib.train(cfg, tcfg)


if __name__ == "__main__":
    main()
