"""Production meshes (spec-mandated shapes).

A FUNCTION, not a module-level constant, so importing never touches jax
device state.  Single pod: 16x16 = 256 chips (v5e pod), axes
("data", "model").  Multi-pod: 2 pods = 512 chips, axes
("pod", "data", "model") — "pod" is pure data parallelism over the DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, axis: str = "shard"):
    """1-D mesh over local devices (graph engine / tests)."""
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    n = n or len(devs)
    return Mesh(np.array(devs[:n]), (axis,))


def data_axes(mesh) -> tuple:
    """The batch/FSDP axis bundle: ("pod","data") multi-pod, else ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
