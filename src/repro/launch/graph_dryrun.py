import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Pod-scale dry-run of the paper's OWN workload: the distributed
chromatic engine on a 256-shard mesh.

Proves the GraphLab port itself (not just the transformer substrate)
lowers and compiles at production scale: a synthetic power-law PageRank
graph is two-phase-partitioned onto 256 shards, the ghost-exchange
schedule is built, and one engine superstep is lowered + compiled with
the state as ShapeDtypeStructs.  Reports the same roofline terms as the
main dry-run.

    PYTHONPATH=src python -m repro.launch.graph_dryrun \
        [--vertices 16384] [--shards 256]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.apps import pagerank
from repro.core import two_phase_partition
from repro.roofline import analysis, hlo_parse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=16384)
    ap.add_argument("--shards", type=int, default=256)
    ap.add_argument("--supersteps", type=int, default=4)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    nv = args.vertices
    # preferential-attachment-ish web graph
    edges = set()
    for v in range(1, nv):
        for _ in range(int(rng.integers(1, 4))):
            u = int(rng.integers(0, max(v, 1)))
            if u != v:
                edges.add((min(u, v), max(u, v)))
    edges = np.asarray(sorted(edges), dtype=np.int64)
    print(f"graph: {nv} vertices, {len(edges)} edges")

    t0 = time.time()
    g = pagerank.make_graph(edges, nv, max_deg=None)
    asg = two_phase_partition(nv, edges, args.shards, seed=0)
    eng = api.build_engine(
        g, pagerank.make_update(1e-4), scheduler="chromatic",
        syncs=[pagerank.total_rank_sync()], n_shards=args.shards,
        partition=asg, max_supersteps=args.supersteps)
    plan = eng.plan
    print(f"plan: {args.shards} shards, R={plan.R} rows/shard, "
          f"Hv={plan.Hv}, colors={plan.n_colors} "
          f"({time.time() - t0:.1f}s host-side)")

    # lower + compile the full run (fixed superstep count)
    t0 = time.time()
    out = eng.run(num_supersteps=args.supersteps)
    dt = time.time() - t0
    print(f"compiled AND executed {args.supersteps} supersteps on "
          f"{args.shards} host devices in {dt:.1f}s "
          f"({out['n_updates']} updates)")
    total = float(out["globals"]["total_rank"])
    print(f"sync total_rank = {total:.2f} (N + converging mass)")
    print("pod-scale graph-engine dry-run: OK")


if __name__ == "__main__":
    main()
