"""Connected components by min-label propagation (int32, exact).

Vertex data: {"label": int32}, initialized to the vertex id (or any
injected labels).  The update takes the minimum over the scope —
``min(own, min over neighbor labels)`` — and reschedules neighbors on
change: chaotic iteration over a confluent semilattice, so *any*
execution order converges to the same fixed point (the per-component
minimum).  That uniqueness is what makes this the serving subsystem's
equivalence workload (DESIGN.md §13): integer min has no floating
rounding, so incremental dirty-scope recompute vs a from-scratch
rebuild can be gated **bitwise**, on any scheduler.

No aggregator is declared on purpose: the kernel fast path is a float32
weighted sum, and labels must stay int32 end to end.  The dense scope
path runs the reduction exactly.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.coloring import greedy_coloring
from repro.core.graph import DataGraph
from repro.core.update import (Consistency, ScopeBatch, UpdateFn,
                               UpdateResult)

_INT32_MAX = np.iinfo(np.int32).max


def make_update() -> UpdateFn:
    def fn(scope: ScopeBatch) -> UpdateResult:
        nbr = jnp.where(scope.nbr_mask, scope.nbr_data["label"], _INT32_MAX)
        new = jnp.minimum(scope.v_data["label"], nbr.min(axis=1))
        changed = new < scope.v_data["label"]
        return UpdateResult(
            v_data={"label": new},
            resched_nbrs=changed[:, None] & scope.nbr_mask,
        )

    return UpdateFn(fn, Consistency.EDGE, name="cc")


def make_graph(edges: np.ndarray, n_vertices: int, *,
               labels: np.ndarray | None = None, max_deg: int | None = None,
               slack: int = 0, edge_capacity: int | None = None) -> DataGraph:
    if labels is None:
        labels = np.arange(n_vertices, dtype=np.int32)
    g = DataGraph.from_edges(
        n_vertices, edges,
        vertex_data={"label": np.asarray(labels, np.int32)},
        max_deg=max_deg, slack=slack, edge_capacity=edge_capacity)
    return g.with_colors(greedy_coloring(n_vertices, edges))


def build(edges: np.ndarray, n_vertices: int, *,
          labels: np.ndarray | None = None, max_deg: int | None = None,
          slack: int = 0, edge_capacity: int | None = None):
    """Uniform facade triple ``(graph, update, syncs)``; no syncs —
    termination is the task set draining at the fixed point."""
    graph = make_graph(edges, n_vertices, labels=labels, max_deg=max_deg,
                       slack=slack, edge_capacity=edge_capacity)
    return graph, make_update(), ()


def reference_components(edges: np.ndarray, n_vertices: int,
                         labels: np.ndarray | None = None) -> np.ndarray:
    """Union-find oracle: each vertex's fixed-point label = the minimum
    injected label over its connected component."""
    parent = np.arange(n_vertices)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in np.asarray(edges, int):
        parent[find(u)] = find(v)
    if labels is None:
        labels = np.arange(n_vertices, dtype=np.int32)
    labels = np.asarray(labels, np.int64)
    best: dict[int, int] = {}
    for v in range(n_vertices):
        r = find(v)
        best[r] = min(best.get(r, _INT32_MAX), int(labels[v]))
    return np.asarray([best[find(v)] for v in range(n_vertices)], np.int32)
