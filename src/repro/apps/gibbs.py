"""Gibbs sampling on a Markov Random Field (paper §5.4).

"Strict sequential consistency is necessary to preserve statistical
properties [22]" — the chromatic engine *is* the parallel colored Gibbs
sampler of Gonzalez et al. [22]: same-colored variables are conditionally
independent given the rest, so sampling a color phase in parallel equals
some sequential scan.

Ising/Potts pairwise MRF.  Vertex data: current spin, a per-vertex PRNG
key (split every update — stateless update functions force the RNG state
into the data graph, which is exactly where GraphLab wants algorithm
state), and sufficient statistics for marginal estimates.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coloring import greedy_coloring
from repro.core.graph import DataGraph
from repro.core.update import Consistency, ScopeBatch, UpdateFn, UpdateResult


def make_update(beta: float, field: float = 0.0, burn_in: int = 0) -> UpdateFn:
    """Ising Gibbs sweep; spins in {0,1}, energy -beta * s_u s_v (±1)."""
    def update(scope: ScopeBatch) -> UpdateResult:
        key = scope.v_data["key"]                    # [B, 2] uint32
        nbr_spin = scope.nbr_data["spin"]            # [B, D] int32
        pm = jnp.where(scope.nbr_mask, 2.0 * nbr_spin - 1.0, 0.0)
        local = 2.0 * (beta * pm.sum(axis=1) + field)
        p_up = jax.nn.sigmoid(local)
        def draw(k, p):
            k1, k2 = jax.random.split(jax.random.wrap_key_data(k))
            u = jax.random.uniform(k2)
            return jax.random.key_data(k1), (u < p).astype(jnp.int32)
        new_key, spin = jax.vmap(draw)(key, p_up)
        sweep = scope.v_data["sweep"] + 1
        collect = (sweep > burn_in).astype(jnp.float32)
        return UpdateResult(
            v_data={
                "spin": spin,
                "key": new_key,
                "sweep": sweep,
                "ones": scope.v_data["ones"] + collect * spin,
                "n": scope.v_data["n"] + collect,
            },
            resched_self=jnp.ones(spin.shape, bool),  # keep sweeping
        )
    return UpdateFn(update, Consistency.EDGE, name="gibbs")


@dataclasses.dataclass
class IsingProblem:
    graph: DataGraph
    beta: float
    field: float
    edges: np.ndarray


def ising_problem(edges: np.ndarray, n_vertices: int, beta: float,
                  field: float = 0.0, seed: int = 0) -> IsingProblem:
    rng = np.random.default_rng(seed)
    keys = jax.vmap(lambda s: jax.random.key_data(jax.random.PRNGKey(s)))(
        jnp.arange(seed * 1000003, seed * 1000003 + n_vertices))
    g = DataGraph.from_edges(
        n_vertices, edges,
        vertex_data={
            "spin": rng.integers(0, 2, n_vertices).astype(np.int32),
            "key": np.asarray(keys),
            "sweep": np.zeros(n_vertices, np.int32),
            "ones": np.zeros(n_vertices, np.float32),
            "n": np.zeros(n_vertices, np.float32),
        })
    g = g.with_colors(greedy_coloring(n_vertices, edges))
    return IsingProblem(g, beta, field, np.asarray(edges))


def build(problem: IsingProblem, *, burn_in: int = 0):
    """Uniform facade triple ``(graph, update, syncs)`` for a problem
    from ``ising_problem`` (no syncs: marginal statistics live on the
    vertices themselves)."""
    return (problem.graph,
            make_update(problem.beta, field=problem.field, burn_in=burn_in),
            ())


def marginals(vertex_data) -> np.ndarray:
    ones = np.asarray(vertex_data["ones"])
    n = np.maximum(np.asarray(vertex_data["n"]), 1.0)
    return ones / n


def exact_marginals(edges: np.ndarray, n_vertices: int, beta: float,
                    field: float = 0.0) -> np.ndarray:
    """Brute-force enumeration oracle (tiny graphs only)."""
    assert n_vertices <= 16
    states = np.arange(2 ** n_vertices)
    bits = ((states[:, None] >> np.arange(n_vertices)) & 1)  # [S, Nv]
    pm = 2.0 * bits - 1.0
    energy = field * pm.sum(axis=1)
    for u, v in edges:
        energy = energy + beta * pm[:, u] * pm[:, v]
    w = np.exp(energy - energy.max())
    w = w / w.sum()
    return (w[:, None] * bits).sum(axis=0)
