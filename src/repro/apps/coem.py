"""CoEM for Named Entity Recognition (paper §5.3).

Bipartite data graph: noun-phrase vertices on the left, context vertices
on the right; an edge where the noun-phrase occurs in the context, with
the co-occurrence count as edge data.  Vertex data is the estimated
distribution over entity types.  The update "computes a weighted sum of
probability tables stored on adjacent vertices and then normalizes";
seed noun-phrases keep their labels fixed.  Two-colored bipartite graph
-> chromatic engine; the paper uses it (with random partitioning) as the
network-stress workload.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.coloring import bipartite_coloring
from repro.core.graph import DataGraph, bipartite_edges
from repro.core.sync import SyncOp
from repro.core.update import (Consistency, ScopeBatch, UpdateFn,
                               UpdateResult, aggregator_update,
                               slot_fold_sum)


def make_update(eps: float = 1e-3) -> UpdateFn:
    """CoEM update as a NeighborAggregator: the weighted probability-table
    mix runs through the ``ell_spmv`` Pallas kernel (DESIGN.md §4); the
    normalization / seed clamping happens in ``combine``."""

    def feature(vertex_data):
        return vertex_data["p"]                      # [..., T]

    def weight(scope: ScopeBatch):
        return scope.edge_data["count"]              # [B, D]

    def combine(scope: ScopeBatch, mix) -> UpdateResult:
        w = jnp.where(scope.nbr_mask, scope.edge_data["count"],
                      0.0).astype(jnp.float32)
        denom = jnp.maximum(slot_fold_sum(w), 1e-9)[:, None]
        new_p = mix / denom
        new_p = new_p / jnp.maximum(new_p.sum(-1, keepdims=True), 1e-9)
        # seeds are clamped to their prior label
        seed = scope.v_data["is_seed"][:, None] > 0
        new_p = jnp.where(seed, scope.v_data["p"], new_p)
        delta = jnp.abs(new_p - scope.v_data["p"]).sum(axis=1)
        changed = delta > eps
        return UpdateResult(
            v_data={"p": new_p, "is_seed": scope.v_data["is_seed"]},
            resched_nbrs=jnp.broadcast_to(changed[:, None], scope.nbr_mask.shape),
            priority=delta,
        )

    return aggregator_update(feature, weight, combine, Consistency.EDGE,
                             name="coem")


def entropy_sync(tau: int = 1) -> SyncOp:
    """Global mean label entropy — a convergence estimator sync."""
    def fold(acc, row):
        p = jnp.clip(row["p"], 1e-9, 1.0)
        h = -(p * jnp.log(p)).sum()
        return (acc[0] + h, acc[1] + 1.0)
    return SyncOp(
        key="entropy", fold=fold,
        merge=lambda a, b: (a[0] + b[0], a[1] + b[1]),
        finalize=lambda acc: acc[0] / jnp.maximum(acc[1], 1.0),
        acc0=(jnp.float32(0.0), jnp.float32(0.0)), tau=tau)


@dataclasses.dataclass
class CoEMProblem:
    graph: DataGraph
    n_phrases: int
    n_contexts: int
    n_types: int
    true_types: np.ndarray


def synthetic_ner(n_phrases: int, n_contexts: int, n_types: int,
                  mean_deg: int = 6, seed_frac: float = 0.05,
                  seed: int = 0) -> CoEMProblem:
    """Planted-types corpus: each phrase/context has a latent type; edges
    prefer same-type pairs, so CoEM can propagate seed labels."""
    rng = np.random.default_rng(seed)
    pt = rng.integers(0, n_types, n_phrases)
    ct = rng.integers(0, n_types, n_contexts)
    pairs = []
    counts = []
    for i in range(n_phrases):
        k = max(1, rng.poisson(mean_deg))
        same = np.nonzero(ct == pt[i])[0]
        for _ in range(k):
            if len(same) and rng.random() < 0.85:
                j = int(rng.choice(same))
            else:
                j = int(rng.integers(0, n_contexts))
            pairs.append((i, j))
            counts.append(float(rng.integers(1, 5)))
    pairs = np.asarray(pairs, dtype=np.int64)
    # dedupe
    _, keep = np.unique(pairs[:, 0] * n_contexts + pairs[:, 1],
                        return_index=True)
    pairs, counts = pairs[keep], np.asarray(counts, np.float32)[keep]
    nv, edges = bipartite_edges(n_phrases, n_contexts, pairs)
    p0 = np.full((nv, n_types), 1.0 / n_types, np.float32)
    is_seed = np.zeros(nv, np.float32)
    n_seed = max(n_types, int(seed_frac * n_phrases))
    seeds = rng.choice(n_phrases, size=n_seed, replace=False)
    for s in seeds:
        p0[s] = 0.0
        p0[s, pt[s]] = 1.0
        is_seed[s] = 1.0
    g = DataGraph.from_edges(
        nv, edges,
        vertex_data={"p": p0, "is_seed": is_seed},
        edge_data={"count": counts})
    g = g.with_colors(bipartite_coloring(n_phrases, nv))
    return CoEMProblem(g, n_phrases, n_contexts, n_types,
                       np.concatenate([pt, ct]))


def build(problem: CoEMProblem, *, eps: float = 1e-3, tau: int = 1):
    """Uniform facade triple ``(graph, update, syncs)`` for a problem
    from ``synthetic_ner``."""
    return problem.graph, make_update(eps), (entropy_sync(tau),)


def label_accuracy(problem: CoEMProblem, vertex_data) -> float:
    p = np.asarray(vertex_data["p"])
    pred = p.argmax(axis=1)
    return float((pred == problem.true_types).mean())
