"""Bayesian Probabilistic Tensor Factorization (paper §5.4).

"The tensor R is decomposed into three matrices R ~ V (x) U (x) T which
can be represented in GraphLab as a tripartite graph."  Ratings carry a
time index; vertices are users, movies, and time factors; each rating
edge connects user<->movie (with its time id as edge data), and the time
vertices chain to their neighbors (temporal smoothing), exactly the BPTF
structure.  We implement the MAP/ALS variant of BPTF (the paper's MCMC
wrapper samples around the same conditional solves; the conditional
least-squares update below is its mode).

Tripartite coloring: users / movies+times is NOT 2-colorable as built
(movie-time edges), so the greedy coloring runs — typically 3 colors,
which is the point: the chromatic engine handles arbitrary data graphs,
not just bipartite ones.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.coloring import greedy_coloring
from repro.core.graph import DataGraph
from repro.core.sync import SyncOp
from repro.core.update import Consistency, ScopeBatch, UpdateFn, UpdateResult


def make_update(d: int, lam: float = 0.05, eps: float = 1e-3) -> UpdateFn:
    """Vertex kinds: 0=user, 1=movie, 2=time.  For a rating (u, m, t):
    r ~ <w_u * w_m, w_t> (elementwise triple product).  The conditional
    LS solve for one factor treats the elementwise product of the other
    two as the design row.  Ratings live on u<->m edges; the time factor
    for each edge is looked up via a *global* time table maintained by a
    sync (time vertices update from their incident edges).
    """
    def update(scope: ScopeBatch) -> UpdateResult:
        kind = scope.v_data["kind"]                    # [B]
        w = scope.v_data["w"]                          # [B, d]
        nbr_w = scope.nbr_data["w"]                    # [B, D, d]
        nbr_kind = scope.nbr_data["kind"]              # [B, D]
        r = scope.edge_data["rating"]                  # [B, D]
        tid = scope.edge_data["time"].astype(jnp.int32)  # [B, D]
        time_table = scope.globals["time_factors"]     # [T, d]
        m = scope.nbr_mask.astype(w.dtype)

        # design rows: for user/movie vertices the row is nbr_w * w_time;
        # for time vertices it is w_user*w_movie -- but a time vertex's
        # neighbors in this graph are other time vertices (smoothing), so
        # its data term comes through the sync'd residual aggregation and
        # its update here is smoothing toward neighbors.
        wt = time_table[tid]                           # [B, D, d]
        X = nbr_w * wt                                 # [B, D, d]
        Xm = X * m[..., None]
        A = jnp.einsum("bdi,bdj->bij", Xm, Xm)
        n_obs = m.sum(axis=1)
        A = A + (lam * jnp.maximum(n_obs, 1.0))[:, None, None] \
            * jnp.eye(X.shape[-1], dtype=w.dtype)
        b = jnp.einsum("bdi,bd->bi", Xm, r * m)
        w_ls = jnp.linalg.solve(A, b[..., None])[..., 0]
        # time vertices: smooth toward neighboring time factors
        nbr_time = jnp.where((nbr_kind == 2)[..., None], nbr_w, 0.0)
        n_time = jnp.maximum(
            (scope.nbr_mask & (nbr_kind == 2)).sum(axis=1), 1)
        w_smooth = (w + nbr_time.sum(axis=1)) / (1.0 + n_time)[:, None]
        new_w = jnp.where((kind == 2)[:, None], w_smooth,
                          jnp.where(n_obs[:, None] > 0, w_ls, w))
        delta = jnp.abs(new_w - w).max(axis=1)
        return UpdateResult(
            v_data={"w": new_w, "kind": kind, "tslot": scope.v_data["tslot"]},
            resched_nbrs=jnp.broadcast_to((delta > eps)[:, None],
                                          scope.nbr_mask.shape),
            priority=delta,
        )
    return UpdateFn(update, Consistency.EDGE, name="bptf")


def time_table_sync(n_times: int, d: int, tau: int = 1) -> SyncOp:
    """Maintain the global [T, d] time-factor table from time vertices —
    the BPTF analogue of the paper's parameter sync."""
    def fold(acc, row):
        tab, cnt = acc
        is_time = row["kind"] == 2
        slot = jnp.clip(row["tslot"].astype(jnp.int32), 0, n_times - 1)
        tab = tab.at[slot].add(jnp.where(is_time, row["w"], 0.0))
        cnt = cnt.at[slot].add(jnp.where(is_time, 1.0, 0.0))
        return (tab, cnt)
    return SyncOp(
        key="time_factors", fold=fold,
        merge=lambda a, b: (a[0] + b[0], a[1] + b[1]),
        finalize=lambda acc: acc[0] / jnp.maximum(acc[1], 1.0)[:, None],
        acc0=(jnp.zeros((n_times, d), jnp.float32),
              jnp.zeros((n_times,), jnp.float32)),
        tau=tau)


@dataclasses.dataclass
class BPTFProblem:
    graph: DataGraph
    n_users: int
    n_movies: int
    n_times: int
    d: int
    ratings: np.ndarray
    triples: np.ndarray     # [Ne, 3] (user, movie, time)
    noise: float


def synthetic_bptf(n_users: int, n_movies: int, n_times: int, d: int,
                   density: float, noise: float = 0.05,
                   seed: int = 0) -> BPTFProblem:
    rng = np.random.default_rng(seed)
    U = rng.normal(size=(n_users, d)) / d ** 0.5
    V = rng.normal(size=(n_movies, d)) / d ** 0.5
    T = 1.0 + 0.1 * rng.normal(size=(n_times, d))
    mask = rng.random((n_users, n_movies)) < density
    ui, mi = np.nonzero(mask)
    ti = rng.integers(0, n_times, len(ui))
    ratings = (np.einsum("ed,ed->e", U[ui] * T[ti], V[mi])
               + noise * rng.normal(size=len(ui))).astype(np.float32)
    nu, nm, nt = n_users, n_movies, n_times
    edges = [(u, nu + m) for u, m in zip(ui, mi)]
    edata_r = list(ratings)
    edata_t = list(ti.astype(np.float32))
    # time chain for smoothing
    for t in range(nt - 1):
        edges.append((nu + nm + t, nu + nm + t + 1))
        edata_r.append(0.0)
        edata_t.append(0.0)
    nv = nu + nm + nt
    kind = np.zeros(nv, np.float32)
    kind[nu:nu + nm] = 1
    kind[nu + nm:] = 2
    tslot = np.zeros(nv, np.float32)
    tslot[nu + nm:] = np.arange(nt)
    w0 = rng.normal(size=(nv, d)).astype(np.float32) * 0.1
    w0[nu + nm:] = 1.0   # time factors start at 1 (multiplicative)
    g = DataGraph.from_edges(
        nv, np.asarray(edges, np.int64),
        vertex_data={"w": w0, "kind": kind, "tslot": tslot},
        edge_data={"rating": np.asarray(edata_r, np.float32),
                   "time": np.asarray(edata_t, np.float32)})
    g = g.with_colors(greedy_coloring(nv, np.asarray(edges)))
    return BPTFProblem(g, nu, nm, nt, d, ratings,
                       np.stack([ui, mi, ti], 1), noise)


def build(problem: BPTFProblem, *, lam: float = 0.05, eps: float = 1e-3,
          tau: int = 1):
    """Uniform facade triple ``(graph, update, syncs)`` for a problem
    from ``synthetic_bptf`` (the time-table sync is load-bearing: the
    update reads the global time factors from ``scope.globals``)."""
    return (problem.graph, make_update(problem.d, lam=lam, eps=eps),
            (time_table_sync(problem.n_times, problem.d, tau),))


def dataset_rmse(problem: BPTFProblem, vertex_data, globals_) -> float:
    w = np.asarray(vertex_data["w"])
    tt = np.asarray(globals_["time_factors"])
    u = w[problem.triples[:, 0]]
    v = w[problem.triples[:, 1] + problem.n_users]
    t = tt[problem.triples[:, 2]]
    pred = np.einsum("ed,ed->e", u * t, v)
    return float(np.sqrt(np.mean((pred - problem.ratings) ** 2)))
