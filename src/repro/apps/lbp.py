"""Loopy Belief Propagation + GMM co-segmentation (paper §5.2, CoSeg).

3-D grid data graph (frames x height x width of super-pixels).  Vertex
data: super-pixel feature statistics (the color/texture stub), unary
log-potentials, current belief.  Edge data: the two directed messages of
sum-product BP in log domain (``msg01``: endpoint0 -> endpoint1, ``msg10``
reverse) — exactly the paper's directed edge data.

The update executes the residual-BP local iteration [27]: recompute
outgoing messages from the cavity belief under a Potts smoothness
potential, reschedule a neighbor when its incoming message moved by more
than ``eps``, with the residual as the task priority — the adaptive
prioritized schedule that requires the locking engine in the paper (here:
the PriorityEngine).  The GMM parameters are maintained by a **sync**: the
centroid M-step folds soft label assignments over all vertices, and the
update reads the fresh centroids from ``scope.globals`` to rebuild its
unary potentials — the paper's "CoSeg alternates between LBP ... and
updating the GMM" loop.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coloring import greedy_coloring
from repro.core.graph import DataGraph, grid_edges_3d
from repro.core.sync import SyncOp
from repro.core.update import Consistency, ScopeBatch, UpdateFn, UpdateResult


def make_update(n_labels: int, beta: float = 1.0, gamma: float = 2.0,
                eps: float = 1e-2, use_gmm_sync: bool = True) -> UpdateFn:
    log_psi = -beta * (1.0 - jnp.eye(n_labels))      # Potts potential

    def update(scope: ScopeBatch) -> UpdateResult:
        feat = scope.v_data["feat"]                  # [B, F]
        if use_gmm_sync and "gmm" in scope.globals:
            mu = scope.globals["gmm"]                # [K, F]
            unary = -gamma * ((feat[:, None, :] - mu[None]) ** 2).sum(-1)
        else:
            unary = scope.v_data["unary"]            # [B, K]
        msg01 = scope.edge_data["msg01"]             # [B, D, K]
        msg10 = scope.edge_data["msg10"]
        inc = jnp.where(scope.is_src[..., None], msg10, msg01)   # into v
        old_out = jnp.where(scope.is_src[..., None], msg01, msg10)
        inc = jnp.where(scope.nbr_mask[..., None], inc, 0.0)
        belief = unary + inc.sum(axis=1)                         # [B, K]
        cavity = belief[:, None, :] - inc                        # [B, D, K]
        # m_vu(x_u) = logsumexp_xv cavity(x_v) + log_psi(x_v, x_u)
        new_out = jax.nn.logsumexp(
            cavity[..., :, None] + log_psi[None, None], axis=2)  # [B, D, K]
        new_out = new_out - jax.nn.logsumexp(new_out, axis=-1, keepdims=True)
        residual = jnp.where(
            scope.nbr_mask, jnp.abs(new_out - old_out).max(-1), 0.0)
        out01 = jnp.where(scope.is_src[..., None], new_out, msg01)
        out10 = jnp.where(scope.is_src[..., None], msg10, new_out)
        belief = belief - jax.nn.logsumexp(belief, -1, keepdims=True)
        return UpdateResult(
            v_data={"feat": feat, "unary": unary, "belief": belief},
            edge_data={"msg01": out01, "msg10": out10},
            resched_nbrs=residual > eps,
            priority=residual.max(axis=1),
        )
    return UpdateFn(update, Consistency.EDGE, name="lbp")


def gmm_sync(n_labels: int, n_feat: int, tau: int = 1) -> SyncOp:
    """Soft k-means M-step over beliefs — the GMM parameter sync."""
    def fold(acc, row):
        p = jax.nn.softmax(row["belief"])            # [K]
        return (acc[0] + p[:, None] * row["feat"][None, :], acc[1] + p)
    return SyncOp(
        key="gmm", fold=fold,
        merge=lambda a, b: (a[0] + b[0], a[1] + b[1]),
        finalize=lambda acc: acc[0] / jnp.maximum(acc[1], 1e-6)[:, None],
        acc0=(jnp.zeros((n_labels, n_feat), jnp.float32),
              jnp.zeros((n_labels,), jnp.float32)),
        tau=tau)


@dataclasses.dataclass
class CoSegProblem:
    graph: DataGraph
    shape: tuple
    n_labels: int
    true_labels: np.ndarray
    centroids: np.ndarray


def synthetic_coseg(n_frames: int, h: int, w: int, n_labels: int = 4,
                    n_feat: int = 3, noise: float = 0.4, seed: int = 0,
                    use_gmm_sync: bool = True) -> CoSegProblem:
    """Planted smooth labeling on a 3-D grid with noisy features."""
    rng = np.random.default_rng(seed)
    nv, edges = grid_edges_3d(n_frames, h, w)
    # planted labels: vertical bands drifting across frames
    labels = np.zeros((n_frames, h, w), dtype=np.int64)
    for f in range(n_frames):
        shift = f % max(w // n_labels, 1)
        for y in range(h):
            for x in range(w):
                labels[f, y, x] = ((x + shift) * n_labels) // w % n_labels
    labels = labels.reshape(-1)
    centroids = rng.normal(size=(n_labels, n_feat)).astype(np.float32) * 2.0
    feat = (centroids[labels]
            + noise * rng.normal(size=(nv, n_feat))).astype(np.float32)
    gamma = 2.0
    unary = -gamma * ((feat[:, None, :] - centroids[None]) ** 2).sum(-1)
    g = DataGraph.from_edges(
        nv, edges,
        vertex_data={
            "feat": feat,
            "unary": unary.astype(np.float32),
            "belief": unary.astype(np.float32),
        },
        edge_data={
            "msg01": np.zeros((len(edges), n_labels), np.float32),
            "msg10": np.zeros((len(edges), n_labels), np.float32),
        })
    g = g.with_colors(greedy_coloring(nv, edges))
    return CoSegProblem(g, (n_frames, h, w), n_labels, labels, centroids)


def label_accuracy(problem: CoSegProblem, vertex_data) -> float:
    """Best-permutation-free accuracy (centroids keep label identity)."""
    pred = np.asarray(vertex_data["belief"]).argmax(axis=1)
    return float((pred == problem.true_labels).mean())


def build(problem: CoSegProblem, *, beta: float = 1.0, gamma: float = 2.0,
          eps: float = 1e-2, use_gmm_sync: bool = True, tau: int = 1):
    """Uniform facade triple ``(graph, update, syncs)`` for a problem
    from ``synthetic_coseg``."""
    upd = make_update(problem.n_labels, beta=beta, gamma=gamma, eps=eps,
                      use_gmm_sync=use_gmm_sync)
    n_feat = problem.graph.vertex_data["feat"].shape[1]
    syncs = ((gmm_sync(problem.n_labels, n_feat, tau),)
             if use_gmm_sync else ())
    return problem.graph, upd, syncs


def residual_locking_engine(problem: CoSegProblem, eps: float = 1e-2,
                            max_pending: int = 64,
                            max_supersteps: int = 20000,
                            use_gmm_sync: bool = True):
    """CoSeg under the locking engine: residual-BP priorities feed the
    pending window — the paper's §5.2 adaptive prioritized schedule,
    which is exactly the workload that *requires* the locking engine
    (the 3-D grid is colorable, but the priority order isn't a color
    order).  ``max_pending`` is the lock-pipeline depth of Fig. 8(b)."""
    from repro import api
    graph, upd, syncs = build(problem, eps=eps, use_gmm_sync=use_gmm_sync)
    return api.build_engine(graph, upd, syncs=syncs, scheduler="locking",
                            max_pending=max_pending,
                            max_supersteps=max_supersteps)


def distributed_locking_engine(problem: CoSegProblem, n_shards: int,
                               max_pending: int = 64,
                               max_supersteps: int = 20000,
                               eps: float = 1e-2,
                               worst_case: bool = False):
    """CoSeg on ``n_shards`` with the distributed locking engine: frame
    partition (or the paper's striped worst case), cut-edge message
    replicas exchanged through the versioned edge sync."""
    from repro import api
    asg_fn = striped_partition if worst_case else frame_partition
    upd = make_update(problem.n_labels, eps=eps, use_gmm_sync=False)
    return api.build_engine(
        problem.graph, upd, scheduler="locking", n_shards=n_shards,
        partition=asg_fn(problem, n_shards), max_pending=max_pending,
        max_supersteps=max_supersteps, exchange_edges=True)


def frame_partition(problem: CoSegProblem, n_machines: int) -> np.ndarray:
    """The paper's natural partitioning: slice across frames (§5.2)."""
    f, h, w = problem.shape
    frames = np.arange(f * h * w) // (h * w)
    return (frames * n_machines) // f


def striped_partition(problem: CoSegProblem, n_machines: int) -> np.ndarray:
    """The paper's worst-case partition: frames striped across machines
    (Fig. 8b) — every scope acquisition crosses shards."""
    f, h, w = problem.shape
    frames = np.arange(f * h * w) // (h * w)
    return frames % n_machines
