"""Paper §5 applications expressed as GraphLab update functions."""
from repro.apps import pagerank, als, coem, lbp, gibbs
