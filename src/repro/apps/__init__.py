"""Paper §5 applications expressed as GraphLab update functions.

Every app module exposes the same three-part surface:

* ``make_update(...) -> UpdateFn`` — the paper's update function;
* a graph/problem builder (``make_graph`` for PageRank, a
  ``synthetic_*`` problem generator elsewhere) plus its sync ops;
* ``build(...) -> (graph, update, syncs)`` — the uniform triple the
  ``repro.api`` facade consumes directly:

      from repro import api
      from repro.apps import pagerank

      graph, update, syncs = pagerank.build(edges, n)
      result = api.run(graph, update, syncs=syncs, scheduler="chromatic")

Apps never import engine classes: engine selection is the facade's job
(``scheduler="chromatic" | "priority" | "bsp" | "locking" |
"sequential"``, DESIGN.md §9).
"""
from repro.apps import als, bptf, cc, coem, gibbs, lbp, pagerank

#: name -> uniform ``build(...) -> (graph, update, syncs)`` helper
BUILDERS = {
    "pagerank": pagerank.build,
    "als": als.build,
    "cc": cc.build,
    "coem": coem.build,
    "lbp": lbp.build,
    "gibbs": gibbs.build,
    "bptf": bptf.build,
}

__all__ = ["als", "bptf", "cc", "coem", "gibbs", "lbp", "pagerank",
           "BUILDERS"]
