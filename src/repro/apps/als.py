"""Alternating Least Squares collaborative filtering (paper §5.1, Netflix).

Bipartite data graph: users [0, n_users) and movies [n_users, n_users +
n_movies); an edge per observed rating.  Vertex data holds the latent
factor row (U row / V column, dim d) plus the locally-accumulated squared
prediction error that the RMSE sync aggregates ("a sync operation is used
to compute the prediction error during the run").  The update recomputes
the regularized least-squares solution from neighbor factors — the paper's
O(d^3 + deg) update — and reschedules neighbors when its factor moved more
than ``eps`` (adaptive ALS).  The bipartite graph is "naturally two
colored" -> chromatic engine with 2 colors.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coloring import bipartite_coloring
from repro.core.graph import DataGraph, bipartite_edges
from repro.core.sync import SyncOp
from repro.core.update import Consistency, ScopeBatch, UpdateFn, UpdateResult


def make_update(d: int, lam: float = 0.05, eps: float = 1e-3) -> UpdateFn:
    def update(scope: ScopeBatch) -> UpdateResult:
        X = scope.nbr_data["w"]                      # [B, D, d]
        r = scope.edge_data["rating"]                # [B, D]
        m = scope.nbr_mask.astype(X.dtype)           # [B, D]
        Xm = X * m[..., None]
        # normal equations: (X^T X + lam*n*I) w = X^T r
        A = jnp.einsum("bdi,bdj->bij", Xm, Xm)
        n_obs = m.sum(axis=1)
        A = A + (lam * jnp.maximum(n_obs, 1.0))[:, None, None] * jnp.eye(d, dtype=X.dtype)
        b = jnp.einsum("bdi,bd->bi", Xm, r * m)
        w_new = jnp.linalg.solve(A, b[..., None])[..., 0]
        # isolated vertices keep their factor
        w_new = jnp.where(n_obs[:, None] > 0, w_new, scope.v_data["w"])
        # local residual (for the RMSE sync); counted on movie side only
        pred = jnp.einsum("bi,bdi->bd", w_new, X)
        se = (((pred - r) * m) ** 2).sum(axis=1)
        is_right = scope.v_data["is_movie"]
        delta = jnp.abs(w_new - scope.v_data["w"]).max(axis=1)
        changed = delta > eps
        return UpdateResult(
            v_data={
                "w": w_new,
                "err": jnp.where(is_right > 0, se, 0.0),
                "cnt": jnp.where(is_right > 0, n_obs, 0.0),
                "is_movie": is_right,
            },
            resched_nbrs=jnp.broadcast_to(changed[:, None], scope.nbr_mask.shape),
            priority=delta,
        )
    return UpdateFn(update, Consistency.EDGE, name="als")


def rmse_sync(tau: int = 1) -> SyncOp:
    """Global RMSE over observed ratings, from per-movie residuals."""
    return SyncOp(
        key="rmse",
        fold=lambda acc, row: (acc[0] + row["err"], acc[1] + row["cnt"]),
        merge=lambda a, b: (a[0] + b[0], a[1] + b[1]),
        finalize=lambda acc: jnp.sqrt(acc[0] / jnp.maximum(acc[1], 1.0)),
        acc0=(jnp.float32(0.0), jnp.float32(0.0)),
        tau=tau,
    )


@dataclasses.dataclass
class ALSProblem:
    graph: DataGraph
    n_users: int
    n_movies: int
    d: int
    ratings: np.ndarray     # [Ne]
    pairs: np.ndarray       # [Ne, 2] (user, movie) indices
    noise: float


def synthetic_netflix(n_users: int, n_movies: int, d: int, density: float,
                      noise: float = 0.1, seed: int = 0,
                      d_model: int | None = None,
                      slack: int = 0) -> ALSProblem:
    """Low-rank ground-truth ratings r = <u, v> + noise.

    ``d_model`` is the factor dimension used by the solver (defaults to the
    generative d) — the paper's Fig. 5(a)/6(c) sweeps this.  ``slack=``
    reserves mutable-storage headroom for online serving (new ratings
    arriving through ``api.serve``, DESIGN.md §13).
    """
    rng = np.random.default_rng(seed)
    d_model = d_model or d
    U = rng.normal(size=(n_users, d)) / np.sqrt(d)
    V = rng.normal(size=(n_movies, d)) / np.sqrt(d)
    mask = rng.random((n_users, n_movies)) < density
    ui, mi = np.nonzero(mask)
    ratings = (np.einsum("ed,ed->e", U[ui], V[mi])
               + noise * rng.normal(size=len(ui))).astype(np.float32)
    pairs = np.stack([ui, mi], axis=1)
    nv, edges = bipartite_edges(n_users, n_movies, pairs)
    w0 = rng.normal(size=(nv, d_model)).astype(np.float32) * 0.1
    is_movie = np.zeros(nv, np.float32)
    is_movie[n_users:] = 1.0
    g = DataGraph.from_edges(
        nv, edges,
        vertex_data={
            "w": w0,
            "err": np.zeros(nv, np.float32),
            "cnt": np.zeros(nv, np.float32),
            "is_movie": is_movie,
        },
        edge_data={"rating": ratings},
        slack=slack,
    )
    g = g.with_colors(bipartite_coloring(n_users, nv))
    return ALSProblem(g, n_users, n_movies, d_model, ratings, pairs, noise)


def build(problem: ALSProblem, *, lam: float = 0.05, eps: float = 1e-3,
          tau: int = 1):
    """Uniform facade triple ``(graph, update, syncs)`` for a problem
    from ``synthetic_netflix`` (keep the problem around for
    ``dataset_rmse``)."""
    return (problem.graph, make_update(problem.d, lam=lam, eps=eps),
            (rmse_sync(tau),))


def dataset_rmse(problem: ALSProblem, vertex_data) -> float:
    """Exact test-style RMSE from factors (oracle for the sync op)."""
    w = np.asarray(vertex_data["w"])
    u = w[problem.pairs[:, 0]]
    v = w[problem.pairs[:, 1] + problem.n_users]
    pred = np.einsum("ed,ed->e", u, v)
    return float(np.sqrt(np.mean((pred - problem.ratings) ** 2)))
