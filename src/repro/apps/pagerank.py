"""PageRank (paper Ex. 3.1 / Alg. 1) — the running example.

Vertex data: {"rank": R(v)}.  Edge data: {"w": w_{u,v}} (directed weight
recovered via ``is_src``; for the symmetric benchmark graphs we store one
weight per undirected edge and normalize by out-degree on the fly).

The update function is the paper's Alg. 1: recompute the weighted sum of
neighbor ranks; if |old - new| > eps, reschedule the neighbors — the
adaptive dynamic scheduling the paper highlights.

The neighborhood reduction is declared as a ``NeighborAggregator``
(feature = rank, weight = edge weight), so the engines dispatch it
through the ``ell_spmv`` Pallas kernel instead of materializing dense
[B, D, F] scopes; the dense fallback is derived from the same triple and
is bit-identical (DESIGN.md §4).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.coloring import greedy_coloring
from repro.core.graph import DataGraph
from repro.core.sync import top_two_sync, sum_sync
from repro.core.update import (Consistency, ScopeBatch, UpdateFn,
                               UpdateResult, aggregator_update)

ALPHA = 0.15


def make_update(eps: float = 1e-4) -> UpdateFn:
    def feature(vertex_data):
        return vertex_data["rank"][..., None]          # [..., 1]

    def weight(scope: ScopeBatch):
        return scope.edge_data["w"]                    # [B, D]

    def combine(scope: ScopeBatch, y) -> UpdateResult:
        new_rank = ALPHA + (1.0 - ALPHA) * y[..., 0]   # Alg. 1
        delta = jnp.abs(new_rank - scope.v_data["rank"])
        changed = delta > eps
        return UpdateResult(
            v_data={"rank": new_rank},
            resched_nbrs=jnp.broadcast_to(changed[:, None], scope.nbr_mask.shape),
            priority=delta,
        )

    return aggregator_update(feature, weight, combine, Consistency.EDGE,
                             name="pagerank")


def make_graph(edges: np.ndarray, n_vertices: int, seed: int = 0,
               max_deg: int | None = None, hub_split: bool = False,
               w_cap: int | None = None,
               edge_locality: bool = False,
               slack: int = 0,
               edge_capacity: int | None = None) -> DataGraph:
    """Build a PageRank data graph with out-degree-normalized weights.

    ``slack=`` reserves mutable-storage headroom for online serving
    (``api.serve``, DESIGN.md §13); weights of edges incident to a
    mutated vertex are degree-dependent — recompute them with
    ``refreshed_weights`` after inserts.
    """
    rng = np.random.default_rng(seed)
    deg = np.zeros(n_vertices)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    deg = np.maximum(deg, 1)
    # symmetric normalized weight per undirected edge (random-walk style)
    w = np.asarray([1.0 / np.sqrt(deg[u] * deg[v]) for u, v in edges],
                   dtype=np.float32)
    g = DataGraph.from_edges(
        n_vertices, edges,
        vertex_data={"rank": np.ones(n_vertices, np.float32)},
        edge_data={"w": w},
        max_deg=max_deg,
        hub_split=hub_split,
        w_cap=w_cap,
        edge_locality=edge_locality,
        slack=slack,
        edge_capacity=edge_capacity,
    )
    return g.with_colors(greedy_coloring(n_vertices, edges))


def refreshed_weights(serving, vertices):
    """Recomputed ``1/sqrt(deg_u * deg_v)`` for every edge incident to
    ``vertices`` — the app-level half of a dynamic-graph insert: an
    edge arrival changes its endpoints' degrees, which this app's edge
    weights depend on, so the incident weights are pushed back through
    ``ServingEngine.update_edge_data`` (whose dirty tracking then seeds
    the affected scopes).  Returns ``(edge_input_ids, {"w": values})``.
    """
    deg = serving.degrees()
    eids, ws = [], []
    seen: set[int] = set()
    for v in vertices:
        nbrs, edge_ids = serving.neighbors(v)
        for nbr, eid in zip(nbrs, edge_ids):
            if eid not in seen:
                seen.add(eid)
                eids.append(int(eid))
                ws.append(1.0 / np.sqrt(deg[v] * deg[nbr]))
    return (np.asarray(eids, np.int64),
            {"w": np.asarray(ws, np.float32)})


def build(edges: np.ndarray, n_vertices: int, *, eps: float = 1e-4,
          seed: int = 0, max_deg: int | None = None, tau: int = 1,
          hub_split: bool = False, w_cap: int | None = None,
          edge_locality: bool = False, slack: int = 0,
          edge_capacity: int | None = None):
    """Uniform facade triple: ``(graph, update, syncs)``.

    The syncs are the paper's §3.3 examples (second most popular page +
    total rank); feed the triple straight to ``repro.api.run``.
    ``hub_split=True`` (or an explicit ``w_cap=``) stores the graph with
    rows wider than ``w_cap`` decomposed into virtual rows; illegal
    ``w_cap`` values raise ``ValueError`` from ``DataGraph.from_edges``.
    """
    graph = make_graph(edges, n_vertices, seed=seed, max_deg=max_deg,
                       hub_split=hub_split, w_cap=w_cap,
                       edge_locality=edge_locality, slack=slack,
                       edge_capacity=edge_capacity)
    syncs = (second_most_popular_sync(tau), total_rank_sync(tau))
    return graph, make_update(eps), syncs


def second_most_popular_sync(tau: int = 1):
    """The paper's §3.3 example sync: second most popular page."""
    return top_two_sync("top2", rank_fn=lambda row: row["rank"], tau=tau)


def total_rank_sync(tau: int = 1):
    return sum_sync("total_rank", lambda row: row["rank"], tau=tau)


def reference_pagerank(edges: np.ndarray, n_vertices: int,
                       n_iters: int = 200) -> np.ndarray:
    """Dense NumPy fixed-point oracle for tests (same weights)."""
    deg = np.zeros(n_vertices)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    deg = np.maximum(deg, 1)
    W = np.zeros((n_vertices, n_vertices), dtype=np.float64)
    for u, v in edges:
        w = 1.0 / np.sqrt(deg[u] * deg[v])
        W[u, v] += w
        W[v, u] += w
    r = np.ones(n_vertices)
    for _ in range(n_iters):
        r = ALPHA + (1 - ALPHA) * W @ r
    return r
