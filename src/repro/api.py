"""One paper-shaped entry point: data graph + update + sync -> run.

The paper's whole programming surface is §3's four objects — a data
graph, an update function, sync operations, and an engine selected by
*configuration* (``set_scheduler_type`` / ``set_scope_type`` /
``start()``, §3.4-3.5).  This module is that surface for the repo
(DESIGN.md §9):

    from repro import api
    from repro.apps import pagerank

    graph, update, syncs = pagerank.build(edges, n)
    result = api.run(graph, update, syncs=syncs,
                     scheduler="priority", k_select=64,
                     until=lambda g: g["total_rank"] < 1e-3)

* ``scheduler=`` names a strategy from the string-keyed registry each
  engine module self-registers into (``repro.core.registry``):
  ``chromatic`` / ``priority`` / ``bsp`` / ``locking`` /
  ``sequential`` (the Def.-3.1 oracle).
* ``n_shards=`` selects the single-device strategy or its ``shard_map``
  variant — engine *class* imports are an implementation detail the
  facade owns.
* kwargs are validated in one place against the registry entry: a knob
  the strategy would silently ignore (``max_pending`` on the chromatic
  engine, a typo'd ``dispatch=``) raises ``ValueError`` naming the
  legal set.
* every run returns the same ``RunResult`` (final state, superstep /
  update counts, sync globals, optional per-superstep ``trace``), and
  ``until=`` terminates on a predicate over the sync results — the
  paper's termination-by-sync — replacing each engine's ad-hoc return
  convention.

The old engine classes remain importable from ``repro.core`` and are
constructed by this facade through the registry; direct construction is
deprecated-but-stable for out-of-tree callers and for the bitwise
facade-vs-direct equivalence tests (``tests/test_api.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.core import registry
from repro.core.exec import EngineState, validate_dispatch
from repro.core.registry import (describe_schedulers,  # noqa: F401
                                 get_distributed, get_scheduler,
                                 list_schedulers)
from repro.core.sync import SyncOp
from repro.core.update import Consistency, UpdateFn

PyTree = Any


# ----------------------------------------------------------------------
# RunResult: the one return convention
# ----------------------------------------------------------------------

@dataclasses.dataclass
class RunResult:
    """What every ``run`` returns, whatever the strategy or shard count.

    ``state`` is the full jittable ``EngineState`` for single-device
    engine runs (feed it to ``resume``/checkpointing); ``None`` for the
    sequential oracle and distributed runs (whose per-shard state stays
    sharded — the local blocks are in ``stats``).  ``superstep`` is
    ``None`` for the sequential oracle, which does not count steps;
    ``active_any`` (did the task set drain?) is reported by every
    scheduler.
    ``stats`` carries strategy-specific extras (the distributed
    engines' ``ghost_rows_sent`` / ``ghost_rows_full`` traffic counts,
    local shard blocks); ``trace`` the per-superstep records when
    tracing was requested; ``profile`` the ``TraceRecorder`` of timed
    launch records when ``profile=True`` (save it and fit a cost model
    with ``repro.profile.fit_cost_model``, DESIGN.md §11).
    ``restarts`` is the supervised-run restart log (a list of
    ``repro.ft.RestartRecord``) when fault tolerance was engaged via
    ``checkpoint_every=``/``resume_from=``/``faults=``; ``None``
    otherwise — an empty list means supervision was on and nothing
    failed.
    """
    vertex_data: PyTree
    edge_data: PyTree | None
    globals: dict
    superstep: int | None
    n_updates: int
    active_any: bool | None = None
    state: EngineState | None = None
    engine: Any = None
    trace: list | None = None
    profile: Any = None
    stats: dict = dataclasses.field(default_factory=dict)
    restarts: list | None = None


# ----------------------------------------------------------------------
# EngineSpec: scheduler name + validated configuration
# ----------------------------------------------------------------------

@dataclasses.dataclass
class EngineSpec:
    """A resolved engine configuration (the ``set_*_type`` bundle).

    ``options`` holds the per-strategy knobs (``k_select``,
    ``max_pending``, ``use_kernel``, ``exchange_edges``, ...) —
    validated against the registry entry at ``build`` time, not
    trusted.  ``dispatch="auto"`` defers to the strategy's registered
    default (sweep engines pin ``"bucket"``, window engines run the
    DESIGN.md §8 cost model); ``"bucket"`` / ``"batch"`` force a launch
    shape.  ``consistency`` overrides the update function's declared
    scope model (the paper's ``set_scope_type``).
    """
    scheduler: str = "chromatic"
    n_shards: int = 1
    consistency: Consistency | str | None = None
    dispatch: str | None = "auto"
    max_supersteps: int | None = None
    options: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        validate_dispatch(self.dispatch)
        if not isinstance(self.n_shards, int) or self.n_shards < 1:
            raise ValueError(
                f"n_shards must be a positive int, got {self.n_shards!r}")

    @property
    def entry(self) -> registry.SchedulerEntry:
        return get_scheduler(self.scheduler)

    # -- kwarg normalization: one validator for every strategy ---------
    def _factory_kwargs(self, entry) -> dict:
        kwargs = dict(self.options)
        if self.max_supersteps is not None:
            kwargs["max_supersteps"] = self.max_supersteps
        # "auto"/None defer to the strategy's registered default: the
        # sweep engines pin "bucket" for a reason (DESIGN.md §8), and a
        # forced mode must be an explicit choice.
        if self.dispatch not in (None, "auto"):
            kwargs["dispatch"] = self.dispatch
        unknown = set(kwargs) - entry.allowed
        if unknown:
            storage = unknown & {"hub_split", "w_cap", "edge_locality",
                                 "bucket_widths"}
            if storage:
                raise ValueError(
                    f"{sorted(storage)} are graph-*storage* options, not "
                    "engine options: pass them to DataGraph.from_edges "
                    "(or an app builder such as pagerank.build) so the "
                    "graph is stored split before handing it to run()")
            dist = isinstance(entry, registry.DistributedEntry)
            raise ValueError(
                f"scheduler {self.scheduler!r}"
                f"{' (distributed)' if dist else ''} does not "
                f"accept {sorted(unknown)}; allowed options: "
                f"{sorted(entry.allowed)}")
        for key in ("max_pending", "k_select", "max_supersteps"):
            v = kwargs.get(key)
            # bool is an int subclass: k_select=True must not quietly
            # become a window of 1
            if v is not None and (isinstance(v, bool)
                                  or not isinstance(v, int) or v < 1):
                raise ValueError(f"{key} must be a positive int, got {v!r}")
        return kwargs

    def _resolve_update(self, update_fn: UpdateFn) -> UpdateFn:
        if not isinstance(update_fn, UpdateFn):
            raise ValueError(
                f"update must be an UpdateFn, got {type(update_fn).__name__}"
                " (wrap the callable with repro.core.update.UpdateFn or "
                "aggregator_update)")
        if self.consistency is None:
            return update_fn
        c = self.consistency
        if isinstance(c, str):
            try:
                c = Consistency(c.lower())
            except ValueError:
                raise ValueError(
                    f"unknown consistency {self.consistency!r}; expected "
                    f"one of {[m.value for m in Consistency]}") from None
        return dataclasses.replace(update_fn, consistency=c)

    # -- engine construction ------------------------------------------
    def distributed(self, partition=None) -> bool:
        """Does this spec resolve to a ``shard_map`` engine?  True for
        ``n_shards > 1``, and for an explicit ``partition=`` at
        ``n_shards == 1`` — the degenerate M=1 plan (bit-identical to
        the single-device strategy, ``tests/test_locking.py``)."""
        return self.n_shards > 1 or partition is not None

    def build(self, graph, update_fn: UpdateFn,
              syncs: Sequence[SyncOp] = (), *, partition=None):
        """Resolve the registry entry and construct the engine.

        Without a ``partition=``, ``n_shards == 1`` builds the
        single-device strategy; otherwise the strategy's ``shard_map``
        variant is built over a ``ShardPlan`` (``partition=`` is a
        ``[Nv]`` shard assignment, a callable ``(graph, n_shards) ->
        assignment``, a prebuilt ``ShardPlan``, or None for the default
        ``two_phase_partition(graph.n_vertices, graph.edges_np,
        n_shards, seed=0)`` — note ``graph.edges_np`` is the graph's
        *stored* bucket-major edge order, not the input edge list, and
        the partitioner is edge-order-sensitive).
        """
        update_fn = self._resolve_update(update_fn)
        if not self.distributed(partition):
            entry = get_scheduler(self.scheduler)
            self._check_colors(entry, graph)
            return entry.factory(graph, update_fn, syncs=tuple(syncs),
                                 **self._factory_kwargs(entry))
        from repro.core.distributed import ShardPlan
        dentry = get_distributed(self.scheduler)
        self._check_colors(get_scheduler(self.scheduler), graph)
        if isinstance(partition, ShardPlan):
            if partition.M != self.n_shards:
                raise ValueError(
                    f"partition= plan has M={partition.M} shards but "
                    f"n_shards={self.n_shards}")
            plan = partition
        else:
            if isinstance(partition, str):
                if partition != "measured":
                    raise ValueError(
                        f"unknown partition {partition!r}: the only "
                        "string form is 'measured' (cost-model-scored "
                        "two_phase_partition, DESIGN.md §11); otherwise "
                        "pass an assignment, a callable, or a ShardPlan")
                from repro.core.partition import two_phase_partition
                from repro.profile.model import (load_cost_model,
                                                 resolve_cost_model)
                model = self.options.get("cost_model")
                model = (resolve_cost_model(model) if model is not None
                         else load_cost_model())
                if model is None:
                    raise ValueError(
                        "partition='measured' needs a cost model: pass "
                        "cost_model=, or calibrate this device first "
                        "(python -m repro.profile.calibrate)")
                assignment = two_phase_partition(
                    graph.n_vertices, graph.edges_np, self.n_shards,
                    seed=0, cost_model=model, w_cap=graph.ell.w_cap)
            elif callable(partition):
                assignment = partition(graph, self.n_shards)
            elif partition is None:
                from repro.core.partition import two_phase_partition
                assignment = two_phase_partition(
                    graph.n_vertices, graph.edges_np, self.n_shards,
                    seed=0)
            else:
                assignment = np.asarray(partition)
            plan = ShardPlan.build(graph, assignment, self.n_shards)
        return dentry.factory(graph, plan, update_fn, syncs=tuple(syncs),
                              **self._factory_kwargs(dentry))

    def _check_colors(self, entry, graph) -> None:
        if entry.needs_colors and graph.colors is None:
            raise ValueError(
                f"scheduler {self.scheduler!r} needs a colored graph; "
                "call graph.with_colors(...) (the locking engine "
                "handles colorless graphs)")


# ----------------------------------------------------------------------
# run(): the uniform run loop
# ----------------------------------------------------------------------

def build_engine(graph, update: UpdateFn, *, scheduler: str = "chromatic",
                 consistency=None, syncs: Sequence[SyncOp] = (),
                 n_shards: int = 1, dispatch: str | None = "auto",
                 max_pending: int | None = None,
                 max_supersteps: int | None = None, partition=None,
                 cost_model=None, **options):
    """Construct (but do not run) the engine ``run`` would drive.

    For callers that reuse one engine across invocations — benchmarks
    timing a warmed jit cache, apps exposing a configured engine —
    while keeping engine-class selection inside the facade.
    """
    if max_pending is not None:
        options["max_pending"] = max_pending
    if cost_model is not None:
        options["cost_model"] = _resolve_cost_model_option(cost_model)
    spec = EngineSpec(scheduler=scheduler, n_shards=n_shards,
                      consistency=consistency, dispatch=dispatch,
                      max_supersteps=max_supersteps, options=options)
    return spec.build(graph, update, syncs, partition=partition)


def _resolve_cost_model_option(cost_model):
    """Normalize ``cost_model=`` once, at the facade: strings resolve
    through ``repro.profile.resolve_cost_model`` ('measured', a model
    path, or a plugin entry-point name) so engines only ever see a
    model instance."""
    from repro.profile.model import resolve_cost_model
    return resolve_cost_model(cost_model)


# kwargs that only mean something on the online-serving path: they
# configure mutable storage and snapshot publication, not a batch run
SERVE_ONLY_KWARGS = frozenset({"slack", "edge_capacity", "publish_every"})


def serve(graph, update: UpdateFn, *, scheduler: str = "locking",
          consistency=None, syncs: Sequence[SyncOp] = (),
          n_shards: int = 1, dispatch: str | None = "auto",
          max_pending: int | None = None,
          max_supersteps: int | None = None, partition=None,
          cost_model=None, slack: int | None = None,
          edge_capacity: int | None = None,
          publish_every: int | None = None, **options):
    """Stand up a long-lived online serving engine (DESIGN.md §13).

    Returns a ``repro.serve.graph_engine.ServingEngine``: a
    mutate/recompute/query loop over the named scheduler —
    ``add_edge``/``update_vertex_data``/``update_edge_data`` land
    mutations on slack storage, ``recompute()`` re-converges exactly
    the dirty scopes, and queries (``read_vertex``/``read_edge``/
    ``top_k``/``snapshot()``) read snapshot-isolated published views.

    ``slack=`` reserves per-row insert headroom (default 4 slots when
    the graph was built without slack; a slack-built graph is used
    as-is); ``edge_capacity=`` caps total reserved edge rows;
    ``publish_every=`` also publishes mid-recompute snapshots every K
    supersteps during long convergences.  Scheduler configuration
    (``max_pending=``, ``dispatch=``, ``cost_model=``, per-strategy
    ``**options``) is validated here, eagerly, against the registry
    entry — inapplicable knobs raise ``ValueError`` naming the allowed
    set, exactly as ``run`` does.
    """
    if max_pending is not None:
        options["max_pending"] = max_pending
    if cost_model is not None:
        options["cost_model"] = _resolve_cost_model_option(cost_model)
    spec = EngineSpec(scheduler=scheduler, n_shards=n_shards,
                      consistency=consistency, dispatch=dispatch,
                      max_supersteps=max_supersteps, options=options)
    entry = spec.entry
    if not spec.distributed(partition) and not entry.stepping:
        raise ValueError(
            f"scheduler {scheduler!r} cannot serve: serving steps the "
            "engine between mutation batches, which needs a stepping "
            f"ExecutorCore strategy; stepping schedulers: "
            f"{[n for n in list_schedulers() if get_scheduler(n).stepping]}")
    # eager validation: surface bad knobs at serve() time, not at the
    # first recompute
    spec._factory_kwargs(get_distributed(scheduler)
                         if spec.distributed(partition) else entry)
    spec._resolve_update(update)
    spec._check_colors(entry, graph)
    if slack is not None and (isinstance(slack, bool)
                              or not isinstance(slack, int) or slack < 1):
        raise ValueError(f"slack must be a positive int, got {slack!r}")
    if graph.slack == 0 or (slack is not None and slack != graph.slack):
        from repro.core.graph import rebuild_compacted
        colors = graph.colors
        graph = rebuild_compacted(graph, slack=slack if slack else 4,
                                  edge_capacity=edge_capacity)
        if colors is not None:
            # vertex ids are stable across the rebuild, so the caller's
            # coloring (greedy, bipartite, ...) stays proper
            graph = graph.with_colors(np.asarray(colors))
    from repro.serve.graph_engine import ServingEngine
    return ServingEngine(graph, spec._resolve_update(update), syncs,
                         spec=spec, partition=partition,
                         publish_every=publish_every)


def run(graph, update: UpdateFn, *, scheduler: str = "chromatic",
        consistency=None, syncs: Sequence[SyncOp] = (), n_shards: int = 1,
        dispatch: str | None = "auto", max_pending: int | None = None,
        max_supersteps: int | None = None,
        until: Callable[[dict], bool] | None = None,
        num_supersteps: int | None = None, active=None,
        trace=None, partition=None, profile: bool = False,
        cost_model=None, checkpoint_every: int | None = None,
        checkpoint_dir: str | None = None,
        resume_from: str | None = None, faults=None,
        max_restarts: int = 3, **options) -> RunResult:
    """Run ``update`` over ``graph`` under the named scheduler.

    The paper's ``start()``: builds the engine from configuration and
    drives it to completion.  Termination is the earliest of the task
    set draining, ``max_supersteps``, an explicit ``num_supersteps``
    budget, or ``until(sync_globals) -> True`` (termination-by-sync,
    evaluated before each superstep on the latest sync results).

    ``trace=True`` (or ``trace=fn``) records one entry per superstep —
    the default record is ``{"superstep", "n_updates", "active",
    "globals"}``; a callable receives the ``EngineState`` and its
    return value is recorded instead.  ``until``/``trace`` step the
    engine superstep by superstep (bit-identical to the fused
    while-loop run — superstep boundaries are consistent cuts, §8) and
    are single-device only.

    ``profile=True`` runs the same stepping loop and additionally wall-
    clocks every superstep, recording launch shapes into a
    ``repro.profile.TraceRecorder`` returned as ``RunResult.profile``
    — the raw material for a fitted cost model (DESIGN.md §11).
    ``cost_model=`` hands such a model (or ``"measured"`` for this
    device's persisted calibration, a ``COSTMODEL_*.json`` path, or a
    plugin entry-point name) to ``dispatch="auto"``; it changes launch
    shapes only, never results.

    Fault tolerance (DESIGN.md §12): ``checkpoint_every=K`` +
    ``checkpoint_dir=`` snapshot the run at every K-th superstep
    boundary (sharded atomic snapshots for distributed runs,
    ``snapshot_engine_state`` files for single-device);
    ``resume_from=`` continues bit-identically from a snapshot
    (distributed resumes rebuild the ShardPlan from the snapshot's
    stored assignment when ``partition=`` is not given); ``faults=``
    takes a ``repro.ft.FaultPlan`` of injected failures; any of the
    three engages the supervised restart loop (``max_restarts``,
    exponential backoff, restore-from-latest-valid-snapshot) and fills
    ``RunResult.restarts``.

    Per-strategy extras (``k_select=``, ``fifo=``, ``max_pending=``,
    ``exchange_edges=``, ``snapshot_phases=``, ``use_kernel=``, ...)
    pass through ``**options`` and are validated against the registry
    entry — unknown or inapplicable knobs raise ``ValueError``.
    """
    serveish = SERVE_ONLY_KWARGS & set(options)
    if serveish:
        raise ValueError(
            f"{sorted(serveish)} are online-serving options: api.run "
            "executes one batch run over a frozen graph — use "
            "api.serve(graph, update, ...) for live mutations, "
            "incremental recompute, and query traffic (DESIGN.md §13)")
    if max_pending is not None:
        options["max_pending"] = max_pending
    if cost_model is not None:
        options["cost_model"] = _resolve_cost_model_option(cost_model)
    if trace is False:
        trace = None          # "tracing off", not a trace callable
    priority = options.pop("priority", None)
    if (checkpoint_every is None) != (checkpoint_dir is None):
        raise ValueError(
            "checkpoint_every= and checkpoint_dir= go together: the "
            "interval says when to snapshot, the directory says where")
    if checkpoint_every is not None and (
            isinstance(checkpoint_every, bool)
            or not isinstance(checkpoint_every, int)
            or checkpoint_every < 1):
        raise ValueError(f"checkpoint_every must be a positive int, "
                         f"got {checkpoint_every!r}")
    if isinstance(max_restarts, bool) or not isinstance(max_restarts, int) \
            or max_restarts < 0:
        raise ValueError(f"max_restarts must be a non-negative int, "
                         f"got {max_restarts!r}")
    ft_active = (checkpoint_every is not None or resume_from is not None
                 or faults is not None)
    if ft_active and (trace is not None or profile):
        raise ValueError(
            "trace=/profile= cannot be combined with checkpointing / "
            "fault injection (checkpoint_every=, resume_from=, faults=)")
    spec = EngineSpec(scheduler=scheduler, n_shards=n_shards,
                      consistency=consistency, dispatch=dispatch,
                      max_supersteps=max_supersteps, options=options)
    entry = spec.entry
    # a directory resume_from is a sharded snapshot (single-device
    # snapshots are single .npz files): resume it on the distributed
    # path even at the default n_shards=1 — the stored assignment
    # rebuilds the degenerate M=1 plan
    import os as _os
    dist_resume = resume_from is not None and _os.path.isdir(resume_from)
    if spec.distributed(partition) or dist_resume:
        if until is not None or trace is not None or profile:
            raise ValueError(
                "until=/trace=/profile= step the engine from the host "
                "and are single-device only; distributed runs execute "
                "one fused shard_map program (n_shards=1 supports all "
                "three)")
        if priority is not None:
            raise ValueError("priority= initialization is single-device "
                             "only (shards derive priority from active)")
        if resume_from is not None:
            from repro.ft.snapshot import read_assignment
            stored, manifest = read_assignment(resume_from)
            if manifest["scheduler"] != scheduler:
                raise ValueError(
                    f"resume_from snapshot was taken by scheduler "
                    f"{manifest['scheduler']!r}, this run asked for "
                    f"{scheduler!r}")
            if manifest["n_shards"] != n_shards:
                raise ValueError(
                    f"resume_from snapshot has {manifest['n_shards']} "
                    f"shards, this run asked for n_shards={n_shards}")
            if partition is None:
                partition = stored   # rebuild the identical ShardPlan
        engine = spec.build(graph, update, syncs, partition=partition)
        restarts = None
        if ft_active:
            from repro.ft import runner as ft_runner
            out, restarts = ft_runner.run_distributed(
                engine, scheduler=scheduler, active=active,
                num_supersteps=num_supersteps,
                checkpoint_every=checkpoint_every,
                checkpoint_dir=checkpoint_dir, resume_from=resume_from,
                faults=faults, max_restarts=max_restarts)
        else:
            out = engine.run(active=active, num_supersteps=num_supersteps)
        main = ("vertex_data", "globals", "supersteps", "n_updates",
                "active_any")
        return RunResult(
            vertex_data=out["vertex_data"], edge_data=None,
            globals=out["globals"], superstep=out["supersteps"],
            n_updates=out["n_updates"], active_any=out["active_any"],
            engine=engine, restarts=restarts,
            stats={k: v for k, v in out.items() if k not in main})

    engine = spec.build(graph, update, syncs)

    if not entry.stepping:
        if ft_active:
            raise ValueError(
                "checkpoint_every=/resume_from=/faults= need a stepping "
                "engine; the sequential oracle supports none of them")
        if trace is not None or profile:
            raise ValueError("trace=/profile= need a stepping engine; "
                             "the sequential oracle supports neither")
        if priority is not None:
            raise ValueError("priority= initialization is engine-only; "
                             "the sequential oracle derives priorities "
                             "from the active set")
        # the sequential oracle: plain-python loop + final task mask
        vdata, edata, globals_, n_updates, act = engine.run(
            active=active, num_supersteps=num_supersteps, until=until)
        return RunResult(vertex_data=vdata, edge_data=edata,
                         globals=globals_, superstep=None,
                         n_updates=n_updates,
                         active_any=bool(np.asarray(act).any()),
                         engine=engine)

    if ft_active:
        from repro.ft import runner as ft_runner
        state, restarts = ft_runner.run_single(
            engine, active=active, priority=priority, until=until,
            num_supersteps=num_supersteps,
            checkpoint_every=checkpoint_every,
            checkpoint_dir=checkpoint_dir, resume_from=resume_from,
            faults=faults, max_restarts=max_restarts)
        result = _result_from_state(state, engine, None)
        result.restarts = restarts
        return result

    if until is None and trace is None and not profile:
        state = engine.run(active=active, priority=priority,
                           num_supersteps=num_supersteps)
        return _result_from_state(state, engine, None)

    recorder = None
    if profile:
        import time

        import jax

        from repro.profile.trace import TraceRecorder
        recorder = TraceRecorder()
        seen_shapes: set = set()
    trace_fn = _default_trace if trace is True else trace
    state = engine.init_state(active, priority)
    records = [] if trace is not None else None
    steps = 0
    while True:
        if num_supersteps is not None:
            if steps >= num_supersteps:
                break
        elif (not bool(state.active.any())
              or int(state.superstep) >= engine.max_supersteps):
            break
        if until is not None and until(state.globals):
            break
        if recorder is not None:
            # shape probe first (host-side, eager), then time the real
            # jitted step; the first step at each launch shape compiles
            # and is marked cold so fits skip it
            probe = engine.profile_probe(state)
            key = (probe["mode"], probe.get("width"), probe.get("rows"))
            t0 = time.perf_counter()
            state = jax.block_until_ready(engine._step_jit(state))
            wall_us = (time.perf_counter() - t0) * 1e6
            recorder.record_step(wall_us=wall_us,
                                 cold=key not in seen_shapes,
                                 superstep=steps, **probe)
            seen_shapes.add(key)
        else:
            state = engine._step_jit(state)
        steps += 1
        if records is not None:
            records.append(trace_fn(state))
    return _result_from_state(state, engine, records, recorder)


def _result_from_state(state: EngineState, engine, trace,
                       profile=None) -> RunResult:
    return RunResult(
        vertex_data=state.vertex_data, edge_data=state.edge_data,
        globals=state.globals, superstep=int(state.superstep),
        n_updates=int(state.n_updates),
        active_any=bool(state.active.any()), state=state, engine=engine,
        trace=trace, profile=profile)


def _default_trace(state: EngineState) -> dict:
    import jax
    return {"superstep": int(state.superstep),
            "n_updates": int(state.n_updates),
            "active": int(state.active.sum()),
            "globals": jax.tree.map(np.asarray, state.globals)}
