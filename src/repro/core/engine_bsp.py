"""BSP / Jacobi baseline engine — the "Pregel/Hadoop-style" comparison.

All active vertices update simultaneously from the *previous* superstep's
data (bulk-synchronous, no sequential consistency across the step).  This
is precisely the chromatic engine run with the trivial single coloring
(every vertex one color): the per-phase snapshot semantics make every
update read pre-step data.  The paper's Fig. 1 (consistent vs
inconsistent ALS) and the Hadoop comparisons (§6.2) are reproduced
against this engine.

For the *message materialization* cost model of MapReduce (the paper's
"the Map only serves to emit the vertex probability table for every
edge"), see ``repro.baselines.mapreduce``: the same computation phrased
so that every edge materializes a full message, whose byte volume the
benchmark accounts.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.coloring import single_color
from repro.core.engine_chromatic import ChromaticEngine
from repro.core.graph import DataGraph
from repro.core.registry import register_scheduler
from repro.core.sync import SyncOp
from repro.core.update import UpdateFn


def bsp_engine(graph: DataGraph, update_fn: UpdateFn,
               syncs: Sequence[SyncOp] = (), max_supersteps: int = 100,
               use_kernel: bool = True,
               kernel_interpret: bool | None = None,
               dispatch: str = "bucket",
               cost_model=None) -> ChromaticEngine:
    """Strategy: one phase containing every active vertex (trivial color).

    The single phase batches the whole graph, so the per-bucket row
    launches are the natural dispatch shape (DESIGN.md §8).
    """
    g = graph.with_colors(single_color(graph.n_vertices))
    return ChromaticEngine(g, update_fn, syncs, max_supersteps,
                           use_kernel=use_kernel,
                           kernel_interpret=kernel_interpret,
                           dispatch=dispatch, cost_model=cost_model)


register_scheduler(
    "bsp", bsp_engine,
    description="bulk-synchronous Jacobi sweeps (single trivial color); "
                "NOT sequentially consistent — the Fig. 1 baseline")
