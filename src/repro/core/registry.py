"""String-keyed engine registry: scheduler names -> execution strategies.

The paper's C++ API selects execution strategy by *configuration*, not
by type: ``set_scheduler_type("priority")`` / ``set_scope_type("edge")``
/ ``start()`` (§3.4-3.5).  After PRs 1-4 this repo had grown six engine
classes with divergent constructor kwargs, and every caller hand-wired
its own — the opposite of the paper's one-surface claim.  This module
restores the configuration form:

* every engine module **self-registers** its strategy here at import
  time (``register_scheduler`` for the single-device strategy,
  ``register_distributed`` for its ``shard_map`` variant), declaring
  the keyword arguments it accepts: the *shared* set every strategy
  understands plus its declared per-strategy *extras* (``k_select``,
  ``max_pending``, ...);
* ``repro.api`` (DESIGN.md §9) resolves a scheduler name through
  ``get_scheduler``/``get_distributed`` and validates user kwargs
  against the entry in one place, so a kwarg an engine would silently
  ignore (``max_pending`` on the chromatic engine, a typo'd
  ``dispatch=`` string) raises a ``ValueError`` naming the legal set
  instead of being dropped.

The registry holds no engine imports of its own — engine modules import
*it*, never the reverse — so import order between strategies and their
distributed variants is free (the two halves are joined at lookup).
"""
from __future__ import annotations

import dataclasses
import importlib.metadata
from typing import Any, Callable

# Keyword arguments every registered single-device strategy understands
# (the normalized constructor surface the facade validates against).
SHARED_KWARGS = ("max_supersteps", "use_kernel", "kernel_interpret",
                 "dispatch", "cost_model")
# The distributed variants additionally understand the shard-plan knobs.
SHARED_DIST_KWARGS = SHARED_KWARGS + ("exchange_edges", "axis")


@dataclasses.dataclass(frozen=True)
class SchedulerEntry:
    """One registered scheduling strategy.

    ``factory(graph, update_fn, syncs=..., **kwargs)`` builds a runner
    exposing ``run(active=None, priority=None, num_supersteps=None)``.
    ``shared + extras`` is the exact keyword surface the facade will
    accept for this scheduler; anything else is a ``ValueError``.
    ``stepping`` says the runner is an ``ExecutorCore`` (EngineState /
    ``_step_jit``), which is what ``until=`` / ``trace=`` stepping
    needs; the sequential oracle sets it False.
    """
    name: str
    factory: Callable[..., Any]
    shared: tuple[str, ...] = SHARED_KWARGS
    extras: tuple[str, ...] = ()
    needs_colors: bool = False
    stepping: bool = True
    description: str = ""

    @property
    def allowed(self) -> frozenset:
        return frozenset(self.shared) | frozenset(self.extras)


@dataclasses.dataclass(frozen=True)
class DistributedEntry:
    """The shard_map variant of a scheduler: ``factory(graph, plan,
    update_fn, syncs=..., **kwargs)`` over a prebuilt ``ShardPlan``."""
    name: str
    factory: Callable[..., Any]
    shared: tuple[str, ...] = SHARED_DIST_KWARGS
    extras: tuple[str, ...] = ()

    @property
    def allowed(self) -> frozenset:
        return frozenset(self.shared) | frozenset(self.extras)


_SCHEDULERS: dict[str, SchedulerEntry] = {}
_DISTRIBUTED: dict[str, DistributedEntry] = {}


def _same_factory(a, b) -> bool:
    """Identity, or same (module, qualname): ``importlib.reload`` of an
    engine module re-executes its ``register_*`` call with a *new*
    class object for the same strategy — that must stay idempotent.
    Lambdas and nested functions all share qualnames like ``<lambda>``,
    so for those only identity counts (two different lambdas in one
    module are different factories)."""
    if a is b:
        return True
    key = lambda f: (getattr(f, "__module__", None),
                     getattr(f, "__qualname__", None))
    (ma, qa), (mb, qb) = key(a), key(b)
    if ma is None or qa is None or "<" in qa:
        return False
    return (ma, qa) == (mb, qb)


def _guard_duplicate(table: dict, name: str, factory):
    """Re-registering the same strategy is idempotent and returns the
    existing entry untouched (so sparse re-registration cannot clobber
    its metadata); a *different* factory under a taken name is a silent
    engine swap — exactly the fail-quietly class this registry exists
    to kill."""
    prior = table.get(name)
    if prior is None:
        return None
    if _same_factory(prior.factory, factory):
        return prior
    raise ValueError(
        f"scheduler name {name!r} is already registered to "
        f"{prior.factory!r}; pick a different name")


def register_scheduler(name: str, factory: Callable[..., Any], *,
                       shared: tuple[str, ...] = SHARED_KWARGS,
                       extras: tuple[str, ...] = (),
                       needs_colors: bool = False,
                       stepping: bool = True,
                       description: str = "") -> SchedulerEntry:
    prior = _guard_duplicate(_SCHEDULERS, name, factory)
    if prior is not None:
        return prior
    entry = SchedulerEntry(name=name, factory=factory, shared=shared,
                           extras=extras, needs_colors=needs_colors,
                           stepping=stepping, description=description)
    _SCHEDULERS[name] = entry
    return entry


def register_distributed(name: str, factory: Callable[..., Any], *,
                         shared: tuple[str, ...] = SHARED_DIST_KWARGS,
                         extras: tuple[str, ...] = ()) -> DistributedEntry:
    prior = _guard_duplicate(_DISTRIBUTED, name, factory)
    if prior is not None:
        return prior
    entry = DistributedEntry(name=name, factory=factory, shared=shared,
                             extras=extras)
    _DISTRIBUTED[name] = entry
    return entry


def _ensure_registered() -> None:
    """Import the engine modules so their registrations have run.

    Harmless if they are already imported (the common case: anything
    that touched ``repro.core`` pulled them in); makes a bare
    ``from repro.core import registry`` self-sufficient.
    """
    import repro.core  # noqa: F401  (imports every engine module)


# ----------------------------------------------------------------------
# Plugin discovery: out-of-tree strategies via package entry points
# ----------------------------------------------------------------------
#
# A package declaring
#
#     [project.entry-points."repro.schedulers"]
#     myengine = "mypkg.engine:register"
#
# makes ``api.run(..., scheduler="myengine")`` work without this repo
# knowing the package exists: on a registry miss the entry point is
# loaded, given a chance to self-register (the usual idiom: the loaded
# object calls ``register_scheduler`` at import or call time), and the
# lookup retried.  ``repro.cost_models`` entry points resolve the same
# way for ``cost_model="..."`` strings (``repro/profile/model.py``).

SCHEDULER_PLUGIN_GROUP = "repro.schedulers"


def _iter_entry_points(group: str):
    """All installed entry points in ``group`` (monkeypatch point for
    tests — no fake package installation needed)."""
    try:
        return tuple(importlib.metadata.entry_points(group=group))
    except Exception:
        return ()


def load_plugin(group: str, name: str):
    """Load entry point ``name`` from ``group``; None if not installed."""
    for ep in _iter_entry_points(group):
        if ep.name == name:
            return ep.load()
    return None


def _try_plugin_scheduler(name: str) -> bool:
    """Resolve a registry miss through ``repro.schedulers`` entry points.

    The loaded object may have self-registered as an import side effect;
    failing that, a callable is treated as (called for) a factory and
    registered under ``name`` with default metadata.  Returns whether
    ``name`` is now registered.
    """
    obj = load_plugin(SCHEDULER_PLUGIN_GROUP, name)
    if obj is None:
        return False
    if name not in _SCHEDULERS and callable(obj):
        produced = obj()
        if name not in _SCHEDULERS:
            if not callable(produced):
                raise ValueError(
                    f"entry point {SCHEDULER_PLUGIN_GROUP!r}:{name!r} "
                    f"neither registered a scheduler nor returned a "
                    f"factory (got {produced!r})")
            register_scheduler(name, produced,
                               description=f"plugin ({obj.__module__})")
    return name in _SCHEDULERS


def get_scheduler(name: str) -> SchedulerEntry:
    _ensure_registered()
    try:
        return _SCHEDULERS[name]
    except KeyError:
        if _try_plugin_scheduler(name):
            return _SCHEDULERS[name]
        raise ValueError(
            f"unknown scheduler {name!r}; registered schedulers: "
            f"{', '.join(list_schedulers())}") from None


def get_distributed(name: str) -> DistributedEntry:
    _ensure_registered()
    if name not in _SCHEDULERS:
        # same error text as get_scheduler: unknown beats undistributable
        get_scheduler(name)
    try:
        return _DISTRIBUTED[name]
    except KeyError:
        raise ValueError(
            f"scheduler {name!r} has no distributed (n_shards > 1) "
            f"engine; distributed schedulers: "
            f"{', '.join(sorted(_DISTRIBUTED))}") from None


def list_schedulers() -> list[str]:
    """Registered scheduler names, sorted (the paper's §3.4 menu)."""
    _ensure_registered()
    return sorted(_SCHEDULERS)


def describe_schedulers() -> dict[str, str]:
    _ensure_registered()
    return {n: _SCHEDULERS[n].description for n in sorted(_SCHEDULERS)}
