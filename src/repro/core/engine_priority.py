"""Priority engine — the TPU-idiomatic analogue of the Locking Engine
(paper §4.2.2).

The paper's locking engine exists to provide *adaptive, prioritized
ordering* (residual-BP-style scheduling [27]) while keeping sequential
consistency via distributed reader/writer locks.  On an SPMD TPU pod
there are no remote mutexes; the equivalent structure is:

  per superstep:
    1. select the K highest-priority active vertices (``jax.lax.top_k``
       over the priority array) — the prioritized task queue;
    2. execute them color phase by color phase — vertices of the selected
       set that share a color are non-adjacent, so each sub-phase is
       conflict-free exactly as in the chromatic engine.  This replaces
       "acquire scope locks"; the static schedule replaces lock
       *pipelining* (XLA overlaps the gathers/collectives it can see).

Semantically this executes tasks in priority order with ties broken by
(color, id) — a legal RemoveNext under the abstraction (§3.4), which only
requires that RemoveNext return *some* task.  FIFO scheduling is the
special case priority := insertion counter (negated).

The ``maxpending`` knob of the paper's lock pipeline reappears here as
``k_select``: how much work is in flight per superstep.  Benchmarks sweep
it like the paper's Fig. 8(b) sweeps maxpending.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import DataGraph
from repro.core.sync import SyncOp
from repro.core.update import UpdateFn, gather_scopes, scatter_result
from repro.core.engine_chromatic import EngineState

PyTree = Any


@dataclasses.dataclass
class PriorityEngine:
    graph: DataGraph
    update_fn: UpdateFn
    syncs: Sequence[SyncOp] = ()
    k_select: int = 64          # "maxpending": tasks in flight per superstep
    max_supersteps: int = 1000
    fifo: bool = False          # FIFO ordering (paper: "efficient FIFO and
                                # priority-based scheduling"): priority is
                                # ignored; tasks keep insertion order via a
                                # monotone counter

    def __post_init__(self):
        if self.graph.colors is None:
            raise ValueError("graph needs colors; call graph.with_colors(...)")
        self.n_colors = int(np.asarray(self.graph.colors).max()) + 1

    def init_state(self, active=None, priority=None) -> EngineState:
        nv = self.graph.n_vertices
        if active is None:
            active = jnp.ones((nv,), bool)
        if priority is None:
            priority = active.astype(jnp.float32)
        globals_ = {s.key: s.run(self.graph.vertex_data) for s in self.syncs}
        return EngineState(
            vertex_data=self.graph.vertex_data,
            edge_data=self.graph.edge_data,
            active=active, priority=priority, globals=globals_,
            superstep=jnp.int32(0), n_updates=jnp.int32(0))

    # ------------------------------------------------------------------
    def _superstep(self, state: EngineState) -> EngineState:
        g = self.graph
        k = min(self.k_select, g.n_vertices)
        if self.fifo:
            # FIFO: earlier-inserted first == larger (superstep-stamped)
            # negative timestamp; ties by vertex id via top_k stability.
            score = jnp.where(state.active, -state.priority, -jnp.inf)
        else:
            score = jnp.where(state.active, state.priority, -jnp.inf)
        _, top_ids = jax.lax.top_k(score, k)            # [K]
        top_sel = state.active[top_ids]                 # mask -inf rows out
        # execute the selected set color phase by color phase
        vcolors = g.colors[top_ids]

        def phase(c, st):
            vdata, edata, active, priority, n_upd = st
            sel = top_sel & (vcolors == c) & active[top_ids]
            scope = gather_scopes(g, vdata, edata, top_ids, state.globals)
            res = self.update_fn(scope)
            vdata, edata = scatter_result(
                g, vdata, edata, top_ids, sel, scope, res)
            active = active.at[top_ids].set(active[top_ids] & ~sel)
            priority = priority.at[top_ids].set(
                jnp.where(sel, 0.0, priority[top_ids]))
            if res.resched_self is not None:
                active = active.at[top_ids].max(sel & res.resched_self)
                if res.priority is not None:
                    priority = priority.at[top_ids].max(
                        jnp.where(sel & res.resched_self, res.priority, -jnp.inf))
            if res.resched_nbrs is not None:
                nmask = scope.nbr_mask & sel[:, None] & res.resched_nbrs
                safe = jnp.where(nmask, scope.nbr_ids, g.n_vertices)
                active = active.at[safe.reshape(-1)].max(
                    nmask.reshape(-1), mode="drop")
                if self.fifo:
                    stamp = (state.superstep + 1).astype(jnp.float32)
                    pr = jnp.where(nmask, stamp, -jnp.inf)
                    priority = priority.at[safe.reshape(-1)].max(
                        pr.reshape(-1), mode="drop")
                elif res.priority is not None:
                    pr = jnp.where(nmask, res.priority[:, None], -jnp.inf)
                    priority = priority.at[safe.reshape(-1)].max(
                        pr.reshape(-1), mode="drop")
            return (vdata, edata, active, priority,
                    n_upd + sel.sum(dtype=jnp.int32))

        st = (state.vertex_data, state.edge_data, state.active,
              state.priority, state.n_updates)
        vdata, edata, active, priority, n_upd = jax.lax.fori_loop(
            0, self.n_colors, phase, st)
        new_globals = dict(state.globals)
        for s in self.syncs:
            due = (state.superstep + 1) % max(s.tau, 1) == 0
            fresh = s.run(vdata)
            new_globals[s.key] = jax.tree.map(
                lambda new, old: jnp.where(due, new, old),
                fresh, state.globals[s.key])
        return EngineState(
            vertex_data=vdata, edge_data=edata, active=active,
            priority=priority, globals=new_globals,
            superstep=state.superstep + 1, n_updates=n_upd)

    @functools.cached_property
    def _run_jit(self):
        def cond(state):
            return state.active.any() & (state.superstep < self.max_supersteps)
        return jax.jit(lambda s: jax.lax.while_loop(cond, self._superstep, s))

    def run(self, active=None, priority=None,
            num_supersteps: int | None = None) -> EngineState:
        state = self.init_state(active, priority)
        if num_supersteps is not None:
            step = jax.jit(self._superstep)
            for _ in range(num_supersteps):
                state = step(state)
            return state
        return self._run_jit(state)
