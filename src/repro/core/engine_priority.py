"""Priority engine — the TPU-idiomatic analogue of the Locking Engine
(paper §4.2.2).

The paper's locking engine exists to provide *adaptive, prioritized
ordering* (residual-BP-style scheduling [27]) while keeping sequential
consistency via distributed reader/writer locks.  On an SPMD TPU pod
there are no remote mutexes; the equivalent structure is:

  per superstep:
    1. select the K highest-priority active vertices (``jax.lax.top_k``
       over the priority array) — the prioritized task queue;
    2. execute them color phase by color phase — vertices of the selected
       set that share a color are non-adjacent, so each sub-phase is
       conflict-free exactly as in the chromatic engine.  This replaces
       "acquire scope locks" *for colorable graphs*; the real lock
       pipeline (claim-pass conflict resolution with a ``max_pending``
       in-flight window, no coloring required) lives in
       ``repro.core.engine_locking`` (DESIGN.md §6).

Semantically this executes tasks in priority order with ties broken by
(color, id) — a legal RemoveNext under the abstraction (§3.4), which only
requires that RemoveNext return *some* task.  FIFO scheduling is the
special case priority := insertion counter (negated).

``k_select`` bounds how much work is in flight per superstep — an
*analogue* of the paper's ``maxpending``, not a replacement for lock
pipelining: it presumes a coloring and never arbitrates conflicts.  The
locking engine's ``max_pending`` is the real knob; ``benchmarks/
fig8_locking.py`` sweeps both side by side.

As a scheduling strategy over ``repro.core.exec.ExecutorCore``, the
whole engine is the top-k selection below: bookkeeping, sync refresh,
the runner and the kernel fast path are shared with the other engines.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exec import EngineState, ExecutorCore
from repro.core.registry import register_scheduler


@dataclasses.dataclass
class PriorityEngine(ExecutorCore):
    """Strategy: top-k priority selection, executed color-by-color."""

    max_supersteps: int = 1000
    k_select: int = 64          # "maxpending": tasks in flight per superstep
    fifo: bool = False          # FIFO ordering (paper: "efficient FIFO and
                                # priority-based scheduling"): priority is
                                # ignored; tasks keep insertion order via a
                                # monotone counter
    # "auto" resolves the k_select window through the cost model
    # (DESIGN.md §8): the small windows this engine exists for launch
    # window-shaped [B, W] kernels instead of the full per-bucket row
    # set, while a graph-sized k_select keeps the bucket launches
    dispatch: str = "auto"

    def __post_init__(self):
        super().__post_init__()
        if self.graph.colors is None:
            raise ValueError("graph needs colors; call graph.with_colors(...)")
        self.n_colors = int(np.asarray(self.graph.colors).max()) + 1
        self.n_phases = self.n_colors

    def prepare(self, state: EngineState):
        k = min(self.k_select, self.graph.n_vertices)
        if self.fifo:
            # FIFO: earlier-inserted first == larger (superstep-stamped)
            # negative timestamp; ties by vertex id via top_k stability.
            score = jnp.where(state.active, -state.priority, -jnp.inf)
        else:
            score = jnp.where(state.active, state.priority, -jnp.inf)
        _, top_ids = jax.lax.top_k(score, k)            # [K]
        top_sel = state.active[top_ids]                 # mask -inf rows out
        return top_ids, top_sel, self.graph.colors[top_ids]

    def select(self, c, ctx):
        top_ids, top_sel, vcolors = ctx
        return top_ids, top_sel & (vcolors == c)

    def nbr_stamp(self, state: EngineState):
        if not self.fifo:
            return None
        return (state.superstep + 1).astype(jnp.float32)


register_scheduler(
    "priority", PriorityEngine, extras=("k_select", "fifo"),
    needs_colors=True,
    description="top-k priority window executed color by color — the "
                "TPU analogue of the paper's prioritized scheduling")
