"""The GraphLab data graph (paper §3.1), adapted to static-shape JAX arrays.

The paper's data graph G = (V, E, D) stores mutable user data on vertices
and (optionally directed) edges while the *structure* is static.  That
static-structure guarantee is exactly what ``jit`` wants: we freeze the
adjacency into padded ELL form (``[Nv, max_deg]``) once, and all engine
iterations are pure array programs over it.

Conventions
-----------
* ``nbrs[v, j]``      -- vertex id of the j-th neighbor of v (0 if padded)
* ``nbr_mask[v, j]``  -- True for real neighbor slots
* ``edge_ids[v, j]``  -- id of the edge {v, nbrs[v,j]}; padded slots point
                         at the *pad edge* row ``n_edges`` so that scatters
                         to padded slots are harmless.
* ``is_src[v, j]``    -- True iff v is endpoint 0 of that edge.  This is how
                         the paper's "data on directed edges" (D_{u->v} vs
                         D_{v->u}) is recovered from an undirected adjacency:
                         edge data may carry separate fields per direction
                         and the update function picks using ``is_src``.

Vertex data and edge data are pytrees of arrays with leading dim ``Nv``
resp. ``n_edges + 1`` (one pad row).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _tree_pad_rows(tree: PyTree, n_rows: int) -> PyTree:
    """Append ``n_rows`` zero rows to every leaf (leading axis)."""
    def pad(a):
        a = jnp.asarray(a)
        pad_shape = (n_rows,) + a.shape[1:]
        return jnp.concatenate([a, jnp.zeros(pad_shape, a.dtype)], axis=0)
    return jax.tree.map(pad, tree)


@dataclasses.dataclass
class DataGraph:
    """Static graph structure + mutable vertex/edge data (device arrays)."""

    n_vertices: int
    n_edges: int
    max_deg: int
    # --- static structure (int32 / bool device arrays) ---
    nbrs: jax.Array            # [Nv, max_deg] int32
    nbr_mask: jax.Array        # [Nv, max_deg] bool
    edge_ids: jax.Array        # [Nv, max_deg] int32 (pad slots -> n_edges)
    is_src: jax.Array          # [Nv, max_deg] bool
    degree: jax.Array          # [Nv] int32
    # --- mutable user data ---
    vertex_data: PyTree        # leaves [Nv, ...]
    edge_data: PyTree          # leaves [n_edges + 1, ...] (last row = pad)
    # --- host-side copies of structure for partitioning / coloring ---
    edges_np: np.ndarray       # [n_edges, 2] int64 host copy
    # --- optional annotations ---
    colors: jax.Array | None = None   # [Nv] int32, attached by coloring.py
    n_colors: int = 0

    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(
        n_vertices: int,
        edges: np.ndarray,
        vertex_data: PyTree,
        edge_data: PyTree = None,
        max_deg: int | None = None,
    ) -> "DataGraph":
        """Build the padded ELL structure from an undirected edge list.

        ``edges``: [Ne, 2] integer array, each row an undirected edge
        {u, v} (self loops and duplicates are the caller's business;
        both are handled but duplicates count twice toward degree).
        """
        edges = np.asarray(edges, dtype=np.int64)
        if edges.size == 0:
            edges = edges.reshape(0, 2)
        ne = len(edges)
        deg = np.zeros(n_vertices, dtype=np.int64)
        for col in (0, 1):
            np.add.at(deg, edges[:, col], 1)
        md = int(deg.max()) if ne else 1
        if max_deg is not None:
            if max_deg < md:
                raise ValueError(f"max_deg={max_deg} < actual max degree {md}")
            md = max_deg
        md = max(md, 1)

        nbrs = np.zeros((n_vertices, md), dtype=np.int32)
        mask = np.zeros((n_vertices, md), dtype=bool)
        eids = np.full((n_vertices, md), ne, dtype=np.int32)  # pad edge
        is_src = np.zeros((n_vertices, md), dtype=bool)
        cursor = np.zeros(n_vertices, dtype=np.int64)
        us, vs = edges[:, 0], edges[:, 1]
        for e in range(ne):
            u, v = us[e], vs[e]
            cu, cv = cursor[u], cursor[v]
            nbrs[u, cu], mask[u, cu], eids[u, cu], is_src[u, cu] = v, True, e, True
            cursor[u] = cu + 1
            nbrs[v, cv], mask[v, cv], eids[v, cv] = u, True, e
            cursor[v] = cv + 1

        edge_data = {} if edge_data is None else edge_data
        return DataGraph(
            n_vertices=n_vertices,
            n_edges=ne,
            max_deg=md,
            nbrs=jnp.asarray(nbrs),
            nbr_mask=jnp.asarray(mask),
            edge_ids=jnp.asarray(eids),
            is_src=jnp.asarray(is_src),
            degree=jnp.asarray(deg, dtype=jnp.int32),
            vertex_data=jax.tree.map(jnp.asarray, vertex_data),
            edge_data=_tree_pad_rows(edge_data, 1),
            edges_np=edges,
        )

    # ------------------------------------------------------------------
    def with_colors(self, colors: np.ndarray) -> "DataGraph":
        colors = np.asarray(colors)
        return dataclasses.replace(
            self,
            colors=jnp.asarray(colors, dtype=jnp.int32),
            n_colors=int(colors.max()) + 1 if colors.size else 1,
        )

    def replace_data(self, vertex_data=None, edge_data=None) -> "DataGraph":
        return dataclasses.replace(
            self,
            vertex_data=self.vertex_data if vertex_data is None else vertex_data,
            edge_data=self.edge_data if edge_data is None else edge_data,
        )

    # convenience -------------------------------------------------------
    @property
    def adjacency_lists(self) -> list[list[int]]:
        """Host-side adjacency (for coloring / partitioning / oracles)."""
        adj: list[list[int]] = [[] for _ in range(self.n_vertices)]
        for u, v in self.edges_np:
            adj[int(u)].append(int(v))
            adj[int(v)].append(int(u))
        return adj


def bipartite_edges(n_left: int, n_right: int, pairs: np.ndarray) -> tuple[int, np.ndarray]:
    """Helper: map (left_i, right_j) pairs to global vertex ids.

    Left vertices get ids [0, n_left), right vertices [n_left, n_left+n_right).
    Returns (n_vertices, edges).
    """
    pairs = np.asarray(pairs, dtype=np.int64)
    edges = np.stack([pairs[:, 0], pairs[:, 1] + n_left], axis=1)
    return n_left + n_right, edges


def grid_edges_3d(nx: int, ny: int, nz: int) -> tuple[int, np.ndarray]:
    """6-connected 3-D grid (the CoSeg super-pixel graph, paper §5.2)."""
    def vid(x, y, z):
        return (x * ny + y) * nz + z
    edges = []
    for x in range(nx):
        for y in range(ny):
            for z in range(nz):
                if x + 1 < nx:
                    edges.append((vid(x, y, z), vid(x + 1, y, z)))
                if y + 1 < ny:
                    edges.append((vid(x, y, z), vid(x, y + 1, z)))
                if z + 1 < nz:
                    edges.append((vid(x, y, z), vid(x, y, z + 1)))
    return nx * ny * nz, np.asarray(edges, dtype=np.int64)
