"""The GraphLab data graph (paper §3.1), adapted to static-shape JAX arrays.

The paper's data graph G = (V, E, D) stores mutable user data on vertices
and (optionally directed) edges while the *structure* is static.  That
static-structure guarantee is exactly what ``jit`` wants: we freeze the
adjacency once, and all engine iterations are pure array programs over it.

Storage layout (DESIGN.md §7): the adjacency is **degree-bucketed
sliced ELL**.  A single padded ``[Nv, max_deg]`` block — the original
layout — lets one hub vertex inflate every row to ``max_deg`` slots,
which on the paper's power-law workloads (Netflix ALS, NER CoEM, §5) is
the scaling limiter Distributed GraphLab (arXiv:1204.6078) calls out.
Instead, vertices are permuted into power-of-two width buckets
(2, 4, ..., ``max_deg``); each bucket stores its own padded block
``[Nv_b, W_b]``, so total storage is ``sum_b Nv_b * W_b`` — within 2x of
the exact CSR size — and kernels unroll ``W_b`` slots instead of
``max_deg``.  The permutation (and its inverse) lives on the graph;
everything above ``DataGraph.from_edges`` is unaware of the layout.

Conventions (per bucket block, and in any padded view of it)
-----------
* ``nbrs[v, j]``      -- vertex id of the j-th neighbor of v (0 if padded)
* ``nbr_mask[v, j]``  -- True for real neighbor slots
* ``edge_ids[v, j]``  -- id of the edge {v, nbrs[v,j]}; padded slots point
                         at the *pad edge* row ``n_edges`` so that scatters
                         to padded slots are harmless.
* ``is_src[v, j]``    -- True iff v is endpoint 0 of that edge.  This is how
                         the paper's "data on directed edges" (D_{u->v} vs
                         D_{v->u}) is recovered from an undirected adjacency:
                         edge data may carry separate fields per direction
                         and the update function picks using ``is_src``.

Slot *order* within a row is identical across layouts (edge-insertion
order), which is what keeps the bucketed kernel path bit-identical to
the dense fallback: trailing zero-weight pad slots are exact no-ops in
the shared kernel accumulation (DESIGN.md §7).

Vertex data and edge data are pytrees of arrays with leading dim ``Nv``
resp. ``n_edges + 1`` (one pad row).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _tree_pad_rows(tree: PyTree, n_rows: int) -> PyTree:
    """Append ``n_rows`` zero rows to every leaf (leading axis)."""
    def pad(a):
        a = jnp.asarray(a)
        pad_shape = (n_rows,) + a.shape[1:]
        return jnp.concatenate([a, jnp.zeros(pad_shape, a.dtype)], axis=0)
    return jax.tree.map(pad, tree)


class EllRows(NamedTuple):
    """A batch of adjacency rows materialized at full width ``[B, Dmax]``."""
    nbrs: jax.Array
    nbr_mask: jax.Array
    edge_ids: jax.Array
    is_src: jax.Array


def sliced_slot_count(starts: Sequence[int], widths: Sequence[int]) -> int:
    """Stored (= bucket-kernel-computed) slots ``sum_b Nv_b * W_b`` —
    the single definition behind ``SlicedEll.padded_slots`` and
    ``ShardPlan.sliced_slots`` (the cost model's bucket-path arm)."""
    return sum((starts[b + 1] - starts[b]) * widths[b]
               for b in range(len(widths)))


# ----------------------------------------------------------------------
# Sliced ELL: degree-bucketed adjacency storage
# ----------------------------------------------------------------------

@dataclasses.dataclass
class SlicedEll:
    """Degree-bucketed adjacency: one padded block per width bucket.

    Rows (vertices locally, shard rows in a ``ShardPlan``) are permuted
    so that bucket ``b`` holds the contiguous position range
    ``[starts[b], starts[b+1])`` with block width ``widths[b]``.
    ``perm[p]`` is the row id stored at bucketed position ``p``
    (``n_rows`` on bucket padding positions); ``inv_perm[r]`` is the
    bucketed position of row ``r`` (every real row is in exactly one
    bucket).  Neighbor values in the blocks are *row ids* in the
    original addressing, so gathers from ``[n_rows, ...]`` data arrays
    need no translation.
    """

    # --- static layout ---
    widths: tuple[int, ...]        # ascending bucket widths
    starts: tuple[int, ...]        # len n_buckets+1 position offsets
    n_rows: int                    # addressable rows (Nv or R)
    max_deg: int                   # widths[-1] (owner max degree if split)
    pad_edge: int                  # edge id stored in padded slots
    # --- per-bucket device blocks ---
    nbrs: tuple[jax.Array, ...]        # [Nv_b, W_b] int32
    nbr_mask: tuple[jax.Array, ...]    # [Nv_b, W_b] bool
    edge_ids: tuple[jax.Array, ...]    # [Nv_b, W_b] int32
    is_src: tuple[jax.Array, ...]      # [Nv_b, W_b] bool
    # --- the permutation (virtual-row space when split) ---
    perm: jax.Array                # [total_rows] int32 (pad -> n_rows)
    inv_perm: jax.Array            # [n_rows] int32
    # --- hub splitting (DESIGN.md §10); None/defaults when unsplit ---
    # Rows wider than ``w_cap`` are chunked into virtual rows of width
    # <= w_cap; blocks/perm/inv_perm then live in *virtual-row* space
    # while ``n_rows``/``max_deg`` keep describing owner rows.  Virtual
    # row v holds owner slots [k*w_cap, (k+1)*w_cap) for its chunk
    # index k = v - vrow_offset[owner]; a row's virtual rows are the
    # contiguous id range [vrow_offset[r], vrow_offset[r+1]).
    w_cap: int | None = None           # chunk width cap (power of two)
    n_chunks_max: int = 1              # max virtual rows of any owner
    owner_of_vrow: jax.Array | None = None   # [n_virtual] int32 (pad->n_rows)
    vrow_offset: jax.Array | None = None     # [n_rows + 1] int32

    # ------------------------------------------------------------------
    @property
    def n_buckets(self) -> int:
        return len(self.widths)

    @property
    def is_split(self) -> bool:
        return self.w_cap is not None

    @property
    def n_virtual(self) -> int:
        """Virtual rows (== addressable rows when unsplit)."""
        return (self.n_rows if self.owner_of_vrow is None
                else self.owner_of_vrow.shape[0])

    @property
    def scope_widths(self) -> tuple[int, ...]:
        """Owner-space width classes for batch-shaped gathers.

        Unsplit these are the bucket widths.  When split, owner rows
        wider than ``w_cap`` need multi-chunk gathers, so the ladder
        continues past the bucket widths with power-of-two chunk
        multiples ``2*w_cap, 4*w_cap, ...`` up to the first one
        covering ``max_deg`` — the static widths the window dispatch
        switch (DESIGN.md §8) compiles against.
        """
        if self.w_cap is None:
            return self.widths
        ws = list(self.widths)
        w = self.w_cap * 2
        while w < self.max_deg:
            ws.append(w)
            w *= 2
        ws.append(w)
        return tuple(ws)

    @property
    def total_rows(self) -> int:
        return self.starts[-1]

    @property
    def padded_slots(self) -> int:
        """Stored (= kernel-computed) neighbor slots, padding included."""
        return sliced_slot_count(self.starts, self.widths)

    @property
    def bucket_launches(self) -> tuple[tuple[int, int], ...]:
        """The ``(width, rows)`` launch sequence of one bucket-mode
        dispatch — the shape a fitted cost model prices when
        ``choose_dispatch`` compares it against a batch launch
        (DESIGN.md §11)."""
        return tuple(
            (int(self.widths[b]), int(self.starts[b + 1] - self.starts[b]))
            for b in range(self.n_buckets))

    def bucket_slices(self, arr: jax.Array) -> tuple[jax.Array, ...]:
        """Split a ``[total_rows, ...]`` array into per-bucket slices."""
        return tuple(arr[self.starts[b]: self.starts[b + 1]]
                     for b in range(self.n_buckets))

    # ------------------------------------------------------------------
    def snap_width(self, width: int) -> int:
        """Snap a requested scope width up to the nearest bucket width.

        Width-specialized gathers compile one jit variant per *scope*
        width (a handful of power-of-two values) instead of one per
        requested window width — the shape-caching contract of the
        batch-shaped dispatch path (DESIGN.md §8).
        """
        for w in self.scope_widths:
            if w >= width:
                return w
        return self.scope_widths[-1]

    def window_bucket(self, ids: jax.Array, sel: jax.Array) -> jax.Array:
        """Runtime index (into ``scope_widths``) of the widest width
        class a selected row needs.

        The batch-shaped dispatch path branches on this scalar
        (``lax.switch`` over the static scope widths) so a hub-free
        window gathers and launches at its own snapped width instead of
        the global ``max_deg``.  An empty selection reports class 0.
        When split, single-chunk rows report their virtual-row bucket
        and multi-chunk (hub) rows report the power-of-two chunk-count
        class ``n_buckets + log2ceil(n_chunks) - 1``.
        """
        bounds = jnp.asarray(self.starts[1:], jnp.int32)
        if self.w_cap is None:
            pos = self.inv_perm[ids]
            b = jnp.searchsorted(bounds, pos, side="right").astype(jnp.int32)
            return jnp.max(jnp.where(sel, b, 0)).astype(jnp.int32)
        off = self.vrow_offset
        nch = off[ids + 1] - off[ids]
        pos0 = self.inv_perm[off[ids]]
        b_single = jnp.searchsorted(bounds, pos0,
                                    side="right").astype(jnp.int32)
        n_wide = len(self.scope_widths) - self.n_buckets
        chunk_bounds = jnp.asarray([2 << j for j in range(n_wide)],
                                   jnp.int32)
        b_wide = self.n_buckets + jnp.searchsorted(
            chunk_bounds, nch, side="left").astype(jnp.int32)
        cls = jnp.where(nch > 1, b_wide, b_single)
        return jnp.max(jnp.where(sel, cls, 0)).astype(jnp.int32)

    def rows(self, ids: jax.Array, width: int | None = None) -> EllRows:
        """Materialize ``[B, W]`` adjacency rows (default ``W=max_deg``).

        The escape from the bucketed layout for everything that is
        per-*batch* rather than per-graph (scope gathers, claim passes,
        edge scatters): one gather per bucket, selected per row by
        bucket membership.  Columns past a row's bucket width read as
        padding (mask False, edge id ``pad_edge``).

        ``width`` (static) truncates the materialization to the snapped
        scope width: rows needing wider gathers are skipped entirely,
        so they read as *empty* — callers must guarantee every row
        they act on fits a scope width <= ``W`` (the ``window_bucket``
        switch of the batch dispatch path does).

        When split, rows wider than ``w_cap`` are reassembled from
        their virtual-row chunks: ``s = W / w_cap`` per-chunk gathers
        concatenated along the slot axis, so the owner-space view is
        bitwise the unsplit padded row (the round-trip property in
        ``tests/test_graph_properties.py``).
        """
        if self.w_cap is None:
            d = self.max_deg if width is None else self.snap_width(width)
            return self._gather_rows(self.inv_perm[ids], d)
        off = self.vrow_offset
        nch = off[ids + 1] - off[ids]
        first = off[ids]
        if width is not None:
            d = self.snap_width(width)
            if d <= self.w_cap:
                # single-chunk class: hubs (nch > 1) read as empty
                pos = jnp.where(nch == 1, self.inv_perm[first],
                                self.total_rows)
                return self._gather_rows(pos, d)
            s = d // self.w_cap
        else:
            s = -(-self.max_deg // self.w_cap)
        nv_last = self.n_virtual - 1
        chunks = []
        for k in range(s):
            ok = (k < nch) & (nch <= s)
            pos = jnp.where(ok,
                            self.inv_perm[jnp.minimum(first + k, nv_last)],
                            self.total_rows)
            chunks.append(self._gather_rows(pos, self.w_cap))
        out = EllRows(*(jnp.concatenate(fs, axis=-1) for fs in zip(*chunks)))
        if width is None and s * self.w_cap != self.max_deg:
            out = EllRows(*(a[..., : self.max_deg] for a in out))
        return out

    def _gather_rows(self, pos: jax.Array, d: int) -> EllRows:
        """One gather per bucket of width <= ``d``, selected per row by
        bucketed-position membership; out-of-range positions (including
        the ``total_rows`` sentinel) read as padding."""
        out_n = jnp.zeros(pos.shape + (d,), jnp.int32)
        out_m = jnp.zeros(pos.shape + (d,), bool)
        out_e = jnp.full(pos.shape + (d,), self.pad_edge, jnp.int32)
        out_s = jnp.zeros(pos.shape + (d,), bool)
        for b in range(self.n_buckets):
            s, e, w = self.starts[b], self.starts[b + 1], self.widths[b]
            if w > d:
                break
            in_b = (pos >= s) & (pos < e)
            loc = jnp.where(in_b, pos - s, 0)
            sel = in_b[..., None]
            pad = [(0, 0)] * (loc.ndim) + [(0, d - w)]
            out_n = jnp.where(sel, jnp.pad(self.nbrs[b][loc], pad), out_n)
            out_m = jnp.where(sel, jnp.pad(self.nbr_mask[b][loc], pad), out_m)
            out_e = jnp.where(sel, jnp.pad(self.edge_ids[b][loc], pad,
                                           constant_values=self.pad_edge),
                              out_e)
            out_s = jnp.where(sel, jnp.pad(self.is_src[b][loc], pad), out_s)
        return EllRows(out_n, out_m, out_e, out_s)

    def row_activation(self, ids: jax.Array, sel: jax.Array) -> jax.Array:
        """Route batch slots to their bucketed rows: ``[total_rows]`` bool.

        The OOB-sentinel scatter of the task-set algebra: unselected /
        padded batch slots go to the out-of-bounds position so
        ``mode="drop"`` makes the scatter exact even though padded slots
        alias row 0.  When split, a selected owner activates *all* of
        its virtual rows (every chunk holds a slice of its scope).
        """
        if self.w_cap is None:
            pos = jnp.where(sel, self.inv_perm[ids], self.total_rows)
            act = jnp.zeros((self.total_rows,), bool)
            return act.at[pos].set(True, mode="drop")
        off = self.vrow_offset
        nch = off[ids + 1] - off[ids]
        k = jnp.arange(self.n_chunks_max, dtype=jnp.int32)
        vid = off[ids][..., None] + k
        ok = sel[..., None] & (k < nch[..., None])
        pos = jnp.where(ok,
                        self.inv_perm[jnp.minimum(vid, self.n_virtual - 1)],
                        self.total_rows)
        act = jnp.zeros((self.total_rows,), bool)
        return act.at[pos.reshape(-1)].set(True, mode="drop")

    def to_padded(self) -> EllRows:
        """The monolithic ``[n_rows, max_deg]`` view — the escape hatch
        for the sequential oracle, property tests and benchmarks."""
        return self.rows(jnp.arange(self.n_rows, dtype=jnp.int32))


jax.tree_util.register_dataclass(
    SlicedEll,
    data_fields=["nbrs", "nbr_mask", "edge_ids", "is_src", "perm",
                 "inv_perm", "owner_of_vrow", "vrow_offset"],
    meta_fields=["widths", "starts", "n_rows", "max_deg", "pad_edge",
                 "w_cap", "n_chunks_max"])


def bucket_major_edge_order(ell: SlicedEll, n_edges: int) -> np.ndarray:
    """Edge ids in bucket-major first-visit order: ``order[new] = old``.

    Walking buckets in width order, rows in bucketed position order and
    slots left to right, an edge is numbered at its first appearance.
    Renumbering edge rows this way makes each bucket block's
    ``edge_ids`` gathers (and the pad-row-guarded scatters back) walk
    edge data in nearly-contiguous ascending runs instead of the random
    order the input edge list happened to arrive in (ROADMAP
    "Edge-data locality").  Host-side, build-time only.
    """
    visits = [np.asarray(ell.edge_ids[b])[np.asarray(ell.nbr_mask[b])]
              for b in range(ell.n_buckets)]
    flat = (np.concatenate(visits) if visits
            else np.zeros(0, np.int64)).astype(np.int64)
    _, first = np.unique(flat, return_index=True)
    order = flat[np.sort(first)]
    assert len(order) == n_edges, "every edge must appear in some row"
    return order


def _renumber_edge_ids(ell: SlicedEll, inv_order: np.ndarray,
                       n_edges: int) -> SlicedEll:
    """Map every stored edge id through ``inv_order`` (reserved-slack
    ids [n_edges, pad_edge] — including the pad id itself — are fixed
    points)."""
    table = np.arange(ell.pad_edge + 1, dtype=np.int32)
    table[:n_edges] = inv_order
    table = jnp.asarray(table)
    return dataclasses.replace(
        ell, edge_ids=tuple(table[e] for e in ell.edge_ids))


def default_bucket_widths(max_deg: int) -> tuple[int, ...]:
    """Power-of-two widths 2, 4, ... capped by (and ending at) max_deg."""
    out, w = [], 2
    while w < max_deg:
        out.append(w)
        w *= 2
    out.append(max(max_deg, 1))
    return tuple(out)


def bucket_index(widths, slot_cnt: np.ndarray) -> np.ndarray:
    """The bucket of each row: the smallest width covering its slot
    count (zero-slot rows to the first bucket).  The single source of
    the assignment rule — ``build_sliced_ell`` and ``ShardPlan.build``
    must agree on it or forced bucket sizes desynchronize."""
    return np.searchsorted(np.asarray(widths), np.maximum(slot_cnt, 1))


def build_sliced_ell(nbrs: np.ndarray, nbr_mask: np.ndarray,
                     edge_ids: np.ndarray, is_src: np.ndarray,
                     pad_edge: int,
                     widths: Sequence[int] | None = None,
                     bucket_sizes: Sequence[int] | None = None,
                     slack: int = 0) -> SlicedEll:
    """Bucket host-side padded ELL arrays into a ``SlicedEll``.

    Each row goes to the smallest bucket whose width covers its real
    slot count (zero-slot rows to the first bucket); within a bucket,
    rows keep ascending id order.  ``bucket_sizes`` forces per-bucket
    row counts (padding with empty rows) — the ``ShardPlan`` uses this
    to keep bucket shapes uniform across shards; without it, empty
    buckets are dropped.  ``slack`` buckets each row as if it had
    ``slack`` extra slots, so every row's block keeps at least that
    many sentinel-padded free slots for in-place edge inserts
    (``insert_edges``, DESIGN.md §13) — the padding is bitwise-inert
    until an insert fills it, exactly like any other padded slot.
    """
    n_rows, d = nbrs.shape
    slot_cnt = nbr_mask.sum(axis=1)
    widths = tuple(widths) if widths is not None \
        else default_bucket_widths(int(d))
    assert widths[-1] >= ((int(slot_cnt.max()) + slack) if n_rows else 0), \
        "bucket ladder must cover every row's slot count + slack"
    bidx = bucket_index(widths, slot_cnt + slack)
    groups = [np.nonzero(bidx == b)[0] for b in range(len(widths))]

    if bucket_sizes is None:
        keep = [b for b in range(len(widths)) if len(groups[b])]
        keep = keep or [0]
        widths = tuple(widths[b] for b in keep)
        groups = [groups[b] for b in keep]
        sizes = [len(g) for g in groups]
    else:
        sizes = [int(s) for s in bucket_sizes]
        assert len(sizes) == len(widths)
        assert all(s >= len(g) for s, g in zip(sizes, groups))

    starts = (0, *np.cumsum(sizes).tolist())
    total = starts[-1]
    perm = np.full(total, n_rows, dtype=np.int32)
    inv_perm = np.zeros(n_rows, dtype=np.int32)
    bn, bm, be, bs = [], [], [], []
    for b, (g, w) in enumerate(zip(groups, widths)):
        nb = np.zeros((sizes[b], w), np.int32)
        mk = np.zeros((sizes[b], w), bool)
        ei = np.full((sizes[b], w), pad_edge, np.int32)
        sr = np.zeros((sizes[b], w), bool)
        if len(g):
            we = min(w, int(d))     # widths may overshoot the padded
            nb[: len(g), :we] = nbrs[g, :we]   # array when slack > 0
            mk[: len(g), :we] = nbr_mask[g, :we]
            ei[: len(g), :we] = edge_ids[g, :we]
            sr[: len(g), :we] = is_src[g, :we]
            perm[starts[b]: starts[b] + len(g)] = g
            inv_perm[g] = np.arange(starts[b], starts[b] + len(g))
        bn.append(jnp.asarray(nb))
        bm.append(jnp.asarray(mk))
        be.append(jnp.asarray(ei))
        bs.append(jnp.asarray(sr))
    return SlicedEll(
        widths=widths, starts=starts, n_rows=n_rows,
        max_deg=int(d), pad_edge=int(pad_edge),
        nbrs=tuple(bn), nbr_mask=tuple(bm), edge_ids=tuple(be),
        is_src=tuple(bs),
        perm=jnp.asarray(perm), inv_perm=jnp.asarray(inv_perm))


# ----------------------------------------------------------------------
# Hub splitting (DESIGN.md §10): virtual rows of width <= w_cap
# ----------------------------------------------------------------------

def default_w_cap(degrees) -> int:
    """``W_cap`` heuristic (DESIGN.md §10): the smallest power of two
    covering the 99th-percentile degree, clamped to [2, 64] — rows past
    the p99 knee split into chunks, the bulk stay single-chunk."""
    deg = np.asarray(degrees, dtype=np.int64)
    target = int(np.quantile(deg, 0.99)) if deg.size else 2
    w = 2
    while w < min(max(target, 2), 64):
        w *= 2
    return w


def candidate_width_plans(slot_cnt, max_deg: int) -> list[dict]:
    """Width-set candidates ``width_policy="measured"`` scores.

    One unsplit pow2-ladder plan plus one hub-split plan per legal
    ``w_cap`` in 4..64, each carrying the ``(width, rows)`` launch
    sequence a full bucket sweep would run under that ladder — computed
    from per-row real slot counts by the same chunking rule
    ``split_hub_rows`` applies (full ``w_cap``-wide chunks land in the
    top bucket, the remainder chunk in its covering bucket, zero-slot
    rows in bucket 0), so the estimate matches what a build would
    store.  Scoring-only: none of these plans is materialized.
    """
    cnt = np.maximum(np.asarray(slot_cnt, np.int64), 0)
    md = max(int(max_deg), 1)

    def launches(widths, counts):
        return tuple((int(w), int(c)) for w, c in zip(widths, counts) if c)

    widths = default_bucket_widths(md)
    counts = np.bincount(bucket_index(widths, cnt), minlength=len(widths))
    plans = [{"hub_split": False, "w_cap": None, "widths": widths,
              "launches": launches(widths, counts)}]
    cap = 4
    while cap < md and cap <= 64:
        wc = default_bucket_widths(cap)
        full, rem = cnt // cap, cnt % cap
        has_rem = (rem > 0) | (cnt == 0)
        counts = np.bincount(bucket_index(wc, rem[has_rem]),
                             minlength=len(wc))
        counts[-1] += int(full.sum())
        plans.append({"hub_split": True, "w_cap": cap, "widths": wc,
                      "launches": launches(wc, counts)})
        cap *= 2
    return plans


def choose_width_plan(slot_cnt, max_deg: int, cost_model) -> dict | None:
    """Cheapest candidate plan under a fitted cost model's predicted
    sweep time; ties keep the earlier candidate (the unsplit ladder
    comes first).  ``None`` when no candidate is predictable — callers
    fall back to the pow2 default, the zero-trace semantics."""
    best = None
    for plan in candidate_width_plans(slot_cnt, max_deg):
        t = cost_model.predict_launches(plan["launches"])
        if t is None:
            continue
        if best is None or t < best[0]:
            best = (t, plan)
    return None if best is None else best[1]


def split_hub_rows(nbrs: np.ndarray, nbr_mask: np.ndarray,
                   edge_ids: np.ndarray, is_src: np.ndarray,
                   pad_edge: int, w_cap: int):
    """Chunk padded-ELL rows into ``[n_virtual, w_cap]`` virtual rows.

    Row ``r`` with ``c`` real slots (slots are filled contiguously, so
    the mask is prefix-true) becomes ``ceil(c / w_cap)`` virtual rows —
    at least one — where chunk ``k`` holds owner slots
    ``[k*w_cap, (k+1)*w_cap)``.  Concatenating a row's chunks in order
    (and trimming to the owner width) restores the padded row bitwise:
    out-of-range columns carry the standard padding values.  Host-side,
    build-time only.  Returns ``(nbrs, mask, edge_ids, is_src, owner,
    vrow_offset)`` with ``owner`` int64 ``[n_virtual]`` and
    ``vrow_offset`` int64 ``[n + 1]``.
    """
    n, d = nbrs.shape
    slot_cnt = nbr_mask.sum(axis=1).astype(np.int64)
    nchunks = np.maximum(1, -(-slot_cnt // w_cap))
    vrow_offset = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(nchunks, out=vrow_offset[1:])
    owner = np.repeat(np.arange(n, dtype=np.int64), nchunks)
    chunk = np.arange(len(owner), dtype=np.int64) - vrow_offset[owner]
    cols = chunk[:, None] * w_cap + np.arange(w_cap, dtype=np.int64)
    valid = cols < d
    safe = np.minimum(cols, max(d - 1, 0))
    rows = owner[:, None]
    vn = np.where(valid, nbrs[rows, safe], 0).astype(np.int32)
    vm = valid & nbr_mask[rows, safe]
    ve = np.where(valid, edge_ids[rows, safe], pad_edge).astype(np.int32)
    vs = valid & is_src[rows, safe]
    return vn, vm, ve, vs, owner, vrow_offset


def build_split_ell(nbrs: np.ndarray, nbr_mask: np.ndarray,
                    edge_ids: np.ndarray, is_src: np.ndarray,
                    pad_edge: int, w_cap: int,
                    widths: Sequence[int] | None = None,
                    bucket_sizes: Sequence[int] | None = None,
                    n_virtual: int | None = None) -> SlicedEll:
    """Hub-split a padded ELL and bucket the virtual rows.

    The bucket ladder is ``default_bucket_widths(w_cap)`` — every full
    chunk is exactly ``w_cap`` wide, remainders land in their covering
    bucket — so the widest stored (and compiled) block is ``w_cap``
    regardless of skew.  ``bucket_sizes`` / ``n_virtual`` force uniform
    shapes across shards (``ShardPlan``): dummy virtual rows are empty,
    owned by the ``n`` sentinel, and land in bucket 0.
    """
    n, d = nbrs.shape
    vn, vm, ve, vs, owner, off = split_hub_rows(
        nbrs, nbr_mask, edge_ids, is_src, pad_edge, w_cap)
    if n_virtual is not None:
        extra = n_virtual - len(owner)
        assert extra >= 0, "n_virtual below actual virtual-row count"
        vn = np.concatenate([vn, np.zeros((extra, w_cap), np.int32)])
        vm = np.concatenate([vm, np.zeros((extra, w_cap), bool)])
        ve = np.concatenate([ve, np.full((extra, w_cap), pad_edge,
                                         np.int32)])
        vs = np.concatenate([vs, np.zeros((extra, w_cap), bool)])
        owner = np.concatenate([owner, np.full(extra, n, np.int64)])
    ell = build_sliced_ell(vn, vm, ve, vs, pad_edge=pad_edge,
                           widths=(default_bucket_widths(w_cap)
                                   if widths is None else widths),
                           bucket_sizes=bucket_sizes)
    return dataclasses.replace(
        ell, n_rows=n, max_deg=int(d), w_cap=int(w_cap),
        n_chunks_max=int((off[1:] - off[:-1]).max()) if n else 1,
        owner_of_vrow=jnp.asarray(owner, jnp.int32),
        vrow_offset=jnp.asarray(off, jnp.int32))


# ----------------------------------------------------------------------
# Padded-ELL builders (host side)
# ----------------------------------------------------------------------

def _build_ell_loop(n_vertices: int, edges: np.ndarray, md: int):
    """Reference per-edge-loop builder (the original ``from_edges``
    body).  Kept as the oracle for the vectorized builder — asserted
    identical in tests and raced in ``benchmarks/graph_storage.py``."""
    ne = len(edges)
    nbrs = np.zeros((n_vertices, md), dtype=np.int32)
    mask = np.zeros((n_vertices, md), dtype=bool)
    eids = np.full((n_vertices, md), ne, dtype=np.int32)  # pad edge
    is_src = np.zeros((n_vertices, md), dtype=bool)
    cursor = np.zeros(n_vertices, dtype=np.int64)
    us, vs = edges[:, 0], edges[:, 1]
    for e in range(ne):
        u, v = us[e], vs[e]
        cu, cv = cursor[u], cursor[v]
        nbrs[u, cu], mask[u, cu], eids[u, cu], is_src[u, cu] = v, True, e, True
        cursor[u] = cu + 1
        nbrs[v, cv], mask[v, cv], eids[v, cv] = u, True, e
        cursor[v] = cv + 1
    return nbrs, mask, eids, is_src


def _build_ell_vectorized(n_vertices: int, edges: np.ndarray, md: int):
    """Vectorized ELL build: lexsort/cumsum slot assignment, no Python
    per-edge loop.  Bit-identical to ``_build_ell_loop`` including its
    self-loop semantics (both endpoint writes share one slot; the
    later, non-src write wins; the cursor advances once).
    """
    ne = len(edges)
    nbrs = np.zeros((n_vertices, md), dtype=np.int32)
    mask = np.zeros((n_vertices, md), dtype=bool)
    eids = np.full((n_vertices, md), ne, dtype=np.int32)
    is_src = np.zeros((n_vertices, md), dtype=bool)
    if ne == 0:
        return nbrs, mask, eids, is_src

    flat_v = edges.reshape(-1)                    # u0, v0, u1, v1, ...
    # Slot of occurrence k = #prior occurrences of that vertex, counting
    # a self-loop's two occurrences once (the loop reads both cursors
    # before either write).  Rank within equal-vertex groups via a
    # stable sort, then subtract the running count of v-side self-loop
    # occurrences (inclusive: a self-loop's v side reuses the u slot).
    vside_selfloop = np.zeros(2 * ne, dtype=np.int64)
    vside_selfloop[1::2] = edges[:, 0] == edges[:, 1]
    order = np.argsort(flat_v, kind="stable")
    sv = flat_v[order]
    boundary = np.ones(2 * ne, dtype=bool)
    boundary[1:] = sv[1:] != sv[:-1]
    group_id = np.cumsum(boundary) - 1
    group_start = np.nonzero(boundary)[0]
    rank_sorted = np.arange(2 * ne) - group_start[group_id]
    cum = np.cumsum(vside_selfloop[order])
    before_group = np.concatenate([[0], cum])[group_start]
    slot_sorted = rank_sorted - (cum - before_group[group_id])
    slot = np.empty(2 * ne, dtype=np.int64)
    slot[order] = slot_sorted

    nbr_flat = edges[:, ::-1].reshape(-1)         # v0, u0, v1, u1, ...
    eid_flat = np.repeat(np.arange(ne, dtype=np.int64), 2)
    src_flat = np.tile(np.asarray([True, False]), ne)
    # duplicate (vertex, slot) pairs only arise from self-loops, where
    # both occurrences write identical nbrs/mask/eids values; is_src is
    # the one field where the sides differ, so force the loop builder's
    # outcome (the v-side write never touches is_src, leaving the
    # u-side True in place) explicitly instead of relying on
    # fancy-assignment ordering
    nbrs[flat_v, slot] = nbr_flat
    mask[flat_v, slot] = True
    eids[flat_v, slot] = eid_flat
    src_flat[1::2] = edges[:, 0] == edges[:, 1]
    is_src[flat_v, slot] = src_flat
    return nbrs, mask, eids, is_src


# ----------------------------------------------------------------------
@dataclasses.dataclass
class DataGraph:
    """Static graph structure + mutable vertex/edge data (device arrays)."""

    n_vertices: int
    n_edges: int
    max_deg: int
    # --- static structure: degree-bucketed sliced ELL ---
    ell: SlicedEll
    degree: jax.Array          # [Nv] int32
    # --- mutable user data ---
    vertex_data: PyTree        # leaves [Nv, ...]
    edge_data: PyTree          # leaves [n_edges + 1, ...] (last row = pad)
    # --- host-side copies of structure for partitioning / coloring ---
    edges_np: np.ndarray       # [n_edges, 2] int64 host copy
    # --- optional annotations ---
    colors: jax.Array | None = None   # [Nv] int32, attached by coloring.py
    n_colors: int = 0
    # --- bucket-major edge renumbering (edge-data locality) ---
    # edge_perm[new] = input-order edge id; edge_inv_perm[input] = new.
    # Identity when built with edge_locality=False.  ``edges_np`` and
    # all edge-data rows are stored in the *new* order.
    edge_perm: np.ndarray | None = None
    edge_inv_perm: np.ndarray | None = None
    # --- mutation slack (DESIGN.md §13) ---
    # Built with ``from_edges(slack=s)``: every adjacency row keeps >= s
    # sentinel-padded free slots and ``edge_capacity - n_edges`` edge
    # rows are reserved, so ``insert_edges`` can land new edges without
    # a global rebuild.  0 means frozen storage (the batch default).
    slack: int = 0

    @property
    def edge_capacity(self) -> int:
        """Edge rows the storage can address (== ``n_edges`` when built
        without slack).  The pad edge row sits at this index."""
        return self.ell.pad_edge

    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(
        n_vertices: int,
        edges: np.ndarray,
        vertex_data: PyTree,
        edge_data: PyTree = None,
        max_deg: int | None = None,
        bucket_widths: Sequence[int] | None = None,
        edge_locality: bool = True,
        hub_split: bool = False,
        w_cap: int | None = None,
        width_policy: str | None = None,
        cost_model=None,
        slack: int = 0,
        edge_capacity: int | None = None,
    ) -> "DataGraph":
        """Build the sliced-ELL structure from an undirected edge list.

        ``edges``: [Ne, 2] integer array, each row an undirected edge
        {u, v} (self loops and duplicates are the caller's business;
        both are handled but duplicates count twice toward degree).
        ``bucket_widths`` overrides the power-of-two degree buckets
        (mostly for tests; the default is ``default_bucket_widths``).
        ``edge_locality`` renumbers edge rows into bucket-major
        first-visit order (``bucket_major_edge_order``): per-bucket
        ``edge_ids`` gathers become nearly contiguous.  ``edge_data``
        must be given in the *input* edge order; it is permuted here,
        and ``edges_np`` / the stored edge rows use the new order
        (``edge_perm`` maps back).  Slot order within every adjacency
        row is untouched, so the renumbering is bitwise inert for any
        engine (asserted in ``tests/test_dispatch.py``).

        ``hub_split`` / ``w_cap`` enable hub splitting (DESIGN.md §10):
        rows wider than ``w_cap`` (a power of two >= 2; default
        ``default_w_cap`` of the degree distribution) are chunked into
        virtual rows so no stored block — and no compiled kernel — is
        wider than ``w_cap``.  Passing ``w_cap`` implies ``hub_split``.
        A graph whose max degree already fits ``w_cap`` stays unsplit.

        ``width_policy`` selects the bucket ladder itself (DESIGN.md
        §11): ``None``/``"pow2"`` is the default power-of-two ladder;
        ``"measured"`` scores every candidate ladder (unsplit pow2 and
        each hub-split ``w_cap`` variant) by a fitted cost model's
        predicted full-sweep time and builds the cheapest.
        ``cost_model`` is anything ``repro.profile.resolve_cost_model``
        accepts; unset, the device's persisted calibration is used, and
        with no calibration at all the policy degrades to the pow2
        default (the zero-trace fallback).

        ``slack`` (DESIGN.md §13) reserves >= ``slack`` sentinel-padded
        free slots in every adjacency row (the bucket ladder extends to
        ``max_deg + slack``) and ``edge_capacity - n_edges`` zeroed
        edge-data rows (default capacity ``n_edges + ceil(Nv*slack/2)``,
        the most inserts the slot slack could absorb), so
        ``insert_edges`` can land new edges in place.  The reserved
        slots/rows are ordinary padding — bitwise-inert until an insert
        fills them.  Slack is incompatible with hub splitting and
        ``width_policy="measured"`` (both choose ladders that leave no
        headroom) and with ``bucket_widths`` (the slack ladder is
        derived, not chosen).
        """
        if width_policy not in (None, "pow2", "measured"):
            raise ValueError(
                f"unknown width_policy {width_policy!r}: expected one "
                f"of (None, 'pow2', 'measured')")
        if cost_model is not None and width_policy != "measured":
            raise ValueError(
                "cost_model= only applies to width_policy='measured' "
                "(other policies never consult a model)")
        if width_policy == "measured" and (
                hub_split or w_cap is not None or bucket_widths is not None):
            raise ValueError(
                "width_policy='measured' chooses the bucket ladder "
                "itself; legal combinations: width_policy='measured' "
                "alone, or bucket_widths/hub_split/w_cap with the "
                "default policy")
        if w_cap is not None:
            legal = "a power of two >= 2 (e.g. 2, 4, ..., 64)"
            if not isinstance(w_cap, (int, np.integer)) or w_cap < 2 \
                    or (w_cap & (w_cap - 1)):
                raise ValueError(
                    f"w_cap={w_cap!r}: legal values are {legal}")
            hub_split = True
        if hub_split and bucket_widths is not None:
            raise ValueError(
                "hub_split uses the default_bucket_widths(w_cap) ladder; "
                "legal combinations: bucket_widths alone, or "
                "hub_split/w_cap alone")
        if isinstance(slack, bool) or not isinstance(slack, (int, np.integer)) \
                or slack < 0:
            raise ValueError(f"slack must be a non-negative int, got {slack!r}")
        if edge_capacity is not None and slack == 0:
            raise ValueError(
                "edge_capacity= only applies to slack > 0 graphs (a frozen "
                "graph stores exactly n_edges rows)")
        if slack and (hub_split or w_cap is not None
                      or width_policy == "measured" or bucket_widths is not None):
            raise ValueError(
                "slack= (mutable storage, DESIGN.md §13) is incompatible "
                "with hub_split/w_cap/width_policy='measured'/"
                "bucket_widths: those pick bucket ladders with no insert "
                "headroom; legal combinations: slack alone, or the "
                "frozen-storage options alone")
        edges = np.asarray(edges, dtype=np.int64)
        if edges.size == 0:
            edges = edges.reshape(0, 2)
        ne = len(edges)
        deg = np.zeros(n_vertices, dtype=np.int64)
        for col in (0, 1):
            np.add.at(deg, edges[:, col], 1)
        md = int(deg.max()) if ne else 1
        if max_deg is not None:
            if max_deg < md:
                raise ValueError(f"max_deg={max_deg} < actual max degree {md}")
            md = max_deg
        md = max(md, 1)

        nbrs, mask, eids, is_src = _build_ell_vectorized(
            n_vertices, edges, md)
        if width_policy == "measured":
            from repro.profile.model import (load_cost_model,
                                             resolve_cost_model)
            model = (resolve_cost_model(cost_model)
                     if cost_model is not None else load_cost_model())
            plan = (choose_width_plan(mask.sum(axis=1), md, model)
                    if model is not None else None)
            if plan is not None and plan["hub_split"]:
                hub_split, w_cap = True, plan["w_cap"]
        if hub_split and w_cap is None:
            w_cap = default_w_cap(np.maximum(deg, 1))
        if slack:
            # widen the padded arrays so every row (even a max-degree
            # one) keeps ``slack`` free columns, and point every padded
            # slot at the *capacity* pad row: edge ids [ne, capacity)
            # stay addressable for inserts.
            cap = (ne + -(-n_vertices * slack // 2)
                   if edge_capacity is None else int(edge_capacity))
            if cap < ne:
                raise ValueError(
                    f"edge_capacity={cap} < n_edges={ne}: capacity must "
                    "cover the edges already present")
            md = md + slack
            grow = ((0, 0), (0, md - nbrs.shape[1]))
            nbrs = np.pad(nbrs, grow)
            mask = np.pad(mask, grow)
            eids = np.where(mask, np.pad(eids, grow), cap)
            is_src = np.pad(is_src, grow)
            ell = build_sliced_ell(nbrs, mask, eids, is_src, pad_edge=cap,
                                   slack=slack)
        elif hub_split and md > w_cap:
            ell = build_split_ell(nbrs, mask, eids, is_src, pad_edge=ne,
                                  w_cap=int(w_cap))
        else:
            ell = build_sliced_ell(nbrs, mask, eids, is_src, pad_edge=ne,
                                   widths=bucket_widths)

        edge_data = {} if edge_data is None else edge_data
        if edge_locality and ne:
            order = bucket_major_edge_order(ell, ne)
            inv_order = np.empty(ne, dtype=np.int64)
            inv_order[order] = np.arange(ne)
            ell = _renumber_edge_ids(ell, inv_order, ne)
            edges = edges[order]
            sel = jnp.asarray(order)
            edge_data = jax.tree.map(lambda a: jnp.asarray(a)[sel],
                                     edge_data)
        else:
            order = np.arange(ne, dtype=np.int64)
            inv_order = order.copy()
        return DataGraph(
            n_vertices=n_vertices,
            n_edges=ne,
            max_deg=md,
            ell=ell,
            degree=jnp.asarray(deg, dtype=jnp.int32),
            vertex_data=jax.tree.map(jnp.asarray, vertex_data),
            # reserved edge rows (capacity - ne of them) then the pad
            # row last, all zeros: inserts fill reserved rows in order
            edge_data=_tree_pad_rows(edge_data, ell.pad_edge - ne + 1),
            edges_np=edges,
            edge_perm=order,
            edge_inv_perm=inv_order,
            slack=int(slack),
        )

    # -- structure access ----------------------------------------------
    @property
    def n_rows(self) -> int:
        """Row-id space / scatter sentinel (mirrors ``LocalStruct``)."""
        return self.n_vertices

    def struct_rows(self, ids: jax.Array,
                    width: int | None = None) -> EllRows:
        """Adjacency rows for a batch of vertex ids; ``width`` requests
        the window-snapped ``[B, W]`` materialization (see
        ``SlicedEll.rows``)."""
        return self.ell.rows(ids, width=width)

    def to_padded(self) -> EllRows:
        """Monolithic ``[Nv, max_deg]`` view (oracle / test escape hatch)."""
        return self.ell.to_padded()

    # ------------------------------------------------------------------
    def with_colors(self, colors: np.ndarray) -> "DataGraph":
        colors = np.asarray(colors)
        return dataclasses.replace(
            self,
            colors=jnp.asarray(colors, dtype=jnp.int32),
            n_colors=int(colors.max()) + 1 if colors.size else 1,
        )

    def replace_data(self, vertex_data=None, edge_data=None) -> "DataGraph":
        return dataclasses.replace(
            self,
            vertex_data=self.vertex_data if vertex_data is None else vertex_data,
            edge_data=self.edge_data if edge_data is None else edge_data,
        )

    # convenience -------------------------------------------------------
    @property
    def adjacency_lists(self) -> list[list[int]]:
        """Host-side adjacency (for coloring / partitioning / oracles)."""
        adj: list[list[int]] = [[] for _ in range(self.n_vertices)]
        for u, v in self.edges_np:
            adj[int(u)].append(int(v))
            adj[int(v)].append(int(u))
        return adj


# ----------------------------------------------------------------------
# Live mutations (DESIGN.md §13): slack inserts + compaction rebuild
# ----------------------------------------------------------------------

def _row_slot_counts(ell: SlicedEll) -> np.ndarray:
    """Real (mask-true) slots per row — the insert cursor position.

    Slots are filled contiguously (both builders and ``insert_edges``
    keep the mask prefix-true), so a row's next free column is exactly
    its slot count.  This is *not* the degree: a self-loop's two
    endpoint writes share one slot.
    """
    cnt = np.zeros(ell.n_rows, np.int64)
    for b in range(ell.n_buckets):
        rows = np.asarray(ell.perm[ell.starts[b]: ell.starts[b + 1]])
        real = rows < ell.n_rows
        slots = np.asarray(ell.nbr_mask[b]).sum(axis=1)
        np.add.at(cnt, rows[real], slots[real])
    return cnt


def insert_edges(graph: DataGraph, new_edges,
                 new_edge_data=None) -> DataGraph | None:
    """Land new undirected edges in reserved slack slots, no rebuild.

    Each new edge takes the next reserved edge row (ids ``n_edges``,
    ``n_edges + 1``, ...) and fills the next free slot of both endpoint
    rows — the same contiguous slot order ``from_edges`` would have
    produced had the edges been in the input list, so the `edge_perm`
    renumbering contract extends by identity (stored id == input-order
    id for inserted edges).  ``new_edge_data`` is a pytree of ``[k,
    ...]`` rows written into the reserved edge-data rows (left zero
    when omitted).

    Returns a new ``DataGraph`` (the input graph's arrays are never
    mutated — published snapshots stay immutable), or ``None`` when any
    endpoint's bucket row or the reserved edge rows are exhausted: the
    caller compacts with ``rebuild_compacted`` instead.  Self-loop
    inserts are rejected (the builders' shared-slot semantics would
    need cursor special-casing that no online workload has asked for).
    """
    ell = graph.ell
    if graph.slack <= 0:
        raise ValueError(
            "insert_edges needs mutable storage: build the graph with "
            "DataGraph.from_edges(slack=...) (DESIGN.md §13)")
    new_edges = np.asarray(new_edges, dtype=np.int64).reshape(-1, 2)
    k = len(new_edges)
    if k == 0:
        return graph
    if (new_edges[:, 0] == new_edges[:, 1]).any():
        raise ValueError("self-loop inserts are unsupported")
    if new_edges.min() < 0 or new_edges.max() >= graph.n_vertices:
        raise ValueError(
            f"edge endpoints must be in [0, {graph.n_vertices})")
    ne, cap = graph.n_edges, ell.pad_edge
    if ne + k > cap:
        return None
    starts = np.asarray(ell.starts)
    inv = np.asarray(ell.inv_perm)
    cnt = _row_slot_counts(ell)
    nb = [np.asarray(a).copy() for a in ell.nbrs]
    mk = [np.asarray(a).copy() for a in ell.nbr_mask]
    ei = [np.asarray(a).copy() for a in ell.edge_ids]
    sr = [np.asarray(a).copy() for a in ell.is_src]
    for i, (u, v) in enumerate(new_edges):
        eid = ne + i
        for r, other, src in ((int(u), int(v), True),
                              (int(v), int(u), False)):
            pos = int(inv[r])
            b = int(np.searchsorted(starts[1:], pos, side="right"))
            slot = int(cnt[r])
            if slot >= ell.widths[b]:
                return None        # bucket row full -> compact
            loc = pos - starts[b]
            nb[b][loc, slot] = other
            mk[b][loc, slot] = True
            ei[b][loc, slot] = eid
            sr[b][loc, slot] = src
            cnt[r] += 1
    deg = np.asarray(graph.degree, np.int64).copy()
    np.add.at(deg, new_edges[:, 0], 1)
    np.add.at(deg, new_edges[:, 1], 1)
    new_ell = dataclasses.replace(
        ell,
        nbrs=tuple(jnp.asarray(a) for a in nb),
        nbr_mask=tuple(jnp.asarray(a) for a in mk),
        edge_ids=tuple(jnp.asarray(a) for a in ei),
        is_src=tuple(jnp.asarray(a) for a in sr))
    edge_data = graph.edge_data
    if new_edge_data is not None and jax.tree.leaves(edge_data):
        rows = jnp.arange(ne, ne + k)
        edge_data = jax.tree.map(
            lambda d, n: d.at[rows].set(jnp.asarray(n, d.dtype)),
            edge_data, new_edge_data)
    fresh = np.arange(ne, ne + k, dtype=np.int64)
    return dataclasses.replace(
        graph,
        n_edges=ne + k,
        ell=new_ell,
        degree=jnp.asarray(deg, dtype=jnp.int32),
        edge_data=edge_data,
        edges_np=np.concatenate([graph.edges_np, new_edges]),
        edge_perm=np.concatenate([graph.edge_perm, fresh]),
        edge_inv_perm=np.concatenate([graph.edge_inv_perm, fresh]),
    )


def input_order_edges(graph: DataGraph):
    """Reconstruct the *input-order* edge list and edge data.

    ``edge_perm[stored] = input`` inverts the bucket-major renumbering
    (and any insert extensions), so feeding the result back through
    ``from_edges`` keeps every input-order edge id stable across a
    compaction — the contract queries-by-edge-id rely on.
    """
    ne = graph.n_edges
    edges_in = np.empty((ne, 2), dtype=np.int64)
    edges_in[graph.edge_perm] = graph.edges_np

    def back(a):
        a = np.asarray(a[:ne])
        out = np.empty_like(a)
        out[graph.edge_perm] = a
        return out

    return edges_in, jax.tree.map(back, graph.edge_data)


def rebuild_compacted(graph: DataGraph, extra_edges=None,
                      extra_edge_data=None, slack: int | None = None,
                      edge_capacity: int | None = None) -> DataGraph:
    """Full compaction rebuild: re-derive the sliced-ELL storage from
    the graph's cumulative input-order edge list (+ pending inserts
    that no longer fit in slack), carrying the current vertex/edge data
    and re-reserving fresh slack headroom.

    This is the slow path ``insert_edges`` falls back to; input-order
    edge ids are preserved (``input_order_edges``), colors are *not*
    re-derived — callers owning a coloring re-color the result.
    """
    edges_in, data_in = input_order_edges(graph)
    if extra_edges is not None and len(extra_edges):
        extra_edges = np.asarray(extra_edges, dtype=np.int64).reshape(-1, 2)
        kx = len(extra_edges)
        if extra_edge_data is None:
            extra_edge_data = jax.tree.map(
                lambda a: np.zeros((kx,) + a.shape[1:], a.dtype), data_in)
        edges_in = np.concatenate([edges_in, extra_edges])
        data_in = jax.tree.map(
            lambda a, b: np.concatenate([a, np.asarray(b, a.dtype)]),
            data_in, extra_edge_data)
    return DataGraph.from_edges(
        graph.n_vertices, edges_in,
        vertex_data=graph.vertex_data,
        edge_data=data_in,
        slack=graph.slack if slack is None else slack,
        edge_capacity=edge_capacity,
    )


def bipartite_edges(n_left: int, n_right: int, pairs: np.ndarray) -> tuple[int, np.ndarray]:
    """Helper: map (left_i, right_j) pairs to global vertex ids.

    Left vertices get ids [0, n_left), right vertices [n_left, n_left+n_right).
    Returns (n_vertices, edges).
    """
    pairs = np.asarray(pairs, dtype=np.int64)
    edges = np.stack([pairs[:, 0], pairs[:, 1] + n_left], axis=1)
    return n_left + n_right, edges


def grid_edges_3d(nx: int, ny: int, nz: int) -> tuple[int, np.ndarray]:
    """6-connected 3-D grid (the CoSeg super-pixel graph, paper §5.2)."""
    def vid(x, y, z):
        return (x * ny + y) * nz + z
    edges = []
    for x in range(nx):
        for y in range(ny):
            for z in range(nz):
                if x + 1 < nx:
                    edges.append((vid(x, y, z), vid(x + 1, y, z)))
                if y + 1 < ny:
                    edges.append((vid(x, y, z), vid(x, y + 1, z)))
                if z + 1 < nz:
                    edges.append((vid(x, y, z), vid(x, y, z + 1)))
    return nx * ny * nz, np.asarray(edges, dtype=np.int64)


def zipf_edges(n_vertices: int, alpha: float = 2.0,
               max_deg: int | None = None, seed: int = 0) -> np.ndarray:
    """Power-law degree graph via the configuration model.

    Samples Zipf(``alpha``) degrees (optionally clipped to ``max_deg``),
    pairs the half-edge stubs uniformly at random, then drops self loops
    and duplicate edges — the natural-graph skew of the paper's Netflix
    / NER workloads, and the regime the sliced-ELL layout targets.
    """
    rng = np.random.default_rng(seed)
    deg = rng.zipf(alpha, n_vertices)
    if max_deg is not None:
        deg = np.minimum(deg, max_deg)
    stubs = np.repeat(np.arange(n_vertices, dtype=np.int64), deg)
    rng.shuffle(stubs)
    pairs = stubs[: 2 * (len(stubs) // 2)].reshape(-1, 2)
    pairs = pairs[pairs[:, 0] != pairs[:, 1]]
    lo = np.minimum(pairs[:, 0], pairs[:, 1])
    hi = np.maximum(pairs[:, 0], pairs[:, 1])
    return np.unique(np.stack([lo, hi], axis=1), axis=0)
