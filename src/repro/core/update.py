"""Update functions and scopes (paper §3.2) in vectorized JAX form.

The paper's update function is ``Update : (v, S_v) -> (S_v, T)`` — a
stateless procedure over the scope of a single vertex that returns the
modified scope and a set of new tasks.  Under ``jit`` we execute a whole
*batch* of non-adjacent vertices at once (the engines guarantee
non-adjacency per the chosen consistency model), so the user writes the
same scope program but over a leading batch axis:

    def update(scope: ScopeBatch) -> UpdateResult: ...

Everything in ``ScopeBatch`` has a leading axis B = number of vertices in
the batch.  Padded neighbor slots have ``nbr_mask == False``; user code
must mask with it (exactly like the paper's user code must iterate only
real neighbors).

Task scheduling (the returned set T) is expressed by ``resched_self``
(schedule myself again) and ``resched_nbrs`` (schedule neighbor slots),
plus an optional ``priority`` used by the priority engine — this is the
paper's "reschedule neighbors only on substantial change" adaptivity.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


class Consistency(enum.Enum):
    """Paper §3.5 consistency models."""
    FULL = "full"        # exclusive R/W on whole scope  -> distance-2 coloring
    EDGE = "edge"        # R/W vertex+edges, R neighbors -> distance-1 coloring
    VERTEX = "vertex"    # R/W vertex only               -> single color
    UNSAFE = "unsafe"    # no guarantee (paper: "at their own risk")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScopeBatch:
    """The scopes S_v of a batch of vertices, materialized by gathers."""
    v_ids: jax.Array        # [B] int32 vertex ids
    v_data: PyTree          # [B, ...]      central vertex data (R/W)
    nbr_ids: jax.Array      # [B, D] int32
    nbr_mask: jax.Array     # [B, D] bool
    nbr_data: PyTree        # [B, D, ...]   adjacent vertex data (R; R/W if FULL)
    edge_data: PyTree       # [B, D, ...]   adjacent edge data (R/W if EDGE/FULL)
    is_src: jax.Array       # [B, D] bool   True iff v is endpoint 0 of slot edge
    degree: jax.Array       # [B] int32
    globals: dict           # latest sync-op results, keyed by SyncOp.key


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class UpdateResult:
    v_data: PyTree                       # [B, ...] new central vertex data
    edge_data: PyTree | None = None      # [B, D, ...] new adjacent edge data
    nbr_data: PyTree | None = None       # [B, D, ...] new adjacent vertex data (FULL only)
    resched_self: jax.Array | None = None   # [B] bool
    resched_nbrs: jax.Array | None = None   # [B, D] bool
    priority: jax.Array | None = None       # [B] float32 (priority engine)


@dataclasses.dataclass(frozen=True)
class UpdateFn:
    """An update function plus the consistency model it requires."""
    fn: Callable[[ScopeBatch], UpdateResult]
    consistency: Consistency = Consistency.EDGE
    name: str = "update"

    def __call__(self, scope: ScopeBatch) -> UpdateResult:
        return self.fn(scope)


# ----------------------------------------------------------------------
# Scope materialization: the gather (pull) half of the engine.
# ----------------------------------------------------------------------

def gather_scopes(graph_struct, vertex_data, edge_data, v_ids, globals_) -> ScopeBatch:
    """Materialize ScopeBatch for the vertex ids ``v_ids`` ([B] int32).

    ``graph_struct`` is anything exposing nbrs / nbr_mask / edge_ids /
    is_src / degree arrays (a DataGraph or a ShardedGraph local block).
    """
    nbrs = graph_struct.nbrs[v_ids]            # [B, D]
    mask = graph_struct.nbr_mask[v_ids]
    eids = graph_struct.edge_ids[v_ids]
    take_v = lambda a: a[v_ids]
    take_n = lambda a: a[nbrs]
    take_e = lambda a: a[eids]
    return ScopeBatch(
        v_ids=v_ids,
        v_data=jax.tree.map(take_v, vertex_data),
        nbr_ids=nbrs,
        nbr_mask=mask,
        nbr_data=jax.tree.map(take_n, vertex_data),
        edge_data=jax.tree.map(take_e, edge_data),
        is_src=graph_struct.is_src[v_ids],
        degree=graph_struct.degree[v_ids],
        globals=globals_,
    )


def scatter_result(
    graph_struct, vertex_data, edge_data, v_ids, valid, scope: ScopeBatch,
    result: UpdateResult,
):
    """Write back an UpdateResult (the push half).  ``valid`` masks padded
    batch rows.  Engines guarantee batches are conflict-free for the
    declared consistency model, so plain scatters are exact."""
    nv_total = jax.tree.leaves(vertex_data)[0].shape[0]
    safe_vids = jnp.where(valid, v_ids, nv_total)  # OOB sentinel -> dropped

    def put_v(dst, new):
        return dst.at[safe_vids].set(new, mode="drop")

    vertex_data = jax.tree.map(lambda d, n: put_v(d, n), vertex_data, result.v_data)

    if result.edge_data is not None:
        eids = graph_struct.edge_ids[v_ids]                      # [B, D]
        emask = scope.nbr_mask & valid[:, None]                  # [B, D]
        # route masked-off writes to the pad edge row
        pad = edge_data and jax.tree.leaves(edge_data)[0].shape[0] - 1
        safe_eids = jnp.where(emask, eids, pad)
        def put_e(dst, new):
            flat_ids = safe_eids.reshape(-1)
            flat_new = new.reshape((-1,) + new.shape[2:])
            return dst.at[flat_ids].set(flat_new, mode="drop")
        edge_data = jax.tree.map(lambda d, n: put_e(d, n), edge_data, result.edge_data)

    if result.nbr_data is not None:
        nbrs = scope.nbr_ids
        nmask = scope.nbr_mask & valid[:, None]
        nv = graph_struct.nbrs.shape[0]
        safe_nbrs = jnp.where(nmask, nbrs, nv)  # drop OOB
        def put_n(dst, new):
            flat_ids = safe_nbrs.reshape(-1)
            flat_new = new.reshape((-1,) + new.shape[2:])
            return dst.at[flat_ids].set(flat_new, mode="drop")
        vertex_data = jax.tree.map(lambda d, n: put_n(d, n), vertex_data, result.nbr_data)

    return vertex_data, edge_data
