"""Update functions and scopes (paper §3.2) in vectorized JAX form.

The paper's update function is ``Update : (v, S_v) -> (S_v, T)`` — a
stateless procedure over the scope of a single vertex that returns the
modified scope and a set of new tasks.  Under ``jit`` we execute a whole
*batch* of non-adjacent vertices at once (the engines guarantee
non-adjacency per the chosen consistency model), so the user writes the
same scope program but over a leading batch axis:

    def update(scope: ScopeBatch) -> UpdateResult: ...

Everything in ``ScopeBatch`` has a leading axis B = number of vertices in
the batch.  Padded neighbor slots have ``nbr_mask == False``; user code
must mask with it (exactly like the paper's user code must iterate only
real neighbors).

Task scheduling (the returned set T) is expressed by ``resched_self``
(schedule myself again) and ``resched_nbrs`` (schedule neighbor slots),
plus an optional ``priority`` used by the priority engine — this is the
paper's "reschedule neighbors only on substantial change" adaptivity.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


class Consistency(enum.Enum):
    """Paper §3.5 consistency models."""
    FULL = "full"        # exclusive R/W on whole scope  -> distance-2 coloring
    EDGE = "edge"        # R/W vertex+edges, R neighbors -> distance-1 coloring
    VERTEX = "vertex"    # R/W vertex only               -> single color
    UNSAFE = "unsafe"    # no guarantee (paper: "at their own risk")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScopeBatch:
    """The scopes S_v of a batch of vertices, materialized by gathers.

    The slot axis D is ``max_deg`` on the bucket dispatch path and the
    window's snapped bucket width ``W <= max_deg`` on the batch-shaped
    path (DESIGN.md §8) — user update functions must treat it as opaque
    (mask with ``nbr_mask``, reduce over the axis), never assume it
    equals the graph's ``max_deg``.
    """
    v_ids: jax.Array        # [B] int32 vertex ids
    v_data: PyTree          # [B, ...]      central vertex data (R/W)
    nbr_ids: jax.Array      # [B, D] int32
    nbr_mask: jax.Array     # [B, D] bool
    nbr_data: PyTree        # [B, D, ...]   adjacent vertex data (R; R/W if FULL)
    edge_data: PyTree       # [B, D, ...]   adjacent edge data (R/W if EDGE/FULL)
    e_ids: jax.Array        # [B, D] int32  slot edge ids (pad -> pad edge row)
    is_src: jax.Array       # [B, D] bool   True iff v is endpoint 0 of slot edge
    degree: jax.Array       # [B] int32
    globals: dict           # latest sync-op results, keyed by SyncOp.key


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class UpdateResult:
    v_data: PyTree                       # [B, ...] new central vertex data
    edge_data: PyTree | None = None      # [B, D, ...] new adjacent edge data
    nbr_data: PyTree | None = None       # [B, D, ...] new adjacent vertex data (FULL only)
    resched_self: jax.Array | None = None   # [B] bool
    resched_nbrs: jax.Array | None = None   # [B, D] bool
    priority: jax.Array | None = None       # [B] float32 (priority engine)


@dataclasses.dataclass(frozen=True)
class NeighborAggregator:
    """Declares an update as a *linear neighbor aggregation* (sweep form).

    Most of the paper's sweep workloads (PageRank Alg. 1, CoEM, the BSP
    baselines) reduce their neighborhood with one weighted sum

        y[v] = sum_j  w[v, j] * feature(D_{nbr(v, j)})

    followed by per-vertex post-processing.  Declaring that structure
    lets the executor skip the dense ``[B, D, F]`` scope gather and
    dispatch the sum through the ``kernels/ell_spmv`` Pallas kernel
    (DESIGN.md §4).

    * ``feature(vertex_data) -> [..., F]`` — the aggregated quantity.
      Must be a rowwise map (leading axes preserved): the executor
      applies it to ``[Nv, ...]`` vertex data for the kernel path and to
      ``[B, D, ...]`` gathered neighbor data for the dense fallback.
    * ``weight(scope) -> [B, D]`` — per-slot edge weights, computed from
      a lite scope (``nbr_data`` is None there — use edge data / masks).
    * ``combine(scope, y) -> UpdateResult`` — post-processing of the
      aggregate ``y [B, F]``; must not touch ``scope.nbr_data``.
    """
    feature: Callable[[PyTree], jax.Array]
    weight: Callable[["ScopeBatch"], jax.Array]
    combine: Callable[["ScopeBatch", jax.Array], "UpdateResult"]


@dataclasses.dataclass(frozen=True)
class UpdateFn:
    """An update function plus the consistency model it requires."""
    fn: Callable[[ScopeBatch], UpdateResult]
    consistency: Consistency = Consistency.EDGE
    name: str = "update"
    aggregator: NeighborAggregator | None = None

    def __call__(self, scope: ScopeBatch) -> UpdateResult:
        return self.fn(scope)


# ----------------------------------------------------------------------
# Slot-axis reductions shared by the dense and kernel update paths.
#
# Floating multiply-add chains are contraction-sensitive: whether the
# compiler fuses ``a*b + c`` into an FMA depends on the surrounding
# program, so writing "the same" fold twice (once in jnp, once in the
# kernel) does NOT give bitwise-equal results.  The dense fallback of an
# aggregator update therefore reduces its materialized scopes through
# ``kernels.ell_fold`` — the *same* kernel as the fast path, applied
# with trivial indices — which is the only robust way to make the two
# paths bit-identical (DESIGN.md §4).  Pure additions (``slot_fold_sum``)
# are contraction-safe and stay in plain jnp.
# ----------------------------------------------------------------------

def weighted_slot_fold(w: jax.Array, vals: jax.Array,
                       interpret: bool | None = None) -> jax.Array:
    """sum_j w[:, j] * vals[:, j] — w [B, D] (pre-masked), vals [B, D, F].

    Runs through the ``ell_spmv`` kernel's accumulation (interpret mode
    off-TPU).  Bitwise reproducibility holds between *same-shape*
    launches only (DESIGN.md §7): an update calling this helper gets
    identical bits on both engine dispatch paths because both call it
    with the same batch shapes — it is NOT bit-comparable against the
    fast path's per-bucket launches.
    """
    from repro.kernels.ell_spmv import ell_fold
    from repro.kernels.ops import default_interpret
    if interpret is None:
        interpret = default_interpret()
    return ell_fold(w, vals, interpret=interpret)


def slot_fold_sum(vals: jax.Array) -> jax.Array:
    """acc_j += vals[:, j] — left-fold sum over the slot axis (add-only,
    hence contraction-safe in any compilation context)."""
    acc = jnp.zeros(vals.shape[:1] + vals.shape[2:], jnp.float32)
    for j in range(vals.shape[1]):
        acc = acc + vals[:, j]
    return acc


def masked_neighbor_sum(weights: jax.Array, values: jax.Array,
                        mask: jax.Array) -> jax.Array:
    """sum_j mask*weights[:, j] * values[:, j] with kernel-grade
    (bit-stable) accumulation; values may be [B, D] or [B, D, F]."""
    w = jnp.where(mask, weights, 0.0).astype(jnp.float32)
    squeeze = values.ndim == 2
    vals = (values[..., None] if squeeze else values).astype(jnp.float32)
    y = weighted_slot_fold(w, vals)
    return y[..., 0] if squeeze else y


def aggregator_update(feature, weight, combine,
                      consistency: Consistency = Consistency.EDGE,
                      name: str = "aggregate") -> UpdateFn:
    """Build an UpdateFn from a NeighborAggregator declaration.

    The returned dense ``fn`` (used with fully materialized scopes, by
    the sequential oracle, and when the kernel path is disabled) derives
    from the *same* (feature, weight, combine) triple and reduces the
    dense scope through the same kernel arithmetic, so both paths agree
    bit-for-bit.
    """
    agg = NeighborAggregator(feature=feature, weight=weight, combine=combine)

    def dense_fn(scope: ScopeBatch) -> UpdateResult:
        w = jnp.where(scope.nbr_mask, weight(scope), 0.0).astype(jnp.float32)
        vals = feature(scope.nbr_data).astype(jnp.float32)
        return combine(scope, weighted_slot_fold(w, vals))

    return UpdateFn(dense_fn, consistency, name=name, aggregator=agg)


# ----------------------------------------------------------------------
# Scope materialization: the gather (pull) half of the engine.
# ----------------------------------------------------------------------

def gather_scopes(graph_struct, vertex_data, edge_data, v_ids, globals_,
                  with_nbr_data: bool = True, rows=None) -> ScopeBatch:
    """Materialize ScopeBatch for the vertex ids ``v_ids`` ([B] int32).

    ``graph_struct`` is anything exposing ``struct_rows(ids)`` /
    ``degree`` / ``n_rows`` (a DataGraph or a ShardPlan LocalStruct);
    the sliced-ELL storage materializes the adjacency rows per *batch*,
    so the scope shape is ``[B, max_deg]`` (or the window's snapped
    ``[B, W]`` on the batch dispatch path) whatever the bucketed layout
    underneath.  ``with_nbr_data=False`` produces a *lite* scope
    (``nbr_data=None``) for the aggregator fast path, skipping the
    [B, D, F] gather.  ``rows`` accepts the batch's already-
    materialized adjacency (e.g. the locking engine's claim pass
    gathered it, or a width-snapped gather) to share the bucketed-row
    gather and to set the scope's slot width.
    """
    if rows is None:
        rows = graph_struct.struct_rows(v_ids)
    nbrs, eids = rows.nbrs, rows.edge_ids      # [B, D]
    take_v = lambda a: a[v_ids]
    take_n = lambda a: a[nbrs]
    take_e = lambda a: a[eids]
    return ScopeBatch(
        v_ids=v_ids,
        v_data=jax.tree.map(take_v, vertex_data),
        nbr_ids=nbrs,
        nbr_mask=rows.nbr_mask,
        nbr_data=(jax.tree.map(take_n, vertex_data)
                  if with_nbr_data else None),
        edge_data=jax.tree.map(take_e, edge_data),
        e_ids=eids,
        is_src=rows.is_src,
        degree=graph_struct.degree[v_ids],
        globals=globals_,
    )


def scatter_result(
    graph_struct, vertex_data, edge_data, v_ids, valid, scope: ScopeBatch,
    result: UpdateResult,
):
    """Write back an UpdateResult (the push half).  ``valid`` masks padded
    batch rows.  Engines guarantee batches are conflict-free for the
    declared consistency model, so plain scatters are exact."""
    nv_total = jax.tree.leaves(vertex_data)[0].shape[0]
    safe_vids = jnp.where(valid, v_ids, nv_total)  # OOB sentinel -> dropped

    def put_v(dst, new):
        return dst.at[safe_vids].set(new, mode="drop")

    vertex_data = jax.tree.map(lambda d, n: put_v(d, n), vertex_data, result.v_data)

    if result.edge_data is not None:
        eids = scope.e_ids                                       # [B, D]
        emask = scope.nbr_mask & valid[:, None]                  # [B, D]
        # route masked-off writes to the pad edge row
        pad = edge_data and jax.tree.leaves(edge_data)[0].shape[0] - 1
        safe_eids = jnp.where(emask, eids, pad)
        def put_e(dst, new):
            flat_ids = safe_eids.reshape(-1)
            flat_new = new.reshape((-1,) + new.shape[2:])
            return dst.at[flat_ids].set(flat_new, mode="drop")
        edge_data = jax.tree.map(lambda d, n: put_e(d, n), edge_data, result.edge_data)

    if result.nbr_data is not None:
        nbrs = scope.nbr_ids
        nmask = scope.nbr_mask & valid[:, None]
        nv = graph_struct.n_rows
        safe_nbrs = jnp.where(nmask, nbrs, nv)  # drop OOB
        def put_n(dst, new):
            flat_ids = safe_nbrs.reshape(-1)
            flat_new = new.reshape((-1,) + new.shape[2:])
            return dst.at[flat_ids].set(flat_new, mode="drop")
        vertex_data = jax.tree.map(lambda d, n: put_n(d, n), vertex_data, result.nbr_data)

    return vertex_data, edge_data
