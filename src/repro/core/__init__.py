"""GraphLab abstraction in JAX — the paper's core contribution.

Public API:
    DataGraph, SlicedEll, bipartite_edges, grid_edges_3d, zipf_edges
    Consistency, UpdateFn, ScopeBatch, UpdateResult
    NeighborAggregator, aggregator_update, masked_neighbor_sum
    SyncOp, sum_sync, top_two_sync
    greedy_coloring, distance2_coloring, single_color, bipartite_coloring
    run, build_engine, EngineSpec, RunResult     (the repro.api facade)
    list_schedulers, register_scheduler          (the engine registry)
    ExecutorCore, ChromaticEngine, PriorityEngine, bsp_engine,
    LockingEngine, run_sequential                (deprecated direct path:
        prefer repro.api.run(..., scheduler=...) — DESIGN.md §9)
    two_phase_partition, random_partition
    ShardPlan, DistributedChromaticEngine, DistributedLockingEngine
"""
from repro.core.graph import (DataGraph, SlicedEll, bipartite_edges,
                              grid_edges_3d, zipf_edges)
from repro.core.update import (Consistency, NeighborAggregator, ScopeBatch,
                               UpdateFn, UpdateResult, aggregator_update,
                               gather_scopes, masked_neighbor_sum,
                               scatter_result)
from repro.core.sync import SyncOp, sum_sync, top_two_sync
from repro.core.coloring import (greedy_coloring, distance2_coloring,
                                 single_color, bipartite_coloring,
                                 verify_coloring)
from repro.core.exec import (EngineState, ExecutorCore, apply_batch,
                             choose_dispatch, claim_winners,
                             consume_and_reschedule, init_engine_state,
                             refresh_syncs, scope_claims,
                             switch_on_window_width)
from repro.core.engine_chromatic import ChromaticEngine
from repro.core.engine_priority import PriorityEngine
from repro.core.engine_bsp import bsp_engine
from repro.core.engine_sequential import run_sequential
from repro.core.partition import (two_phase_partition, random_partition,
                                  over_partition, build_meta_graph,
                                  balance_meta_graph, cut_edges)
from repro.core.distributed import ShardPlan, DistributedChromaticEngine
from repro.core.engine_locking import (DistributedLockingEngine,
                                       LockingEngine)
from repro.core.registry import (describe_schedulers, get_distributed,
                                 get_scheduler, list_schedulers,
                                 register_distributed, register_scheduler)

# The facade (repro.api) is re-exported lazily: api.py imports the
# engine modules above, so a module-level import here would be a cycle.
_API_NAMES = ("run", "build_engine", "EngineSpec", "RunResult", "api")


def __getattr__(name):
    if name in _API_NAMES:
        from repro import api
        return api if name == "api" else getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
