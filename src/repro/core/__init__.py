"""GraphLab abstraction in JAX — the paper's core contribution.

Public API:
    DataGraph, bipartite_edges, grid_edges_3d
    Consistency, UpdateFn, ScopeBatch, UpdateResult
    SyncOp, sum_sync, top_two_sync
    greedy_coloring, distance2_coloring, single_color, bipartite_coloring
    ChromaticEngine, PriorityEngine, bsp_engine, run_sequential
    two_phase_partition, random_partition
    ShardPlan, DistributedChromaticEngine
"""
from repro.core.graph import DataGraph, bipartite_edges, grid_edges_3d
from repro.core.update import (Consistency, ScopeBatch, UpdateFn,
                               UpdateResult, gather_scopes, scatter_result)
from repro.core.sync import SyncOp, sum_sync, top_two_sync
from repro.core.coloring import (greedy_coloring, distance2_coloring,
                                 single_color, bipartite_coloring,
                                 verify_coloring)
from repro.core.engine_chromatic import ChromaticEngine, EngineState
from repro.core.engine_priority import PriorityEngine
from repro.core.engine_bsp import bsp_engine
from repro.core.engine_sequential import run_sequential
from repro.core.partition import (two_phase_partition, random_partition,
                                  over_partition, build_meta_graph,
                                  balance_meta_graph, cut_edges)
from repro.core.distributed import ShardPlan, DistributedChromaticEngine
