"""The sync operation (paper §3.3): (Key, Fold, Merge, Finalize, acc0, tau).

Fold aggregates vertex data into an accumulator, Merge combines partial
accumulators (the paper's "Global Synchronous Reduce"), Finalize transforms
the final value, and the result is stored globally under Key for update
functions to read.  tau is the interval (in engine supersteps here; the
paper leaves the resolution to the implementation, see its footnote 2).

Fold must be expressible as a commutative-associative reduction for a
parallel implementation — the same requirement the paper's distributed
runtime imposes implicitly (Fold runs per-machine, Merge combines
machines).  We execute Fold as a ``lax.scan``-free tree reduction: first
``fold`` is applied to each vertex independently against ``acc0`` (a
"contribution"), then ``merge`` tree-reduces.  For the common map-reduce
style syncs (sums, top-k, error norms) this is exact and fast; a strictly
sequential Fold can be requested with ``sequential=True`` (lax.scan).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SyncOp:
    key: str
    fold: Callable[[PyTree, PyTree], PyTree]      # (acc, v_data_row) -> acc
    merge: Callable[[PyTree, PyTree], PyTree]     # (acc, acc') -> acc
    finalize: Callable[[PyTree], PyTree]          # acc -> result
    acc0: PyTree
    tau: int = 1            # run every `tau` supersteps
    sequential: bool = False

    def local_reduce(self, vertex_data: PyTree, valid: jax.Array | None = None) -> PyTree:
        """Fold+Merge over the local vertex set -> partial accumulator."""
        n = jax.tree.leaves(vertex_data)[0].shape[0]
        if self.sequential:
            def body(acc, row):
                vrow, ok = row
                new = self.fold(acc, vrow)
                if valid is not None:
                    new = jax.tree.map(lambda a, b: jnp.where(ok, a, b), new, acc)
                return new, None
            ok = valid if valid is not None else jnp.ones((n,), bool)
            acc, _ = jax.lax.scan(body, self.acc0, (vertex_data, ok))
            return acc
        # parallel path: per-vertex contribution then tree-reduce with merge
        contrib = jax.vmap(lambda row: self.fold(self.acc0, row))(vertex_data)
        if valid is not None:
            acc0_b = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n,) + jnp.shape(a)), self.acc0)
            contrib = jax.tree.map(
                lambda c, z: jnp.where(
                    valid.reshape((-1,) + (1,) * (c.ndim - 1)), c, z),
                contrib, acc0_b)

        def tree_reduce(c):
            m = jax.tree.leaves(c)[0].shape[0]
            while m > 1:
                half = m // 2
                a = jax.tree.map(lambda x: x[:half], c)
                b = jax.tree.map(lambda x: x[half:2 * half], c)
                merged = jax.vmap(self.merge)(a, b)
                if m % 2:
                    tail = jax.tree.map(lambda x: x[m - 1:m], c)
                    merged = jax.tree.map(
                        lambda x, t: jnp.concatenate([x, t], 0), merged, tail)
                c = merged
                m = half + (m % 2)
            return jax.tree.map(lambda x: x[0], c)

        return tree_reduce(contrib)

    def run(self, vertex_data: PyTree, valid: jax.Array | None = None) -> PyTree:
        return self.finalize(self.local_reduce(vertex_data, valid))


def sum_sync(key: str, value_fn: Callable[[PyTree], jax.Array], tau: int = 1,
             finalize: Callable | None = None, init=0.0) -> SyncOp:
    """Convenience constructor for the ubiquitous additive sync."""
    return SyncOp(
        key=key,
        fold=lambda acc, row: acc + value_fn(row),
        merge=lambda a, b: a + b,
        finalize=finalize or (lambda a: a),
        acc0=jnp.asarray(init, jnp.float32),
        tau=tau,
    )


def top_two_sync(key: str, rank_fn: Callable[[PyTree], jax.Array], id_fn=None,
                 tau: int = 1) -> SyncOp:
    """The paper's running example: second most popular page (§3.3).

    acc = (top2 values, top2 ids); Finalize extracts entry [1].
    """
    neg = jnp.asarray(-jnp.inf, jnp.float32)

    def fold(acc, row):
        vals, ids = acc
        r = rank_fn(row).astype(jnp.float32)
        i = (id_fn(row) if id_fn is not None else jnp.int32(-1))
        allv = jnp.concatenate([vals, r[None]])
        alli = jnp.concatenate([ids, jnp.asarray(i, jnp.int32)[None]])
        top, idx = jax.lax.top_k(allv, 2)
        return (top, alli[idx])

    def merge(a, b):
        allv = jnp.concatenate([a[0], b[0]])
        alli = jnp.concatenate([a[1], b[1]])
        top, idx = jax.lax.top_k(allv, 2)
        return (top, alli[idx])

    return SyncOp(
        key=key, fold=fold, merge=merge,
        finalize=lambda acc: (acc[0][1], acc[1][1]),
        acc0=(jnp.full((2,), neg), jnp.full((2,), -1, jnp.int32)),
        tau=tau,
    )
