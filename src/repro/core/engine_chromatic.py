"""The Chromatic Engine (paper §4.2.1) as a jitted SPMD superstep program.

Execution model (paper Alg. 2): while the task set T is non-empty, remove
and execute tasks.  The chromatic engine fixes RemoveNext to canonical
color order: all *active* vertices of color 0 update in parallel, then
color 1, ...  One sweep over all colors is a **superstep**.  Because no
two same-colored vertices are adjacent (distance-1 coloring -> edge
consistency; distance-2 -> full consistency), each color phase is
conflict-free and the whole execution is sequentially consistent
(Def. 3.1): it equals the sequential execution in (color, vertex-id)
order, which ``tests/test_consistency.py`` asserts bit-for-bit against a
pure-Python sequential executor.

The task set T is an ``active`` boolean mask (static shape); "add task"
is a masked scatter-OR, "remove task" clears the bit.  Termination =
``active.sum() == 0`` — a psum in the distributed engine, replacing the
paper's Misra-marker consensus (see DESIGN.md §2).

Sync operations run every ``tau`` supersteps between color phases, as the
paper prescribes ("the sync operation can be run safely between colors").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import DataGraph
from repro.core.sync import SyncOp
from repro.core.update import UpdateFn, gather_scopes, scatter_result

PyTree = Any


def build_color_batches(colors: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-color vertex-id lists into [n_colors, Cmax] (+valid mask)."""
    colors = np.asarray(colors)
    n_colors = int(colors.max()) + 1 if colors.size else 1
    groups = [np.nonzero(colors == c)[0] for c in range(n_colors)]
    cmax = max(1, max(len(g) for g in groups))
    ids = np.zeros((n_colors, cmax), dtype=np.int32)
    valid = np.zeros((n_colors, cmax), dtype=bool)
    for c, g in enumerate(groups):
        ids[c, : len(g)] = g
        valid[c, : len(g)] = True
    return ids, valid


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    vertex_data: PyTree
    edge_data: PyTree
    active: jax.Array        # [Nv] bool — the task set T
    priority: jax.Array      # [Nv] f32  — task priorities (used by priority engine)
    globals: dict            # sync results, keyed by SyncOp.key
    superstep: jax.Array     # i32
    n_updates: jax.Array     # i64-ish i32 total update-function applications


@dataclasses.dataclass
class ChromaticEngine:
    """Compiles (graph structure, update_fn, syncs) into a jitted runner."""

    graph: DataGraph
    update_fn: UpdateFn
    syncs: Sequence[SyncOp] = ()
    max_supersteps: int = 100

    def __post_init__(self):
        if self.graph.colors is None:
            raise ValueError("graph needs colors; call graph.with_colors(...)")
        ids, valid = build_color_batches(np.asarray(self.graph.colors))
        self._color_ids = jnp.asarray(ids)
        self._color_valid = jnp.asarray(valid)
        self.n_colors = ids.shape[0]

    # ------------------------------------------------------------------
    def init_state(self, active: jax.Array | None = None,
                   priority: jax.Array | None = None) -> EngineState:
        nv = self.graph.n_vertices
        if active is None:
            active = jnp.ones((nv,), bool)
        if priority is None:
            priority = active.astype(jnp.float32)
        globals_ = {s.key: s.run(self.graph.vertex_data) for s in self.syncs}
        return EngineState(
            vertex_data=self.graph.vertex_data,
            edge_data=self.graph.edge_data,
            active=active, priority=priority, globals=globals_,
            superstep=jnp.int32(0), n_updates=jnp.int32(0),
        )

    # ------------------------------------------------------------------
    def _color_phase(self, state: EngineState, c: jax.Array) -> EngineState:
        g = self.graph
        ids = self._color_ids[c]          # [Cmax]
        valid = self._color_valid[c]
        sel = valid & state.active[ids]
        scope = gather_scopes(g, state.vertex_data, state.edge_data, ids,
                              state.globals)
        res = self.update_fn(scope)
        vdata, edata = scatter_result(
            g, state.vertex_data, state.edge_data, ids, sel, scope, res)
        # -- task bookkeeping: consume executed tasks, add returned tasks.
        # Padded batch slots alias vertex 0; route them to an OOB sentinel
        # so duplicate-index scatters cannot clobber real writes.
        safe_ids = jnp.where(sel, ids, g.n_vertices)
        active = state.active.at[safe_ids].set(False, mode="drop")
        priority = state.priority.at[safe_ids].set(0.0, mode="drop")
        if res.resched_self is not None:
            re_self = sel & res.resched_self
            active = active.at[jnp.where(re_self, ids, g.n_vertices)].set(
                True, mode="drop")
        if res.resched_nbrs is not None:
            nmask = scope.nbr_mask & sel[:, None] & res.resched_nbrs
            safe = jnp.where(nmask, scope.nbr_ids, g.n_vertices)
            active = active.at[safe.reshape(-1)].max(
                nmask.reshape(-1), mode="drop")
            if res.priority is not None:
                # neighbors inherit the scheduling priority of the rescheduler
                pr = jnp.where(nmask, res.priority[:, None], -jnp.inf)
                priority = priority.at[safe.reshape(-1)].max(
                    pr.reshape(-1), mode="drop")
        if res.priority is not None and res.resched_self is not None:
            pr_self = jnp.where(sel & res.resched_self, res.priority, -jnp.inf)
            priority = priority.at[ids].max(pr_self)
        return dataclasses.replace(
            state, vertex_data=vdata, edge_data=edata, active=active,
            priority=priority, n_updates=state.n_updates + sel.sum(dtype=jnp.int32))

    def _superstep(self, state: EngineState) -> EngineState:
        state = jax.lax.fori_loop(
            0, self.n_colors, lambda c, s: self._color_phase(s, c), state)
        # sync ops between supersteps (== "between colors" safety, §4.2.1)
        new_globals = dict(state.globals)
        for s in self.syncs:
            due = (state.superstep + 1) % max(s.tau, 1) == 0
            fresh = s.run(state.vertex_data)
            new_globals[s.key] = jax.tree.map(
                lambda new, old: jnp.where(due, new, old),
                fresh, state.globals[s.key])
        return dataclasses.replace(
            state, globals=new_globals, superstep=state.superstep + 1)

    # ------------------------------------------------------------------
    @functools.cached_property
    def _run_jit(self):
        def cond(state):
            return (state.active.any()) & (state.superstep < self.max_supersteps)
        def run(state):
            return jax.lax.while_loop(cond, self._superstep, state)
        return jax.jit(run)

    def run(self, active: jax.Array | None = None,
            num_supersteps: int | None = None) -> EngineState:
        """Run to convergence of the task set (or max_supersteps)."""
        state = self.init_state(active)
        if num_supersteps is not None:
            step = jax.jit(self._superstep)
            for _ in range(num_supersteps):
                state = step(state)
            return state
        return self._run_jit(state)
