"""The Chromatic Engine (paper §4.2.1) as a scheduling strategy.

Execution model (paper Alg. 2): while the task set T is non-empty, remove
and execute tasks.  The chromatic engine fixes RemoveNext to canonical
color order: all *active* vertices of color 0 update in parallel, then
color 1, ...  One sweep over all colors is a **superstep**.  Because no
two same-colored vertices are adjacent (distance-1 coloring -> edge
consistency; distance-2 -> full consistency), each color phase is
conflict-free and the whole execution is sequentially consistent
(Def. 3.1): it equals the sequential execution in (color, vertex-id)
order, which ``tests/test_consistency.py`` asserts bit-for-bit against a
pure-Python sequential executor.

All engine machinery — the ``active`` task-set mask, OOB-sentinel
scatter bookkeeping, sync refresh, the jitted while-loop, termination
(``active.sum() == 0``; a psum in the distributed engine, replacing the
paper's Misra-marker consensus, see DESIGN.md §2), and the Pallas
aggregator fast path — lives in ``repro.core.exec``.  This class only
answers "which conflict-free batch runs in phase c?": the static
per-color vertex batches.

Sync operations run every ``tau`` supersteps between color phases, as the
paper prescribes ("the sync operation can be run safely between colors").
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

# Re-exported for backward compatibility: EngineState and the batch
# builder were born here and are imported from here by older call sites.
from repro.core.exec import (EngineState, ExecutorCore,  # noqa: F401
                             build_color_batches)
from repro.core.registry import register_scheduler


@dataclasses.dataclass
class ChromaticEngine(ExecutorCore):
    """Strategy: phase c = all active vertices of color c (static batches)."""

    # color batches sweep most of the graph: the per-bucket row launches
    # are the right (amortized) launch shape (DESIGN.md §8)
    dispatch: str = "bucket"

    def __post_init__(self):
        super().__post_init__()
        if self.graph.colors is None:
            raise ValueError("graph needs colors; call graph.with_colors(...)")
        ids, valid = build_color_batches(np.asarray(self.graph.colors))
        self._color_ids = jnp.asarray(ids)
        self._color_valid = jnp.asarray(valid)
        self.n_colors = ids.shape[0]
        self.n_phases = self.n_colors

    def select(self, c, ctx):
        return self._color_ids[c], self._color_valid[c]


register_scheduler(
    "chromatic", ChromaticEngine, needs_colors=True,
    description="static per-color sweeps (§4.2.1); sequentially "
                "consistent for the coloring's consistency model")
