"""Sequential reference executor — the oracle for Def. 3.1.

Executes update tasks strictly one at a time in the chromatic engine's
canonical (color, vertex-id) order, calling the *same* vectorized update
function with batch size 1.  A parallel engine is sequentially consistent
iff its resulting data graph equals this executor's bit-for-bit (for a
deterministic update function).  Used only in tests; intentionally
unjitted and simple.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import DataGraph
from repro.core.sync import SyncOp
from repro.core.update import UpdateFn, gather_scopes, scatter_result


def run_sequential(
    graph: DataGraph,
    update_fn: UpdateFn,
    syncs: Sequence[SyncOp] = (),
    active: np.ndarray | None = None,
    max_supersteps: int = 100,
):
    """Returns (vertex_data, edge_data, globals, n_updates)."""
    nv = graph.n_vertices
    colors = np.asarray(graph.colors)
    n_colors = int(colors.max()) + 1 if colors.size else 1
    per_color = [np.nonzero(colors == c)[0] for c in range(n_colors)]
    vdata, edata = graph.vertex_data, graph.edge_data
    act = np.ones(nv, bool) if active is None else np.asarray(active).copy()
    globals_ = {s.key: s.run(vdata) for s in syncs}
    n_updates = 0

    for step in range(max_supersteps):
        if not act.any():
            break
        for c in range(n_colors):
            # snapshot the phase's task selection exactly like the engine:
            # tasks added *during* phase c run no earlier than phase c+1.
            sel = [v for v in per_color[c] if act[v]]
            for v in sel:
                ids = jnp.asarray([v], jnp.int32)
                scope = gather_scopes(graph, vdata, edata, ids, globals_)
                res = update_fn(scope)
                valid = jnp.ones((1,), bool)
                vdata, edata = scatter_result(
                    graph, vdata, edata, ids, valid, scope, res)
                act[v] = False
                if res.resched_self is not None and bool(res.resched_self[0]):
                    act[v] = True
                if res.resched_nbrs is not None:
                    nmask = np.asarray(scope.nbr_mask[0] & res.resched_nbrs[0])
                    for j, nb in enumerate(np.asarray(scope.nbr_ids[0])):
                        if nmask[j]:
                            act[int(nb)] = True
                n_updates += 1
        for s in syncs:
            if (step + 1) % max(s.tau, 1) == 0:
                globals_[s.key] = s.run(vdata)
    return vdata, edata, globals_, n_updates
