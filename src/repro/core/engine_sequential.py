"""Sequential reference executor — the oracle for Def. 3.1.

Executes update tasks strictly one at a time, calling the *same*
vectorized update function with batch size 1.  A parallel engine is
sequentially consistent iff its resulting data graph equals this
executor's bit-for-bit (for a deterministic update function).  Used only
in tests; intentionally unjitted and simple.

The oracle replays each engine's RemoveNext policy (§3.4), so every
scheduling strategy of the shared executor core can be checked against
it:

* default            — the chromatic engine's canonical (superstep,
  color, vertex-id) order;
* ``k_select=K``     — the priority engine's order: each superstep
  selects the K highest-priority active vertices (stable ties by id,
  matching ``jax.lax.top_k``), then sweeps them color by color, with
  the same consume/reschedule priority bookkeeping as the engines;
* ``locking_pending=P`` — the locking engine's order: each superstep
  puts the P highest-priority active vertices in flight and executes
  the min-id claim winners under the update's consistency model
  (scope-disjoint for FULL, independent-set for EDGE, everybody for
  VERTEX/UNSAFE) — the replay of ``engine_locking``'s conflict pass;
* ``snapshot_phases``— gathers every phase's scopes from a snapshot
  taken at phase start.  For a proper coloring this changes nothing
  (same-phase vertices are non-adjacent); with the trivial single
  coloring it models the BSP engine's Jacobi semantics, which is how
  the BSP engine is validated (it is *not* sequentially consistent —
  the snapshot oracle is its ground truth instead).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import DataGraph
from repro.core.registry import register_scheduler
from repro.core.sync import SyncOp
from repro.core.update import (Consistency, UpdateFn, gather_scopes,
                               scatter_result)


def _locking_winners(cand: list[int], adj, consistency: Consistency,
                     nv: int) -> list[int]:
    """Replay of the engines' claim pass: min-id claim winners among the
    pending window ``cand`` under the update's consistency model."""
    if consistency == Consistency.FULL:
        claim = {}
        for v in cand:
            for x in [v] + adj[v]:
                claim[x] = min(claim.get(x, nv + 1), v)
        return [v for v in cand
                if claim[v] == v and all(claim[u] == v for u in adj[v])]
    if consistency == Consistency.EDGE:
        cset = set(cand)
        return [v for v in cand
                if all(u not in cset or u > v for u in adj[v])]
    return list(cand)       # VERTEX / UNSAFE: no conflicts


def run_sequential(
    graph: DataGraph,
    update_fn: UpdateFn,
    syncs: Sequence[SyncOp] = (),
    active: np.ndarray | None = None,
    max_supersteps: int = 100,
    k_select: int | None = None,
    locking_pending: int | None = None,
    snapshot_phases: bool = False,
    until=None,
    return_active: bool = False,
):
    """Returns (vertex_data, edge_data, globals, n_updates) —
    plus the final ``active`` task mask when ``return_active`` (how the
    facade surfaces ``RunResult.active_any`` without changing this
    function's long-standing 4-tuple).

    ``until(globals) -> bool`` is the facade's termination-by-sync
    predicate (paper §3.3 / DESIGN.md §9): evaluated before each
    superstep on the latest sync results, mirroring the engines'
    stepping loop (a predicate true at init executes nothing).
    """
    nv = graph.n_vertices
    if locking_pending is None:
        if graph.colors is None:
            raise ValueError(
                "sequential replay of color-ordered strategies needs a "
                "colored graph; call graph.with_colors(...) or pass "
                "locking_pending/max_pending for the colorless locking "
                "replay")
        colors = np.asarray(graph.colors)
        n_colors = int(colors.max()) + 1 if colors.size else 1
        per_color = [np.nonzero(colors == c)[0] for c in range(n_colors)]
    else:
        # the locking engine ignores colors: one conflict-resolved
        # phase per superstep
        colors, n_colors, per_color = None, 1, None
        adj = graph.adjacency_lists
    vdata, edata = graph.vertex_data, graph.edge_data
    act = np.ones(nv, bool) if active is None else np.asarray(active).copy()
    prio = act.astype(np.float32).copy()
    globals_ = {s.key: s.run(vdata) for s in syncs}
    n_updates = 0

    for step in range(max_supersteps):
        if not act.any():
            break
        # pre-step, like the facade's stepping loop: a predicate already
        # true on the current sync results executes no further tasks
        if until is not None and until(globals_):
            break
        winners = None
        if locking_pending is not None:
            # the locking engine's RemoveNext: pending window = top-P
            # active by priority (stable ties by id), then the min-id
            # claim winners execute as one conflict-free batch
            p = min(locking_pending, nv)
            score = np.where(act, prio, -np.inf)
            cand = [int(v) for v in np.argsort(-score, kind="stable")[:p]
                    if act[v]]
            winners = _locking_winners(cand, adj,
                                       update_fn.consistency, nv)
            chosen = None
        elif k_select is None:
            chosen = None
        else:
            # the priority engine's RemoveNext: top-k by priority with
            # stable ties by vertex id (jax.lax.top_k semantics)
            k = min(k_select, nv)
            score = np.where(act, prio, -np.inf)
            chosen = np.argsort(-score, kind="stable")[:k]
            chosen = chosen[act[chosen]]          # mask -inf rows out
        for c in range(n_colors):
            # snapshot the phase's task selection exactly like the engine:
            # tasks added *during* phase c run no earlier than phase c+1.
            if winners is not None:
                sel = winners
            elif chosen is None:
                sel = [v for v in per_color[c] if act[v]]
            else:
                sel = [int(v) for v in chosen if colors[v] == c and act[v]]
            gather_src = (vdata, edata) if snapshot_phases else None
            # the engines apply task bookkeeping at *batch* granularity:
            # every executed task is consumed, then all returned tasks
            # are OR/max-merged — so a reschedule raised by a same-phase
            # vertex survives the target's own consumption.  Collect the
            # phase's effects and apply them at phase end.
            consumed: list[int] = []
            resched: dict[int, float] = {}
            for v in sel:
                ids = jnp.asarray([v], jnp.int32)
                src_v, src_e = gather_src if snapshot_phases else (vdata, edata)
                scope = gather_scopes(graph, src_v, src_e, ids, globals_)
                res = update_fn(scope)
                valid = jnp.ones((1,), bool)
                vdata, edata = scatter_result(
                    graph, vdata, edata, ids, valid, scope, res)
                consumed.append(v)
                pr = (float(res.priority[0]) if res.priority is not None
                      else -np.inf)
                if res.resched_self is not None and bool(res.resched_self[0]):
                    resched[v] = max(resched.get(v, -np.inf), pr)
                if res.resched_nbrs is not None:
                    nmask = np.asarray(scope.nbr_mask[0] & res.resched_nbrs[0])
                    for j, nb in enumerate(np.asarray(scope.nbr_ids[0])):
                        if nmask[j]:
                            resched[int(nb)] = max(
                                resched.get(int(nb), -np.inf), pr)
                n_updates += 1
            for v in consumed:
                act[v] = False
                prio[v] = 0.0
            for u, pr in resched.items():
                act[u] = True
                if np.isfinite(pr):
                    prio[u] = max(prio[u], pr)
        for s in syncs:
            if (step + 1) % max(s.tau, 1) == 0:
                globals_[s.key] = s.run(vdata)
    if return_active:
        return vdata, edata, globals_, n_updates, act
    return vdata, edata, globals_, n_updates


class SequentialEngine:
    """The oracle as a registered strategy behind the ``repro.api``
    facade: ``scheduler="sequential"`` builds one of these, with the
    *same* keyword surface as the parallel engines it replays
    (``k_select`` replays the priority engine's RemoveNext,
    ``max_pending`` the locking engine's pending window,
    ``snapshot_phases`` the BSP engine's Jacobi semantics).

    Intentionally unjitted and stateless across runs, exactly like
    ``run_sequential`` — it exists so facade callers can flip a
    parallel run to its ground-truth replay by changing one string.
    """

    def __init__(self, graph: DataGraph, update_fn: UpdateFn,
                 syncs: Sequence[SyncOp] = (), max_supersteps: int = 100,
                 k_select: int | None = None,
                 max_pending: int | None = None,
                 snapshot_phases: bool = False):
        self.graph = graph
        self.update_fn = update_fn
        self.syncs = syncs
        self.max_supersteps = max_supersteps
        self.k_select = k_select
        self.max_pending = max_pending
        self.snapshot_phases = snapshot_phases

    def run(self, active: np.ndarray | None = None,
            num_supersteps: int | None = None, until=None):
        """Returns (vertex_data, edge_data, globals, n_updates,
        active) — ``run_sequential``'s tuple plus the final task mask,
        wrapped into a ``RunResult`` by the facade."""
        steps = (num_supersteps if num_supersteps is not None
                 else self.max_supersteps)
        return run_sequential(
            self.graph, self.update_fn, syncs=self.syncs, active=active,
            max_supersteps=steps, k_select=self.k_select,
            locking_pending=self.max_pending,
            snapshot_phases=self.snapshot_phases, until=until,
            return_active=True)


register_scheduler(
    "sequential", SequentialEngine,
    shared=("max_supersteps",),
    extras=("k_select", "max_pending", "snapshot_phases"),
    stepping=False,
    description="unjitted one-task-at-a-time oracle (Def. 3.1); replays "
                "chromatic / priority (k_select) / locking (max_pending) "
                "/ BSP (snapshot_phases) RemoveNext orders")
