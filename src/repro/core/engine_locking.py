"""The Distributed Locking Engine (paper §4.2.2) — and its single-device
strategy form — realized as data-parallel conflict resolution.

The paper's second distributed engine generalizes to graphs where a
coloring is unavailable: every vertex update acquires reader/writer
locks over its scope in canonical (vertex-id) order, and *pipelines* up
to ``maxpending`` lock acquisitions per machine to hide wire latency.
Distributed GraphLab (arXiv:1204.6078) later made this the default
engine.  On an SPMD mesh there are no remote mutexes; the equivalent
deterministic structure (DESIGN.md §6) is:

1. **Pending window** ("lock pipeline"): each shard keeps up to
   ``max_pending`` highest-priority active owned vertices in flight —
   the paper's ``maxpending`` scope acquisitions per machine.
2. **Claim pass**: every in-flight vertex min-scatters its *global* id
   onto the rows it would lock — the whole scope under FULL consistency
   (``scope_claims``: write locks everywhere), only its own row under
   EDGE (``self_claims``: read locks are compatible, so only adjacency
   conflicts).  Shards min-combine claims on replicated rows over the
   symmetric ``tsend/trecv`` channel (ghost -> owner -> ghost).
3. **Winner batch** (``claim_winners`` / ``adjacent_claim_winners``): a
   vertex executes only if it holds the min-id claim over its lock set.
   FULL winners have pairwise-disjoint scopes; EDGE winners form an
   independent set (the chromatic engine's per-phase guarantee) — either
   way the batch is serializable (sequential consistency, Def. 3.1),
   and the globally minimal in-flight vertex always wins: min-id
   ordering is the deadlock-free canonical lock order, with
   livelock-freedom by the same argument.
4. **Versioned ghost sync**: per-vertex version counters bump on every
   execution; the ``all_to_all`` ghost push carries a freshness bit per
   scheduled row and the receiver applies only rows modified since its
   last refresh — the paper's "only transmit modified data", replacing
   the chromatic engine's static per-color schedule.  (SPMD buffers are
   static-width, so the saving is counted, not shrunk: the engine
   reports ``ghost_rows_sent`` vs the unfiltered ``ghost_rows_full``.)

Losers stay active and retry next round; their locks are "released"
simply by the claim array being rebuilt from scratch each superstep.

``LockingEngine`` is the single-device degenerate case expressed as an
``ExecutorCore`` scheduling strategy (so it shares every line of
bookkeeping with chromatic/priority/BSP and is checked by the same
sequential-consistency oracle).  ``DistributedLockingEngine`` runs the
identical program per shard under ``shard_map`` — with a saturating
window (``max_pending >= rows``) the two are bit-identical on any mesh
size, which ``tests/test_locking.py`` asserts on 8 virtual devices.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.distributed import (ShardPlan, make_dist_sync_run,
                                    task_backflow)
from repro.core.exec import (NO_CLAIM, ExecutorCore,
                             adjacent_claim_winners, apply_batch,
                             choose_dispatch, claim_winners,
                             default_interpret, refresh_syncs,
                             scope_claims, self_claims,
                             switch_on_window_width, validate_dispatch)
from repro.core.graph import DataGraph
from repro.core.registry import register_distributed, register_scheduler
from repro.core.sync import SyncOp
from repro.core.update import Consistency, UpdateFn

PyTree = Any


def conflict_winners(struct, ids, sel, consistency: Consistency,
                     claim_ids=None, combine=None, rows=None):
    """Reader/writer lock grant as one claim scatter + one check.

    The claim pattern mirrors the paper's lock table per consistency
    model: FULL write-locks the whole scope (``scope_claims`` -> scope-
    disjoint winners), EDGE write-locks only the vertex while read locks
    are compatible (``self_claims`` -> independent-set winners), and
    VERTEX/UNSAFE scopes never conflict (every candidate wins).
    ``combine`` is the distributed engine's cross-shard min-combine of
    the claim array (identity when None / single shard).  ``rows`` is
    the candidates' materialized adjacency — one bucketed-row gather
    shared by the claim scatter and the winner check.
    """
    if consistency == Consistency.FULL:
        rows = struct.struct_rows(ids) if rows is None else rows
        claim = scope_claims(struct, ids, sel, claim_ids, rows=rows)
        if combine is not None:
            claim = combine(claim)
        return claim_winners(struct, ids, sel, claim, claim_ids, rows=rows)
    if consistency == Consistency.EDGE:
        rows = struct.struct_rows(ids) if rows is None else rows
        claim = self_claims(struct, ids, sel, claim_ids)
        if combine is not None:
            claim = combine(claim)
        return adjacent_claim_winners(struct, ids, sel, claim, claim_ids,
                                      rows=rows)
    return sel      # VERTEX / UNSAFE: no inter-vertex conflicts


def conflict_winners_windowed(struct, ids, sel, consistency: Consistency,
                              claim_ids=None, combine=None):
    """``conflict_winners`` at the window's snapped bucket width.

    The batch-shaped claim pass (DESIGN.md §8): candidate adjacency is
    gathered at ``[P, W]`` where ``W`` is the pending window's max
    bucket width, instead of the ``[P, max_deg]`` materialization the
    bucket path shares with its dispatch — the last place the old full
    width shape leaked into small-window execution.

    Without a ``combine`` (single device), one width switch wraps the
    whole pass, sharing a single ``[P, W]`` gather between claim
    scatter and winner check exactly like the bucket path's ``rows=``.
    With a ``combine``, the claim array — ``[n_rows]`` whatever the
    width — must cross shards between scatter and check, so the
    collective runs *between* two width switches (each gathering its
    own ``[P, W]`` rows); shards may resolve different widths
    independently because the switch branches are collective-free.
    """
    if consistency not in (Consistency.FULL, Consistency.EDGE):
        return sel      # VERTEX / UNSAFE: no inter-vertex conflicts
    if combine is None:
        def at_width(w):
            def f(_):
                rows = struct.struct_rows(ids, width=w)
                return conflict_winners(struct, ids, sel, consistency,
                                        claim_ids, rows=rows)
            return f
        return switch_on_window_width(struct.ell, ids, sel, at_width,
                                      jnp.int32(0))
    if consistency == Consistency.FULL:
        def claim_at(w):
            def f(_):
                rows = struct.struct_rows(ids, width=w)
                return scope_claims(struct, ids, sel, claim_ids, rows=rows)
            return f
        claim = combine(switch_on_window_width(struct.ell, ids, sel,
                                               claim_at, jnp.int32(0)))

        def win_at(w):
            def f(claim):
                rows = struct.struct_rows(ids, width=w)
                return claim_winners(struct, ids, sel, claim, claim_ids,
                                     rows=rows)
            return f
        return switch_on_window_width(struct.ell, ids, sel, win_at, claim)
    # EDGE: self claims touch no adjacency (width-independent by nature)
    claim = combine(self_claims(struct, ids, sel, claim_ids))

    def win_at(w):
        def f(claim):
            rows = struct.struct_rows(ids, width=w)
            return adjacent_claim_winners(struct, ids, sel, claim,
                                          claim_ids, rows=rows)
        return f
    return switch_on_window_width(struct.ell, ids, sel, win_at, claim)


@dataclasses.dataclass
class LockingEngine(ExecutorCore):
    """Strategy: top-``max_pending`` pending window, min-id claim winners.

    Needs no coloring — conflict resolution is dynamic.  ``max_pending``
    is the real lock-pipeline knob of the paper's Fig. 8(b) sweep: with
    P = 1 execution is strictly sequential (one scope in flight), larger
    P admits more concurrent winners per round.
    """

    max_supersteps: int = 2000
    max_pending: int = 64       # P: in-flight scope acquisitions
    # "auto" (DESIGN.md §8): small pending windows get the window-shaped
    # [P, W] claim pass and kernel launches; a saturating window
    # (max_pending ~ Nv) keeps the per-bucket row launches
    dispatch: str = "auto"

    def __post_init__(self):
        super().__post_init__()
        self.n_phases = 1

    def prepare(self, state):
        p = min(self.max_pending, self.graph.n_vertices)
        score = jnp.where(state.active, state.priority, -jnp.inf)
        _, cand = jax.lax.top_k(score, p)           # [P] pending window
        cand_sel = state.active[cand]
        mode = self.resolve_dispatch(p)
        if mode == "batch":
            win = conflict_winners_windowed(self.graph, cand, cand_sel,
                                            self.update_fn.consistency)
        else:
            win = conflict_winners(self.graph, cand, cand_sel,
                                   self.update_fn.consistency)
        return cand, win

    def select(self, c, ctx):
        return ctx


# ======================================================================
@dataclasses.dataclass
class DistributedLockingEngine:
    """Locking engine over a 1-D device mesh via shard_map.

    Per superstep and shard: pending window -> claim pass (+ cross-shard
    min-combine) -> winner batch through the shared ``apply_batch`` ->
    version bump -> versioned ghost/edge sync -> task backflow.  The
    single-shard plan (M=1) is the degenerate case: every exchange is an
    identity collective and the program equals ``LockingEngine``
    bit-for-bit.
    """

    graph: DataGraph
    plan: ShardPlan
    update_fn: UpdateFn
    syncs: Sequence[SyncOp] = ()
    max_supersteps: int = 2000
    max_pending: int = 64
    exchange_edges: bool = False   # app writes edge data on cut edges?
    axis: str = "shard"
    use_kernel: bool = True                 # aggregator fast path on?
    kernel_interpret: bool | None = None    # None -> auto (off-TPU: True)
    # "auto" (DESIGN.md §8): small per-shard pending windows get the
    # batch-shaped claim pass and [P, W] launches; saturating windows
    # keep the per-bucket row launches
    dispatch: str = "auto"
    # fitted launch-time model for dispatch="auto" (DESIGN.md §11)
    cost_model: Any = None

    def __post_init__(self):
        validate_dispatch(self.dispatch)
        if (self.update_fn.consistency == Consistency.FULL
                and self.plan.M > 1):
            # FULL neighbor writes land on ghost rows; there is no
            # ghost->owner data backflow (same limitation as the
            # distributed chromatic engine) — fail loudly rather than
            # silently dropping writes at shard boundaries.
            raise ValueError(
                "FULL-consistency neighbor writes are not supported "
                "across shards (ghost-row writes cannot flow back to "
                "the owner); use the single-shard LockingEngine")
        devs = jax.devices()
        if len(devs) < self.plan.M:
            raise ValueError(f"need {self.plan.M} devices, have {len(devs)}")
        self.mesh = Mesh(np.array(devs[: self.plan.M]), (self.axis,))

    # -- per-shard program (runs under shard_map; leading dim 1) --------
    def _build_superstep(self):
        plan, upd, axis = self.plan, self.update_fn, self.axis
        M, R, E_loc = plan.M, plan.R, plan.E_loc
        interpret = (self.kernel_interpret if self.kernel_interpret
                     is not None else default_interpret())
        use_kernel = self.use_kernel
        P_win = min(self.max_pending, R)
        exchange_edges = self.exchange_edges
        syncs = self.syncs
        consistency = self.update_fn.consistency
        mode = choose_dispatch(self.dispatch, P_win,
                               plan.ell_widths[-1], plan.sliced_slots,
                               cost_model=self.cost_model,
                               bucket_launches=plan.bucket_launches)

        def a2a(x):
            return jax.lax.all_to_all(x, axis, 0, 0, tiled=True)

        def combine_claims(claim, plan_b):
            """Min-combine claims across replicas: ghost -> owner, then
            the combined value back owner -> ghost (same Hg channel)."""
            tsidx, tsmask = plan_b["tsend_idx"], plan_b["tsend_mask"]
            tridx = plan_b["trecv_idx"]
            up = jnp.where(tsmask, claim[jnp.where(tsmask, tsidx, 0)],
                           NO_CLAIM)
            claim = claim.at[tridx.reshape(-1)].min(
                a2a(up).reshape(-1), mode="drop")
            tr_ok = tridx < R
            down = jnp.where(tr_ok, claim[jnp.where(tr_ok, tridx, 0)],
                             NO_CLAIM)
            return claim.at[jnp.where(tsmask, tsidx, R).reshape(-1)].min(
                a2a(down).reshape(-1), mode="drop")

        def push_ghost_versioned(vdata, version, sent_ver, plan_b):
            """Owner -> ghost data push carrying only modified rows.

            ``sent_ver[j, t]`` is the owner-side version last shipped to
            peer j for schedule slot t; a row travels (and is applied)
            only when its version advanced — the paper's "only transmit
            modified data" with the static schedule as the transport."""
            tsidx, tsmask = plan_b["tsend_idx"], plan_b["tsend_mask"]
            tridx = plan_b["trecv_idx"]
            tr_ok = tridx < R
            tr_safe = jnp.where(tr_ok, tridx, 0)
            ver = jnp.where(tr_ok, version[tr_safe], 0)
            fresh = tr_ok & (ver > sent_ver)                  # [M, Hg]
            fresh_r = a2a(fresh.astype(jnp.int32)) > 0
            tgt = jnp.where(tsmask & fresh_r, tsidx, R)
            def push(arr):
                buf = a2a(arr[tr_safe])                       # [M, Hg, ...]
                return arr.at[tgt.reshape(-1)].set(
                    buf.reshape((-1,) + buf.shape[2:]), mode="drop")
            vdata = jax.tree.map(push, vdata)
            sent_ver = jnp.where(fresh, ver, sent_ver)
            return (vdata, sent_ver, fresh.sum(dtype=jnp.int32),
                    tr_ok.sum(dtype=jnp.int32))

        def push_edges_versioned(edata, eversion, esent_ver, plan_b):
            """Cut-edge replica push, version-filtered like the vertex
            path (an edge's version bumps when its owned endpoint ran)."""
            ceidx, cemask = plan_b["cesend_idx"], plan_b["cesend_mask"]
            cridx = plan_b["cerecv_idx"]
            ever = jnp.where(cemask, eversion[ceidx], 0)
            fresh = cemask & (ever > esent_ver)               # [M, Hc]
            fresh_r = a2a(fresh.astype(jnp.int32)) > 0
            tgt = jnp.where(fresh_r, cridx, E_loc + 1)        # OOB drop
            def push(arr):
                buf = a2a(arr[ceidx])
                return arr.at[tgt.reshape(-1)].set(
                    buf.reshape((-1,) + buf.shape[2:]), mode="drop")
            edata = jax.tree.map(push, edata)
            esent_ver = jnp.where(fresh, ever, esent_ver)
            return edata, esent_ver

        def superstep(state, struct, plan_b):
            (vdata, edata, active, priority, globals_, step, n_upd,
             version, eversion, sent_ver, esent_ver, sent, full) = state
            owned = plan_b["owned_mask"]
            gids = plan_b["global_ids"]

            # 1. pending window: the shard's lock pipeline
            score = jnp.where(active & owned, priority, -jnp.inf)
            _, cand = jax.lax.top_k(score, P_win)
            cand_sel = (active & owned)[cand]

            # 2-3. claim pass + cross-shard combine -> winner batch.
            # Batch mode gathers candidate adjacency at the window's
            # snapped bucket width (collectives stay between the width
            # switches); bucket mode shares one full-width gather
            # across claim pass and dispatch.
            if mode == "batch":
                cand_rows = None
                win = conflict_winners_windowed(
                    struct, cand, cand_sel, consistency,
                    claim_ids=gids[cand],
                    combine=lambda c: combine_claims(c, plan_b))
            else:
                cand_rows = struct.struct_rows(cand)
                win = conflict_winners(
                    struct, cand, cand_sel, consistency,
                    claim_ids=gids[cand],
                    combine=lambda c: combine_claims(c, plan_b),
                    rows=cand_rows)

            # 4. execute winners through the shared executor core
            # (reusing the claim pass's materialized candidate rows)
            carry = (vdata, edata, active, priority, n_upd)
            carry = apply_batch(
                struct, upd, carry, cand, win, globals_, sentinel=R,
                use_kernel=use_kernel, interpret=interpret, rows=cand_rows,
                dispatch=mode)
            vdata, edata, active, priority, n_upd = carry

            # 5. version bumps for executed rows (and their edges)
            version = version.at[jnp.where(win, cand, R)].add(
                1, mode="drop")
            if exchange_edges:
                def bump_eversion(rows, ev):
                    emask = rows.nbr_mask & win[:, None]
                    return ev.at[jnp.where(emask, rows.edge_ids,
                                           E_loc + 1).reshape(-1)].add(
                                               1, mode="drop")
                if mode == "batch":
                    def bump_at(w):
                        def f(ev):
                            rows = struct.struct_rows(cand, width=w)
                            return bump_eversion(rows, ev)
                        return f
                    eversion = switch_on_window_width(
                        struct.ell, cand, win, bump_at, eversion)
                else:
                    eversion = bump_eversion(cand_rows, eversion)

            # 6. versioned ghost/edge sync
            vdata, sent_ver, n_fresh, n_full = push_ghost_versioned(
                vdata, version, sent_ver, plan_b)
            sent, full = sent + n_fresh, full + n_full
            if exchange_edges:
                edata, esent_ver = push_edges_versioned(
                    edata, eversion, esent_ver, plan_b)

            # 7. task backflow (ghost flags/priority -> owner)
            active, priority = task_backflow(active, priority, plan_b,
                                             axis, R)

            new_globals = refresh_syncs(
                syncs, globals_, vdata, step,
                run_fn=make_dist_sync_run(axis, M, owned))
            return (vdata, edata, active, priority, new_globals,
                    step + 1, n_upd, version, eversion, sent_ver,
                    esent_ver, sent, full)

        return superstep

    # ------------------------------------------------------------------
    # Carry-based execution (mirrors DistributedChromaticEngine): the
    # carry additionally holds the versioned-ghost-sync state — vertex
    # and edge version counters plus the owner-side sent-version tables
    # — which is exactly why sharded snapshots (repro.ft) must save
    # them: dropping them would re-ship (or worse, skip) ghost rows
    # after a restore and break bitwise resume.
    # ------------------------------------------------------------------

    def init_carry(self, active: np.ndarray | None = None) -> dict:
        plan = self.plan
        nv = self.graph.n_vertices
        vdata0 = plan.shard_vertex_data(self.graph.vertex_data)
        edata_global = jax.tree.map(lambda a: a[:-1], self.graph.edge_data)
        edata0 = plan.shard_edge_data(edata_global)
        if active is None:
            active = np.ones(nv, bool)
        act0 = plan.shard_vertex_data({"a": jnp.asarray(active)})["a"] \
            & plan.owned_mask
        M, R, E_loc, Hg, Hc = plan.M, plan.R, plan.E_loc, plan.Hg, plan.Hc
        return dict(
            vertex_data=vdata0, edge_data=edata0, active=act0,
            priority=act0.astype(jnp.float32),
            globals={s.key: s.run(self.graph.vertex_data)
                     for s in self.syncs},
            superstep=jnp.int32(0),
            n_updates=jnp.zeros((M,), jnp.int32),
            version=jnp.zeros((M, R), jnp.int32),
            eversion=jnp.zeros((M, E_loc + 1), jnp.int32),
            sent_ver=jnp.zeros((M, M, Hg), jnp.int32),
            esent_ver=jnp.zeros((M, M, Hc), jnp.int32),
            ghost_sent=jnp.zeros((M,), jnp.int32),
            ghost_full=jnp.zeros((M,), jnp.int32))

    @property
    def _plan_arrays(self) -> dict:
        plan = self.plan
        return dict(
            degree=plan.degree,
            owned_mask=plan.owned_mask, global_ids=plan.global_ids,
            tsend_idx=plan.tsend_idx, tsend_mask=plan.tsend_mask,
            trecv_idx=plan.trecv_idx, cesend_idx=plan.cesend_idx,
            cesend_mask=plan.cesend_mask, cerecv_idx=plan.cerecv_idx,
            **plan.ell_arrays(),
        )

    def _carry_specs(self):
        spec_s, spec_r = P(self.axis), P()
        return dict(vertex_data=spec_s, edge_data=spec_s, active=spec_s,
                    priority=spec_s, globals=spec_r, superstep=spec_r,
                    n_updates=spec_s, version=spec_s, eversion=spec_s,
                    sent_ver=spec_s, esent_ver=spec_s, ghost_sent=spec_s,
                    ghost_full=spec_s)

    def _program(self, fixed: int | None, ignore_active: bool = False):
        key = (fixed, ignore_active)
        cache = self.__dict__.setdefault("_program_cache", {})
        if key in cache:
            return cache[key]
        superstep = self._build_superstep()
        plan, axis = self.plan, self.axis

        def shard_fn(plan_blk, carry, stop_at):
            plan_b = jax.tree.map(lambda a: a[0], plan_blk)
            squeeze = lambda t: jax.tree.map(lambda a: a[0], t)
            struct = plan.local_struct(plan_b)
            state = (squeeze(carry["vertex_data"]),
                     squeeze(carry["edge_data"]),
                     carry["active"][0], carry["priority"][0],
                     carry["globals"], carry["superstep"],
                     carry["n_updates"][0], carry["version"][0],
                     carry["eversion"][0], carry["sent_ver"][0],
                     carry["esent_ver"][0], carry["ghost_sent"][0],
                     carry["ghost_full"][0])

            def body(state):
                return superstep(state, struct, plan_b)

            if fixed is not None:
                for _ in range(fixed):
                    state = body(state)
            else:
                def cond(state):
                    below = state[5] < stop_at
                    if ignore_active:
                        return below
                    act_l = state[2] & plan_b["owned_mask"]
                    total = jax.lax.psum(act_l.sum(dtype=jnp.int32), axis)
                    return (total > 0) & below
                state = jax.lax.while_loop(cond, body, state)
            (vdata, edata, act, prio, globals_, step, n_upd,
             version, eversion, sent_ver, esent_ver, sent, full) = state
            expand = lambda t: jax.tree.map(lambda a: a[None], t)
            return dict(
                vertex_data=expand(vdata), edge_data=expand(edata),
                active=act[None], priority=prio[None], globals=globals_,
                superstep=step, n_updates=n_upd[None],
                version=version[None], eversion=eversion[None],
                sent_ver=sent_ver[None], esent_ver=esent_ver[None],
                ghost_sent=sent[None], ghost_full=full[None])

        from jax.experimental.shard_map import shard_map
        fn = shard_map(
            shard_fn, mesh=self.mesh,
            in_specs=(P(self.axis), self._carry_specs(), P()),
            out_specs=self._carry_specs(),
            check_rep=False)
        cache[key] = jax.jit(fn)
        return cache[key]

    def _commit_carry(self, carry: dict) -> dict:
        # uncommitted init/restored leaves would key a second jit cache
        # entry vs program-returned carries (a full recompile on the
        # first mixed call); no-copy no-op when already committed
        from jax.sharding import NamedSharding
        specs = self._carry_specs()
        return {k: jax.device_put(v, NamedSharding(self.mesh, specs[k]))
                for k, v in carry.items()}

    def step_chunk(self, carry: dict, stop_at: int,
                   ignore_active: bool = False) -> dict:
        # host-side fault-injection site (repro.ft); None => zero cost
        hook = getattr(self, "fault_hook", None)
        if hook is not None:
            hook("superstep", superstep=int(carry["superstep"]))
        prog = self._program(None, ignore_active)
        with jax.transfer_guard("allow"):
            return prog(self._plan_arrays, self._commit_carry(carry),
                        jnp.int32(stop_at))

    def carry_active_any(self, carry: dict) -> bool:
        return bool((np.asarray(carry["active"])
                     & np.asarray(self.plan.owned_mask)).any())

    def finalize(self, carry: dict) -> dict:
        plan = self.plan
        return dict(
            vertex_data=plan.unshard_vertex_data(
                carry["vertex_data"], self.graph.n_vertices),
            local_vertex_data=carry["vertex_data"],
            local_edge_data=carry["edge_data"],
            globals=carry["globals"],
            supersteps=int(carry["superstep"]),
            n_updates=int(np.asarray(carry["n_updates"]).sum()),
            active_any=self.carry_active_any(carry),
            # version-filtered traffic vs what a static push would send
            ghost_rows_sent=int(np.asarray(carry["ghost_sent"]).sum()),
            ghost_rows_full=int(np.asarray(carry["ghost_full"]).sum()),
        )

    def run(self, active: np.ndarray | None = None,
            num_supersteps: int | None = None):
        carry = self.init_carry(active)
        prog = self._program(num_supersteps)
        with jax.transfer_guard("allow"):
            carry = prog(self._plan_arrays, carry,
                         jnp.int32(self.max_supersteps))
        return self.finalize(carry)


register_scheduler(
    "locking", LockingEngine, extras=("max_pending",),
    description="pipelined reader/writer lock engine (§4.2.2): "
                "max_pending window + min-id claim winners; needs no "
                "coloring")
register_distributed(
    "locking", DistributedLockingEngine, extras=("max_pending",))
