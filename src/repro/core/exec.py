"""Shared executor core: one engine skeleton, many scheduling strategies.

The paper's central claim (§3-4) is that a single abstraction — data
graph + update function + scheduler — serves chromatic, locking/priority
and BSP execution without rewriting user code.  This module is that
claim in code (DESIGN.md §1): everything the concrete engines used to
triplicate lives here exactly once:

* ``EngineState``           — the jittable engine state pytree.
* ``init_engine_state``     — task-set / priority / sync-result init.
* ``consume_and_reschedule``— the task-set algebra: consume executed
  tasks, OR/max-merge returned tasks, all via the OOB-sentinel scatter
  trick (padded batch slots alias vertex 0; routing them to an
  out-of-bounds index makes ``mode="drop"`` scatters exact).
* ``scope_claims`` / ``self_claims`` / ``claim_winners`` /
  ``adjacent_claim_winners`` — the locking engine's conflict-resolution
  pass (DESIGN.md §6): reader/writer lock acquisition in canonical
  min-id order, expressed in the same sentinel scatter algebra.
* ``dispatch_update``       — scope materialization + update dispatch,
  including the Pallas aggregator fast path (DESIGN.md §4): an update
  function that declares itself a linear neighbor aggregation skips the
  dense ``[B, D, F]`` scope gather and runs through the ``ell_spmv``
  kernel instead.
* ``apply_batch``           — one conflict-free batch end to end:
  select -> gather/kernel -> update -> scatter -> bookkeeping.
* ``refresh_syncs``         — periodic sync-op refresh ("between
  colors", §4.2.1), parameterized over how a single sync is evaluated so
  the distributed engine can plug in its all_gather+merge reduction.
* ``ExecutorCore``          — the jitted while-loop runner.  A concrete
  engine subclasses it and implements only the *scheduling strategy*:
  how to pick the next conflict-free batch (``prepare``/``select``).

The distributed engine reuses ``apply_batch``/``refresh_syncs`` inside
``shard_map`` rather than subclassing (its superstep interleaves ghost
exchanges with color phases), so the bookkeeping still exists once.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import DataGraph
from repro.core.sync import SyncOp
from repro.core.update import UpdateFn, gather_scopes, scatter_result
from repro.kernels.ell_spmv import (ell_fold, ell_spmv_batched,
                                    ell_spmv_bucketed, segment_combine)
from repro.kernels.ops import default_interpret

PyTree = Any


# ----------------------------------------------------------------------
# Engine state
# ----------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    vertex_data: PyTree
    edge_data: PyTree
    active: jax.Array        # [Nv] bool — the task set T
    priority: jax.Array      # [Nv] f32  — task priorities (priority engine)
    globals: dict            # sync results, keyed by SyncOp.key
    superstep: jax.Array     # i32
    n_updates: jax.Array     # i32 total update-function applications


def engine_state_field_names() -> tuple[str, ...]:
    """The EngineState field set, in declaration order.  Snapshots
    (train.checkpoint, repro.ft) record this so a restore against a
    build whose EngineState gained/lost a field fails by name instead
    of resuming with a silently-defaulted field."""
    return tuple(f.name for f in dataclasses.fields(EngineState))


def init_engine_state(vertex_data: PyTree, edge_data: PyTree,
                      n_vertices: int, syncs: Sequence[SyncOp],
                      active: jax.Array | None = None,
                      priority: jax.Array | None = None) -> EngineState:
    if active is None:
        active = jnp.ones((n_vertices,), bool)
    if priority is None:
        priority = active.astype(jnp.float32)
    globals_ = {s.key: s.run(vertex_data) for s in syncs}
    return EngineState(
        vertex_data=vertex_data, edge_data=edge_data,
        active=active, priority=priority, globals=globals_,
        superstep=jnp.int32(0), n_updates=jnp.int32(0))


def build_color_batches(colors: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-color vertex-id lists into [n_colors, Cmax] (+valid mask)."""
    colors = np.asarray(colors)
    n_colors = int(colors.max()) + 1 if colors.size else 1
    groups = [np.nonzero(colors == c)[0] for c in range(n_colors)]
    cmax = max(1, max(len(g) for g in groups))
    ids = np.zeros((n_colors, cmax), dtype=np.int32)
    valid = np.zeros((n_colors, cmax), dtype=bool)
    for c, g in enumerate(groups):
        ids[c, : len(g)] = g
        valid[c, : len(g)] = True
    return ids, valid


# ----------------------------------------------------------------------
# Task-set algebra
# ----------------------------------------------------------------------

def consume_and_reschedule(active, priority, ids, sel, nbr_ids, nbr_mask,
                           res, sentinel: int, nbr_stamp=None):
    """Consume executed tasks and merge the returned task set.

    ``sentinel`` is the OOB row index (n_vertices locally, R per shard):
    padded/unselected batch slots are routed there so duplicate-index
    scatters cannot clobber real writes.  ``nbr_stamp`` overrides the
    priority given to rescheduled neighbors (FIFO insertion stamping).
    """
    safe_ids = jnp.where(sel, ids, sentinel)
    active = active.at[safe_ids].set(False, mode="drop")
    priority = priority.at[safe_ids].set(0.0, mode="drop")
    if res.resched_self is not None:
        re_self = sel & res.resched_self
        active = active.at[jnp.where(re_self, ids, sentinel)].set(
            True, mode="drop")
    if res.resched_nbrs is not None:
        nmask = nbr_mask & sel[:, None] & res.resched_nbrs
        safe = jnp.where(nmask, nbr_ids, sentinel)
        active = active.at[safe.reshape(-1)].max(
            nmask.reshape(-1), mode="drop")
        if nbr_stamp is not None:
            # FIFO: neighbors enter the queue stamped with insertion time
            pr = jnp.where(nmask, nbr_stamp, -jnp.inf)
            priority = priority.at[safe.reshape(-1)].max(
                pr.reshape(-1), mode="drop")
        elif res.priority is not None:
            # neighbors inherit the scheduling priority of the rescheduler
            pr = jnp.where(nmask, res.priority[:, None], -jnp.inf)
            priority = priority.at[safe.reshape(-1)].max(
                pr.reshape(-1), mode="drop")
    if res.priority is not None and res.resched_self is not None:
        pr_self = jnp.where(sel & res.resched_self, res.priority, -jnp.inf)
        priority = priority.at[safe_ids].max(pr_self, mode="drop")
    return active, priority


def dirty_scope_mask(graph: DataGraph, vertices) -> jax.Array:
    """1-hop dirty closure of a mutated vertex set: ``[Nv]`` bool.

    The serving engine's bridge from mutations to the task set
    (DESIGN.md §13): a mutation invalidates every update function whose
    *scope* can read the changed datum, which by the scope definition
    (§3.1) is the vertex itself plus its neighbors.  Seeding
    ``active=`` with this mask makes incremental recompute a plain
    scheduler run — the task-set algebra then grows the frontier
    exactly as far as ``resched`` decisions demand, which is the
    equivalence-to-full-rebuild argument for confluent updates.

    Built with the same OOB-sentinel scatter as the task-set algebra so
    padded neighbor slots cannot mark vertex 0 dirty.
    """
    ids = jnp.asarray(vertices, jnp.int32).reshape(-1)
    mask = jnp.zeros((graph.n_vertices,), bool)
    if ids.shape[0] == 0:
        return mask
    mask = mask.at[ids].set(True, mode="drop")
    rows = graph.struct_rows(ids)
    safe = jnp.where(rows.nbr_mask, rows.nbrs, graph.n_vertices)
    return mask.at[safe.reshape(-1)].max(
        rows.nbr_mask.reshape(-1), mode="drop")


# ----------------------------------------------------------------------
# Min-id scope claims: the locking engine's conflict-resolution pass
# ----------------------------------------------------------------------

NO_CLAIM = jnp.iinfo(jnp.int32).max   # "nobody claims this row"


def scope_claims(struct, ids, sel, claim_ids=None, rows=None):
    """Deterministic Chandy–Misra-style lock acquisition as one scatter.

    Every candidate vertex ``ids[p]`` (masked by ``sel``) *claims* its
    whole scope — itself plus its neighbor slots — by min-scattering its
    claim id into a per-row claim array.  The claim id defaults to the
    row id itself; the distributed engine passes *global* vertex ids so
    the total order (and therefore the winner set) is partition
    independent.  Padded/unselected slots are routed to the OOB row
    (``n_rows``) exactly like the task-set algebra, so ``mode="drop"``
    scatters are exact.  ``rows`` is the candidates' materialized
    adjacency (``struct.struct_rows(ids)``); pass it in to share one
    bucketed-row gather across the claim pass.

    Returns ``claim [n_rows] int32``: the minimum claim id over all
    candidates whose scope contains the row, ``NO_CLAIM`` where
    unclaimed.
    """
    n_rows = struct.n_rows
    cid = ids.astype(jnp.int32) if claim_ids is None else claim_ids
    claim = jnp.full((n_rows,), NO_CLAIM, jnp.int32)
    safe_self = jnp.where(sel, ids, n_rows)
    claim = claim.at[safe_self].min(cid, mode="drop")
    rows = struct.struct_rows(ids) if rows is None else rows
    nmask = rows.nbr_mask & sel[:, None]
    safe_n = jnp.where(nmask, rows.nbrs, n_rows)
    cvals = jnp.where(nmask, cid[:, None], NO_CLAIM)
    return claim.at[safe_n.reshape(-1)].min(cvals.reshape(-1), mode="drop")


def self_claims(struct, ids, sel, claim_ids=None):
    """Candidacy marks: each candidate min-scatters its claim id onto
    its *own* row only.  ``claim[x] == NO_CLAIM`` therefore reads "x is
    not in any pending window" — the read-lock-compatible claim array
    for the edge-consistency winner rule (``adjacent_claim_winners``).
    """
    n_rows = struct.n_rows
    cid = ids.astype(jnp.int32) if claim_ids is None else claim_ids
    claim = jnp.full((n_rows,), NO_CLAIM, jnp.int32)
    return claim.at[jnp.where(sel, ids, n_rows)].min(cid, mode="drop")


def claim_winners(struct, ids, sel, claim, claim_ids=None, rows=None):
    """Full-consistency grant: a candidate enters the executing batch
    iff it holds the min-id claim over *every* row of its scope (self +
    real neighbor slots) in a ``scope_claims`` array.

    This is the write-lock-everything discipline of the paper's FULL
    model: winners have pairwise-disjoint scopes, so executing them in
    parallel is trivially serializable (sequential consistency, Def.
    3.1).  The globally minimal candidate always wins, so each
    conflict-resolution round makes progress (no livelock) without any
    lock-ordering handshake: min-id ordering *is* the deadlock-free
    canonical lock order of the paper's §4.2.2 pipelined locking engine.
    """
    cid = ids.astype(jnp.int32) if claim_ids is None else claim_ids
    own = claim[ids] == cid
    rows = struct.struct_rows(ids) if rows is None else rows
    nb_ok = jnp.where(rows.nbr_mask,
                      claim[rows.nbrs] == cid[:, None], True).all(axis=-1)
    return sel & own & nb_ok


def adjacent_claim_winners(struct, ids, sel, claim, claim_ids=None,
                           rows=None):
    """Edge/vertex-consistency grant over a ``self_claims`` array: a
    candidate wins iff its id is strictly minimal among its *candidate
    neighbors* (non-candidates read as ``NO_CLAIM`` = +inf).

    Read locks are compatible, so two candidates sharing a neighbor may
    both run — only adjacency (write-lock on self vs the neighbor's
    read lock, plus the shared-edge write) conflicts.  Winners form an
    independent set, exactly the chromatic engine's per-phase guarantee,
    and the same min-id progress/deadlock-freedom argument applies.
    """
    cid = ids.astype(jnp.int32) if claim_ids is None else claim_ids
    own = claim[ids] == cid
    rows = struct.struct_rows(ids) if rows is None else rows
    nb_ok = jnp.where(rows.nbr_mask,
                      claim[rows.nbrs] > cid[:, None], True).all(axis=-1)
    return sel & own & nb_ok


# ----------------------------------------------------------------------
# Update dispatch (dense scopes or the Pallas aggregator fast path)
# ----------------------------------------------------------------------

DISPATCH_MODES = ("auto", "bucket", "batch")


def validate_dispatch(mode: str | None) -> None:
    """Reject unknown dispatch strings at *construction* time.

    ``choose_dispatch`` also raises, but only once a superstep traces —
    by which point the typo'd engine has already been handed around.
    Every engine (and the ``repro.api`` facade validator) funnels its
    ``dispatch=`` through here in ``__post_init__`` so the error is
    immediate and names the legal set.
    """
    if mode not in (None,) + DISPATCH_MODES:
        raise ValueError(
            f"unknown dispatch mode {mode!r}: expected one of "
            f"{DISPATCH_MODES} (DESIGN.md §8)")


def choose_dispatch(mode: str | None, batch_size: int, max_deg: int,
                    sliced_slots: int, cost_model=None,
                    bucket_launches=None) -> str:
    """Resolve a dispatch mode to ``"bucket"`` or ``"batch"`` (DESIGN.md §8).

    ``"bucket"`` launches the full per-bucket row set — per-dispatch
    cost is the sliced slot count ``sum_b Nv_b * W_b``, amortized and
    optimal for sweep engines whose batches cover most of the graph.
    ``"batch"`` gathers the window at its snapped bucket width and
    launches once at ``[B, W]`` — cost ``B * W``, the right shape for
    the dynamic engines' small scheduler windows (k << Nv).

    ``"auto"`` without a model is the static cost rule: the batch
    path's typical-case worst width (every window touches the widest
    stored *bucket* — callers pass ``ell.widths[-1]``, which hub
    splitting bounds by ``W_cap`` instead of ``max_deg``) against the
    bucket path's fixed slot count.  With a fitted ``cost_model``
    (DESIGN.md §11) the same two candidates are priced in measured
    microseconds instead of slots: one ``[B, widths[-1]]`` batch
    launch versus the bucket path's per-bucket launch sequence
    (``bucket_launches``, e.g. ``ell.bucket_launches``).  Either side
    predicting ``None`` (shape outside the trace) falls back to the
    static rule, so a zero-trace model reproduces the static choices
    exactly.  All inputs are trace-time constants — batch width ``B``
    is the engine's static window size — so the choice never retraces,
    and either answer is performance-only: both launch shapes are
    bitwise-identical in results (tests/test_dispatch.py).

    On a split graph a window that does contain a hub runs its batch
    launch at ``B * s * W_cap`` chunk slots, costlier than either
    estimate but still bounded by the window's actual slot work;
    hub-free windows (the common case on power-law graphs, where hubs
    are few) only ever undercut it.
    """
    if mode in ("bucket", "batch"):
        return mode
    # same legal-set error text as construction-time validation
    validate_dispatch(mode)
    if cost_model is not None:
        t_batch = cost_model.predict(max_deg, batch_size)
        if bucket_launches is None:
            t_bucket = None
        else:
            t_bucket = cost_model.predict_launches(bucket_launches)
        if t_batch is not None and t_bucket is not None:
            return "batch" if t_batch < t_bucket else "bucket"
    return "batch" if batch_size * max_deg < sliced_slots else "bucket"


def route_batch_to_buckets(ell, ids, sel, w, vals=None):
    """Scatter batch-row slot arrays onto their bucketed rows.

    ``w [B, max_deg]`` (pre-masked weights) — and optionally
    ``vals [B, max_deg, F]`` — are routed to per-bucket
    ``[Nv_b, W_b(, F)]`` buffers by the same OOB-sentinel scatter as
    ``SlicedEll.row_activation``; rows outside the batch stay zero
    (and are gated off by the row mask anyway).  Both aggregator
    dispatch paths build their launch inputs this way, so weight
    evaluation happens once, on the batch scope, at batch cost —
    never per graph row.

    On a split graph the batch's owner-space slot arrays are first
    reshaped into ``[B * n_chunks_max, w_cap]`` chunk pseudo-rows (slot
    ``j`` of owner row ``i`` is slot ``j % w_cap`` of pseudo-row
    ``i * n_chunks_max + j // w_cap``) whose positions come from the
    owner's virtual rows — still one scatter per bucket, landing each
    hub chunk on its own virtual row.
    """
    if ell.w_cap is not None:
        wc, S = ell.w_cap, ell.n_chunks_max
        off = ell.vrow_offset
        nch = off[ids + 1] - off[ids]
        k = jnp.arange(S, dtype=jnp.int32)
        vid = off[ids][:, None] + k
        ok = sel[:, None] & (k < nch[:, None])
        pos = jnp.where(
            ok, ell.inv_perm[jnp.minimum(vid, ell.n_virtual - 1)],
            ell.total_rows).reshape(-1)                     # [B*S]
        t, d = S * wc, w.shape[1]
        if d < t:       # pre-masked: slots past d (and past t) are 0
            w = jnp.zeros((w.shape[0], t), jnp.float32).at[:, :d].set(w)
            if vals is not None:
                vals = jnp.zeros((vals.shape[0], t) + vals.shape[2:],
                                 jnp.float32).at[:, :d].set(vals)
        w = w[:, :t].reshape(-1, wc)                        # [B*S, wc]
        if vals is not None:
            vals = vals[:, :t].reshape((-1, wc) + vals.shape[2:])
        sel = ok.reshape(-1)
    else:
        pos = jnp.where(sel, ell.inv_perm[ids], ell.total_rows)
    w_blocks, v_blocks = [], []
    for b in range(ell.n_buckets):
        s, e, wb = ell.starts[b], ell.starts[b + 1], ell.widths[b]
        rb = e - s
        in_b = sel & (pos >= s) & (pos < e)
        loc = jnp.where(in_b, pos - s, rb)         # OOB sentinel row
        w_blocks.append(jnp.zeros((rb + 1, wb), jnp.float32).at[loc].set(
            w[:, :wb], mode="drop")[:rb])
        if vals is not None:
            f = vals.shape[-1]
            v_blocks.append(
                jnp.zeros((rb + 1, wb, f), jnp.float32).at[loc].set(
                    vals[:, :wb], mode="drop")[:rb])
    return w_blocks, v_blocks


def bucketed_dense_fold(ell, ids, sel, w, vals, interpret: bool):
    """Reduce a dense batch scope through per-bucket kernel folds.

    The dense fallback's reduction must stay bit-identical to the
    bucketed fast path, and floating multiply-add chains are only
    reproducible when compiled at the *same shapes*: whether the
    backend contracts ``acc + w*x`` into an FMA can vary with launch
    width and row count, so folding the batch at ``[B, max_deg]`` while
    the fast path runs ``[Nv_b, W_b]`` launches drifts by ulps.  The
    fallback therefore routes the batch's (pre-masked) weights and
    gathered values onto their bucketed rows and reduces each bucket
    with ``ell_fold`` at exactly the fast path's ``[Nv_b, W_b]`` shape,
    with the same dynamic row gate (DESIGN.md §7).
    """
    row_masks = ell.bucket_slices(ell.row_activation(ids, sel))
    w_blocks, v_blocks = route_batch_to_buckets(ell, ids, sel, w, vals)
    ys = [ell_fold(wbuf, vbuf, row_mask=rm, interpret=interpret)
          for wbuf, vbuf, rm in zip(w_blocks, v_blocks, row_masks)]
    y_rows = jnp.concatenate(ys, axis=0)
    return _owner_rows(ell, y_rows, ids, sel)


def _owner_rows(ell, y_rows, ids, sel):
    """Bucketed-order stage-1 partials -> ``[B, F]`` owner-row results.

    Unsplit this is the inverse-permutation gather; on a split graph
    the virtual-row partials first pass through ``segment_combine`` —
    stage 2 of the hub split (DESIGN.md §10).  Both dispatch paths
    (kernel and dense fold) exit through this identical op on
    bitwise-equal stage-1 inputs, which is what carries the bitwise
    parity invariant across the split.
    """
    if ell.w_cap is None:
        return jnp.where(sel[:, None], y_rows[ell.inv_perm[ids]], 0.0)
    y_own = segment_combine(y_rows[ell.inv_perm], ell.owner_of_vrow,
                            ell.n_rows)
    return jnp.where(sel[:, None], y_own[ids], 0.0)


def dispatch_update(struct, update_fn: UpdateFn, vertex_data, edge_data,
                    ids, sel, globals_, *, use_kernel: bool,
                    interpret: bool, rows=None, batch_shaped: bool = False):
    """Materialize scopes for ``ids`` and run the update function.

    If the update declares a ``NeighborAggregator`` and the kernel path
    is enabled, the dense ``[B, D, F]`` neighbor-data gather is skipped:
    a lite scope (no ``nbr_data``) is materialized and the aggregation
    runs through ``ell_spmv_bucketed`` — one width-specialized Pallas
    launch per degree bucket over the bucket's own rows, with the batch
    routed onto bucket rows by the OOB-sentinel scatter
    (``SlicedEll.row_activation``).  Per-row compute is therefore the
    bucket width, not the global ``max_deg``.  With the kernel path
    disabled, the dense ``[B, D, F]`` scope *is* materialized, and its
    reduction runs through ``bucketed_dense_fold`` — the same kernel
    accumulation at the same per-bucket shapes — which is what keeps
    the two paths bit-identical (DESIGN.md §4, §7).

    ``batch_shaped`` selects the window-shaped dispatch instead
    (DESIGN.md §8): ``rows`` is the window's ``[B, W]`` snapped-width
    adjacency, the aggregation launches once through
    ``ell_spmv_batched`` at ``[B, W]`` (cost ``B * W``, not the sliced
    slot count), and the dense fallback reduces through ``ell_fold`` at
    the *same* ``[B, W]`` shape with the same row gate — so the
    dense-vs-kernel bitwise parity invariant extends to this path.
    """
    agg = update_fn.aggregator
    if agg is None:
        scope = gather_scopes(struct, vertex_data, edge_data, ids, globals_,
                              rows=rows)
        return scope, update_fn(scope)
    if batch_shaped:
        assert rows is not None, "batch-shaped dispatch needs window rows"
        ell = struct.ell
        win_w = rows.nbrs.shape[1]
        # Split graphs: windows snapped past w_cap (they contain a hub)
        # launch at [B*s, w_cap] chunk pseudo-rows — stage 1 over the
        # window's virtual rows — then segment_combine chunks back onto
        # their batch slot (stage 2).  Dense and kernel arms share the
        # reshape and the combine, so parity is per-shape as ever.
        n_chunk = (win_w // ell.w_cap
                   if ell.w_cap is not None and win_w > ell.w_cap else 1)

        def _chunk_rows(a):
            return a.reshape((-1, win_w // n_chunk) + a.shape[2:])

        def _combine_chunks(y_part, b):
            seg = jnp.repeat(jnp.arange(b, dtype=jnp.int32), n_chunk)
            return segment_combine(y_part, seg, b)

        if not use_kernel:
            scope = gather_scopes(struct, vertex_data, edge_data, ids,
                                  globals_, rows=rows)
            w = jnp.where(scope.nbr_mask, agg.weight(scope),
                          0.0).astype(jnp.float32)
            vals = agg.feature(scope.nbr_data).astype(jnp.float32)
            if n_chunk == 1:
                y = ell_fold(w, vals, row_mask=sel, interpret=interpret)
            else:
                y_part = ell_fold(_chunk_rows(w), _chunk_rows(vals),
                                  row_mask=jnp.repeat(sel, n_chunk),
                                  interpret=interpret)
                y = _combine_chunks(y_part, w.shape[0])
            return scope, agg.combine(scope, y)
        scope = gather_scopes(struct, vertex_data, edge_data, ids, globals_,
                              with_nbr_data=False, rows=rows)
        x = agg.feature(vertex_data).astype(jnp.float32)
        w = jnp.where(scope.nbr_mask, agg.weight(scope),
                      0.0).astype(jnp.float32)
        if n_chunk == 1:
            y = ell_spmv_batched(rows.nbrs, w, x, row_mask=sel,
                                 interpret=interpret)
        else:
            y_part = ell_spmv_batched(_chunk_rows(rows.nbrs),
                                      _chunk_rows(w), x,
                                      row_mask=jnp.repeat(sel, n_chunk),
                                      interpret=interpret)
            y = _combine_chunks(y_part, w.shape[0])
        return scope, agg.combine(scope, y)
    if not use_kernel:
        scope = gather_scopes(struct, vertex_data, edge_data, ids, globals_,
                              rows=rows)
        w = jnp.where(scope.nbr_mask, agg.weight(scope),
                      0.0).astype(jnp.float32)
        vals = agg.feature(scope.nbr_data).astype(jnp.float32)
        y = bucketed_dense_fold(struct.ell, ids, sel, w, vals, interpret)
        return scope, agg.combine(scope, y)
    scope = gather_scopes(struct, vertex_data, edge_data, ids, globals_,
                          with_nbr_data=False, rows=rows)
    ell = struct.ell
    x = agg.feature(vertex_data).astype(jnp.float32)
    w = jnp.where(scope.nbr_mask, agg.weight(scope), 0.0).astype(jnp.float32)
    w_blocks, _ = route_batch_to_buckets(ell, ids, sel, w)
    row_masks = ell.bucket_slices(ell.row_activation(ids, sel))
    y_rows = ell_spmv_bucketed(ell.nbrs, w_blocks, x, row_masks=row_masks,
                               interpret=interpret)
    y = _owner_rows(ell, y_rows, ids, sel)
    return scope, agg.combine(scope, y)


def _apply_selected(struct, update_fn: UpdateFn, carry, ids, sel, globals_,
                    *, sentinel: int, nbr_stamp, use_kernel: bool,
                    interpret: bool, rows, batch_shaped: bool):
    """Gather/kernel -> update -> scatter -> bookkeeping for a resolved
    selection mask (the shared tail of both dispatch paths)."""
    vdata, edata, active, priority, n_upd = carry
    scope, res = dispatch_update(
        struct, update_fn, vdata, edata, ids, sel, globals_,
        use_kernel=use_kernel, interpret=interpret, rows=rows,
        batch_shaped=batch_shaped)
    vdata, edata = scatter_result(struct, vdata, edata, ids, sel, scope, res)
    active, priority = consume_and_reschedule(
        active, priority, ids, sel, scope.nbr_ids, scope.nbr_mask, res,
        sentinel, nbr_stamp=nbr_stamp)
    return (vdata, edata, active, priority,
            n_upd + sel.sum(dtype=jnp.int32))


def switch_on_window_width(ell, ids, sel, width_fn, operand):
    """Run ``width_fn(W)(operand)`` at the window's snapped bucket width.

    The batch-shaped dispatch trick (DESIGN.md §8): ``lax.switch`` on
    the runtime ``window_bucket`` index over one statically-traced
    branch per scope width (bucket widths, plus chunk-count multiples
    of ``w_cap`` on a split graph), so a hub-free window pays
    ``[B, W]``-shaped gathers and launches instead of ``[B, max_deg]``.
    Branch outputs must be width-independent shapes (engine carries,
    claim arrays, winner masks all are).  Branches contain no
    collectives, so shards of a distributed engine may resolve
    different widths independently.
    """
    scope_widths = ell.scope_widths
    if len(scope_widths) == 1:
        return width_fn(scope_widths[0])(operand)
    bidx = ell.window_bucket(ids, sel)
    return jax.lax.switch(
        bidx, [width_fn(w) for w in scope_widths], operand)


def apply_batch(struct, update_fn: UpdateFn, carry, ids, valid, globals_,
                *, sentinel: int, nbr_stamp=None, use_kernel: bool = True,
                interpret: bool = False, rows=None, dispatch: str = "bucket"):
    """Execute one conflict-free batch: the body every engine shares.

    ``carry`` is ``(vertex_data, edge_data, active, priority, n_updates)``;
    ``valid`` masks padded/foreign batch slots; tasks actually executed
    are ``valid & active[ids]``.  ``rows`` optionally shares the batch's
    materialized adjacency with a preceding claim pass (bucket path
    only).  ``dispatch`` picks the launch shape (resolve "auto" through
    ``choose_dispatch`` first): ``"bucket"`` runs the per-bucket row
    launches, ``"batch"`` runs the whole body at the window's snapped
    ``[B, W]`` width under ``switch_on_window_width``.  Both paths
    produce bitwise-identical results under the interpret-mode
    FMA-blocking guard — trailing zero-weight slots are exact no-ops —
    which ``tests/test_dispatch.py`` asserts engine by engine.
    """
    vdata, edata, active, priority, n_upd = carry
    sel = valid & active[ids]
    if dispatch == "batch":
        def at_width(w):
            def body(carry):
                wrows = struct.struct_rows(ids, width=w)
                return _apply_selected(
                    struct, update_fn, carry, ids, sel, globals_,
                    sentinel=sentinel, nbr_stamp=nbr_stamp,
                    use_kernel=use_kernel, interpret=interpret,
                    rows=wrows, batch_shaped=True)
            return body
        return switch_on_window_width(struct.ell, ids, sel, at_width, carry)
    return _apply_selected(
        struct, update_fn, carry, ids, sel, globals_, sentinel=sentinel,
        nbr_stamp=nbr_stamp, use_kernel=use_kernel, interpret=interpret,
        rows=rows, batch_shaped=False)


# ----------------------------------------------------------------------
# Sync-op refresh
# ----------------------------------------------------------------------

def refresh_syncs(syncs: Sequence[SyncOp], globals_: dict, vertex_data,
                  superstep, run_fn=None) -> dict:
    """Refresh every sync op whose tau divides the finished superstep.

    ``run_fn(sync, vertex_data)`` evaluates one sync; the default is the
    local tree-reduction, the distributed engine passes its
    all_gather+merge reduction.
    """
    if run_fn is None:
        run_fn = lambda s, vd: s.run(vd)
    new_globals = dict(globals_)
    for s in syncs:
        due = (superstep + 1) % max(s.tau, 1) == 0
        fresh = run_fn(s, vertex_data)
        new_globals[s.key] = jax.tree.map(
            lambda new, old: jnp.where(due, new, old),
            fresh, globals_[s.key])
    return new_globals


# ----------------------------------------------------------------------
# The executor: jitted while-loop over strategy-selected batches
# ----------------------------------------------------------------------

@dataclasses.dataclass
class ExecutorCore:
    """Engine skeleton; subclasses supply the scheduling strategy.

    A strategy answers one question — which conflict-free batch runs in
    phase ``c``? — via ``prepare`` (once per superstep, e.g. a top-k
    selection) and ``select`` (per phase, returning ``(ids, valid)``).
    Everything else (task bookkeeping, sync refresh, termination,
    kernel dispatch) is shared.
    """

    graph: DataGraph
    update_fn: UpdateFn
    syncs: Sequence[SyncOp] = ()
    max_supersteps: int = 100
    use_kernel: bool = True                 # aggregator fast path on?
    kernel_interpret: bool | None = None    # None -> auto (off-TPU: True)
    # launch shape per phase batch: "bucket" (per-bucket row launches),
    # "batch" (window-shaped [B, W]), or "auto" (cost model, DESIGN.md §8).
    # Sweep strategies (chromatic/BSP) pin "bucket"; the dynamic window
    # strategies (priority/locking) keep "auto", whose cost model sends
    # their small windows down the batch path and graph-sized windows
    # back to the bucket launches.
    dispatch: str = "auto"
    # fitted launch-time model consulted by dispatch="auto" (DESIGN.md
    # §11): a repro.profile.CostModel (or anything with its predict
    # surface).  None keeps the static slot-count rule.  Performance
    # knob only — never changes results (dispatcher invisibility).
    cost_model: Any = None

    # -- strategy interface -------------------------------------------
    n_phases: int = dataclasses.field(init=False, default=1)

    def __post_init__(self):
        # subclasses with their own __post_init__ chain back via super()
        validate_dispatch(self.dispatch)

    def prepare(self, state: EngineState):
        """Once-per-superstep selection context (e.g. top-k ids)."""
        return None

    def select(self, c, ctx):
        """Phase ``c``'s conflict-free batch: (ids [B], valid [B])."""
        raise NotImplementedError

    def nbr_stamp(self, state: EngineState):
        """Priority override for rescheduled neighbors (FIFO stamps)."""
        return None

    # -- shared machinery ---------------------------------------------
    def _interpret(self) -> bool:
        if self.kernel_interpret is not None:
            return self.kernel_interpret
        return default_interpret()

    def resolve_dispatch(self, batch_size: int) -> str:
        """This engine's ``choose_dispatch`` call, in one place: every
        dispatch decision an ``ExecutorCore`` subclass makes routes
        through here so the ``cost_model`` hook applies uniformly."""
        ell = self.graph.ell
        return choose_dispatch(self.dispatch, batch_size, ell.widths[-1],
                               ell.padded_slots, cost_model=self.cost_model,
                               bucket_launches=ell.bucket_launches)

    @functools.cached_property
    def _probe_sel_jit(self):
        """Jitted first-phase selection for ``profile_probe``: eager
        selection re-traces its ``lax.switch``/claim gathers on every
        call (seconds per probe), which would dwarf the supersteps a
        serving recompute is probing.  Same runtime-graph trick as
        ``_step_dyn_jit`` so one compile serves across mutations."""
        def sel_fn(ell, degree, state):
            base = self.graph
            self.graph = dataclasses.replace(base, ell=ell, degree=degree)
            try:
                ctx = self.prepare(state)
                ids, valid = self.select(0, ctx)
                ell_ = self.graph.ell
                if len(ell_.scope_widths) > 1:
                    bidx = ell_.window_bucket(ids, valid & state.active[ids])
                else:
                    bidx = jnp.int32(0)
                return jnp.int32(ids.shape[0]), bidx
            finally:
                self.graph = base
        return jax.jit(sel_fn)

    def profile_probe(self, state: EngineState) -> dict:
        """Launch shape of this state's first phase, for trace records.

        Runs the strategy's selection (jitted, never the update body)
        and reports what the step will launch: batch mode resolves the
        window's snapped scope width, bucket mode reports the full
        per-bucket launch sequence.  Used by ``api.run(...,
        profile=True)`` and ``ServingEngine.recompute(track_launches=
        True)``; costs one extra selection pass per probed superstep,
        which is why probing is opt-in.
        """
        g = self.graph
        batch, bidx = self._probe_sel_jit(g.ell, g.degree, state)
        batch = int(batch)
        mode = self.resolve_dispatch(batch)
        rec = {"mode": mode, "phases": int(self.n_phases)}
        if mode == "batch":
            rec["rows"] = batch
            rec["width"] = int(g.ell.scope_widths[int(bidx)])
        else:
            rec["launches"] = list(g.ell.bucket_launches)
        return rec

    def init_state(self, active: jax.Array | None = None,
                   priority: jax.Array | None = None) -> EngineState:
        return init_engine_state(
            self.graph.vertex_data, self.graph.edge_data,
            self.graph.n_vertices, self.syncs, active, priority)

    def _superstep(self, state: EngineState) -> EngineState:
        ctx = self.prepare(state)
        stamp = self.nbr_stamp(state)
        interpret = self._interpret()

        def phase(c, carry):
            ids, valid = self.select(c, ctx)
            mode = self.resolve_dispatch(ids.shape[0])
            return apply_batch(
                self.graph, self.update_fn, carry, ids, valid,
                state.globals, sentinel=self.graph.n_vertices,
                nbr_stamp=stamp, use_kernel=self.use_kernel,
                interpret=interpret, dispatch=mode)

        carry = (state.vertex_data, state.edge_data, state.active,
                 state.priority, state.n_updates)
        vdata, edata, active, priority, n_upd = jax.lax.fori_loop(
            0, self.n_phases, phase, carry)
        new_globals = refresh_syncs(
            self.syncs, state.globals, vdata, state.superstep)
        return EngineState(
            vertex_data=vdata, edge_data=edata, active=active,
            priority=priority, globals=new_globals,
            superstep=state.superstep + 1, n_updates=n_upd)

    @functools.cached_property
    def _step_jit(self):
        return jax.jit(self._superstep)

    @functools.cached_property
    def _run_jit(self):
        def cond(state):
            return state.active.any() & (state.superstep < self.max_supersteps)
        return jax.jit(lambda s: jax.lax.while_loop(cond, self._superstep, s))

    def run(self, active: jax.Array | None = None,
            priority: jax.Array | None = None,
            num_supersteps: int | None = None) -> EngineState:
        """Run to convergence of the task set (or max/num supersteps)."""
        state = self.init_state(active, priority)
        return self.resume(state, num_supersteps)

    def resume(self, state: EngineState,
               num_supersteps: int | None = None) -> EngineState:
        """Continue from an existing EngineState (e.g. a restored
        snapshot, paper §8: superstep boundaries are globally consistent
        cuts, so resuming from one is bit-identical to never stopping)."""
        if num_supersteps is not None:
            for _ in range(num_supersteps):
                state = self._step_jit(state)
            return state
        return self._run_jit(state)

    # -- dynamic-graph stepping (serving path, DESIGN.md §13) ---------
    @functools.cached_property
    def _step_dyn_jit(self):
        """One superstep with the graph *structure* as a runtime arg.

        ``_step_jit`` closes over the construction-time graph, so its
        adjacency arrays bake into the executable as constants — fine
        for batch runs, fatal for serving, where every slack insert
        would mean a fresh compile.  Here ``(ell, degree)`` are traced
        pytree arguments instead: ``self.graph`` is swapped for a
        tracer-carrying replica only while ``_superstep`` traces (the
        strategy's ``prepare``/``select`` read ``self.graph``), then
        restored.  Slack inserts keep every array shape and all ELL
        meta (pytree aux data) constant, so steady-state serving reuses
        one executable; a compaction that changes bucket meta retraces
        exactly once, by construction of the jit cache key.
        """
        def step(ell, degree, state):
            base = self.graph
            self.graph = dataclasses.replace(base, ell=ell, degree=degree)
            try:
                return self._superstep(state)
            finally:
                self.graph = base
        return jax.jit(step)

    def step_on(self, graph: DataGraph, state: EngineState) -> EngineState:
        """Run one superstep against ``graph``'s current structure
        (same vertex set/strategy constants as the build graph; see
        ``_step_dyn_jit`` for why this doesn't recompile per mutation).
        """
        return self._step_dyn_jit(graph.ell, graph.degree, state)

    def probe_on(self, graph: DataGraph, state: EngineState) -> dict:
        """``profile_probe`` against a runtime graph: eager, so a plain
        temporary swap of ``self.graph`` is enough."""
        base = self.graph
        self.graph = graph
        try:
            return self.profile_probe(state)
        finally:
            self.graph = base
