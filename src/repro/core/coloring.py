"""Vertex colorings for the chromatic engine (paper §4.2.1).

* ``greedy_coloring``     -- 1st-order coloring => edge consistency model.
* ``distance2_coloring``  -- 2nd-order coloring => full consistency model.
* ``single_color``        -- trivial coloring   => vertex consistency model.
* ``bipartite_coloring``  -- the paper's fast path: "many optimization
  problems in ML are naturally expressed as bipartite graphs" (ALS, CoEM);
  a bipartite graph is two-colored by construction.
"""
from __future__ import annotations

import numpy as np


def greedy_coloring(n_vertices: int, edges: np.ndarray, order: np.ndarray | None = None) -> np.ndarray:
    """First-fit greedy coloring: no adjacent vertices share a color."""
    adj: list[list[int]] = [[] for _ in range(n_vertices)]
    for u, v in np.asarray(edges, dtype=np.int64):
        if u == v:
            continue
        adj[int(u)].append(int(v))
        adj[int(v)].append(int(u))
    colors = np.full(n_vertices, -1, dtype=np.int32)
    if order is None:
        # largest-degree-first tends to produce fewer colors
        order = np.argsort([-len(a) for a in adj], kind="stable")
    for v in order:
        used = {colors[u] for u in adj[v] if colors[u] >= 0}
        c = 0
        while c in used:
            c += 1
        colors[v] = c
    return colors


def distance2_coloring(n_vertices: int, edges: np.ndarray) -> np.ndarray:
    """Coloring of the square graph: no vertex shares a color with any
    distance<=2 neighbor.  Satisfies the *full* consistency model under
    the chromatic engine (paper §4.2.1)."""
    adj: list[set[int]] = [set() for _ in range(n_vertices)]
    for u, v in np.asarray(edges, dtype=np.int64):
        if u == v:
            continue
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))
    colors = np.full(n_vertices, -1, dtype=np.int32)
    order = np.argsort([-len(a) for a in adj], kind="stable")
    for v in order:
        used = set()
        for u in adj[v]:
            if colors[u] >= 0:
                used.add(colors[u])
            for w in adj[u]:
                if w != v and colors[w] >= 0:
                    used.add(colors[w])
        c = 0
        while c in used:
            c += 1
        colors[v] = c
    return colors


def single_color(n_vertices: int) -> np.ndarray:
    """All vertices one color: the vertex consistency model (fully
    independent map operations), and also the *unsafe* Jacobi mode the
    paper's 'adventurous user' (§3.5) may select."""
    return np.zeros(n_vertices, dtype=np.int32)


def bipartite_coloring(n_left: int, n_vertices: int) -> np.ndarray:
    """Two-coloring of a bipartite graph with left block [0, n_left)."""
    colors = np.zeros(n_vertices, dtype=np.int32)
    colors[n_left:] = 1
    return colors


def verify_coloring(n_vertices: int, edges: np.ndarray, colors: np.ndarray, distance: int = 1) -> bool:
    """Property check used by tests: valid (distance-1 or -2) coloring."""
    edges = np.asarray(edges, dtype=np.int64)
    colors = np.asarray(colors)
    ok = True
    for u, v in edges:
        if u != v and colors[u] == colors[v]:
            return False
    if distance == 2:
        adj: list[list[int]] = [[] for _ in range(n_vertices)]
        for u, v in edges:
            if u == v:
                continue
            adj[int(u)].append(int(v))
            adj[int(v)].append(int(u))
        for v in range(n_vertices):
            seen = {}
            for u in adj[v]:
                for w in adj[u]:
                    if w != v:
                        if colors[w] == colors[v]:
                            return False
    return ok
