"""Distributed data graph + distributed chromatic engine (paper §4).

Host-side, ``ShardPlan.build`` performs the paper's load procedure: take a
vertex->machine assignment (from ``partition.two_phase_partition`` or
``random_partition``), give every shard its owned vertices plus **ghosts**
(boundary vertices/edges of neighbors, §4.1 Fig. 4), and precompute the
static communication schedule:

* ``send/recv`` (per color): owned color-c vertices that peers ghost —
  the "synchronize modified ghost data between colors" traffic of the
  chromatic engine (§4.2.1), realized as a single ``all_to_all`` per
  phase.  Sending only the *current color's* rows is the static-schedule
  form of the paper's versioned "only transmit modified data".
* ``esend/erecv`` (per color): replicated cut-edge data written by the
  color-c endpoint, pushed to the replica holder.
* ``tsend/trecv``: task-set backflow — reschedule flags & priorities
  raised on ghost rows are OR/max-combined into the owner's task set.
  This replaces the paper's cross-machine task scheduling messages; and
  termination detection is a ``psum`` of owned active counts, replacing
  the Misra consensus algorithm (§4.2.2, see DESIGN.md).
* color-independent schedules for the **locking engine** (DESIGN.md §6):
  ``global_ids`` (the partition-independent total order its min-id
  claims compare in) and ``cesend/cerecv`` (cut-edge replica pushes
  without a color schedule).  The ``tsend/trecv`` pattern doubles as the
  claim-combine and versioned ghost-data channel — its slot layout is
  symmetric under ``all_to_all``, so the same indices serve both
  directions (ghost -> owner and owner -> ghost).

Device-side, ``DistributedChromaticEngine`` runs the same color-phase
program as the single-shard engine inside ``shard_map`` over a 1-D
"shard" mesh axis; all shapes are uniform across shards (SPMD).

Consistency support: EDGE / VERTEX / UNSAFE (writes to self + adjacent
edges).  FULL-consistency *neighbor writes* would require ghost-data
backflow and are not supported distributed (none of the paper's
applications write neighbor vertex data).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.exec import (NO_CLAIM, apply_batch, choose_dispatch,
                             default_interpret, refresh_syncs,
                             validate_dispatch)
from repro.core.registry import register_distributed
from repro.core.graph import (DataGraph, EllRows, SlicedEll, bucket_index,
                              build_sliced_ell, build_split_ell,
                              default_bucket_widths, sliced_slot_count,
                              split_hub_rows)
from repro.core.sync import SyncOp
from repro.core.update import UpdateFn

PyTree = Any


class LocalStruct(NamedTuple):
    """Per-shard graph structure adapter consumed by gather/scatter.

    Mirrors ``DataGraph``'s structure API (``struct_rows`` / ``degree``
    / ``n_rows`` / ``ell``) over the shard-local degree-bucketed blocks,
    so the shared executor core runs unchanged under ``shard_map``.
    """
    ell: SlicedEll
    degree: jax.Array
    n_vertices: int   # rows per shard R (scatter sentinel)

    @property
    def n_rows(self) -> int:
        return self.n_vertices

    def struct_rows(self, ids: jax.Array,
                    width: int | None = None) -> EllRows:
        return self.ell.rows(ids, width=width)


@dataclasses.dataclass
class ShardPlan:
    """Static distributed layout + communication schedule (host-built)."""
    M: int                 # number of shards (== mesh axis size)
    R: int                 # rows per shard (owned + ghost + padding)
    E_loc: int             # local edges per shard (excl. pad row)
    n_colors: int
    Cmax: int              # color batch width
    Hv: int                # vertex-exchange width per (color, peer)
    He: int                # edge-exchange width per (color, peer)
    Hg: int                # task-backflow width per peer
    # ---- sliced-ELL local structure (per bucket [M, R_b, W_b]) ----
    ell_widths: tuple          # static ascending bucket widths
    ell_starts: tuple          # static position offsets (len n_buckets+1)
    ell_nbrs: tuple            # per bucket [M, R_b, W_b] local nbr slots
    ell_nbr_mask: tuple        # per bucket [M, R_b, W_b]
    ell_edge_ids: tuple        # per bucket [M, R_b, W_b] (pad -> E_loc)
    ell_is_src: tuple          # per bucket [M, R_b, W_b]
    ell_perm: jax.Array        # [M, total] bucketed pos -> local row (pad -> R)
    ell_inv_perm: jax.Array    # [M, R] local row -> bucketed pos
    degree: jax.Array      # [M, R]
    owned_mask: jax.Array  # [M, R]
    color_ids: jax.Array   # [M, n_colors, Cmax] local owned slots
    color_valid: jax.Array # [M, n_colors, Cmax]
    send_idx: jax.Array    # [M, n_colors, M, Hv] local owned slot to send
    send_mask: jax.Array   # [M, n_colors, M, Hv]
    recv_idx: jax.Array    # [M, n_colors, M, Hv] local ghost slot to fill
    esend_idx: jax.Array   # [M, n_colors, M, He]
    esend_mask: jax.Array  # [M, n_colors, M, He]
    erecv_idx: jax.Array   # [M, n_colors, M, He]
    tsend_idx: jax.Array   # [M, M, Hg] local ghost slot whose flags go home
    tsend_mask: jax.Array  # [M, M, Hg]
    trecv_idx: jax.Array   # [M, M, Hg] owner's owned slot
    # ---- color-independent schedules (locking engine) ----
    Hc: int                # cut-edge exchange width per (owner, peer)
    global_ids: jax.Array  # [M, R] global vertex id (NO_CLAIM on pad rows)
    cesend_idx: jax.Array  # [M, M, Hc] local edge slot pushed to the peer
    cesend_mask: jax.Array # [M, M, Hc]
    cerecv_idx: jax.Array  # [M, M, Hc] peer's replica slot for that edge
    # ---- host-side maps ----
    local_to_global: np.ndarray  # [M, R] global vertex id or -1
    ledge_to_global: np.ndarray  # [M, E_loc] global edge id or -1
    assignment: np.ndarray       # [Nv]
    # ---- hub splitting (mirrors SlicedEll; None/defaults unsplit) ----
    # Virtual rows are shard-local: a hub's chunks never cross a shard
    # boundary, so every ghost-sync / claim / backflow schedule above
    # stays in owner-row space, untouched.  Shapes are shard-uniform
    # (NVirt, chunk count and bucket sizes maxed over shards; dummy
    # virtual rows are empty and owned by the R sentinel).
    ell_max_deg: int | None = None       # owner-space width (== D)
    ell_w_cap: int | None = None
    ell_n_chunks_max: int = 1
    ell_owner_of_vrow: jax.Array | None = None   # [M, NVirt]
    ell_vrow_offset: jax.Array | None = None     # [M, R + 1]

    # ------------------------------------------------------------------
    @staticmethod
    def build(graph: DataGraph, assignment: np.ndarray, M: int) -> "ShardPlan":
        nv, ne, D = graph.n_vertices, graph.n_edges, graph.max_deg
        # Colorless graphs get the trivial single-color schedule: enough
        # for the locking engine (which ignores colors); the chromatic
        # engine still requires a real coloring for correctness.
        colors = (np.asarray(graph.colors) if graph.colors is not None
                  else np.zeros(nv, dtype=np.int64))
        n_colors = int(colors.max()) + 1 if nv else 1
        assignment = np.asarray(assignment, dtype=np.int64)
        edges = graph.edges_np

        owned = [np.nonzero(assignment == i)[0] for i in range(M)]
        adj = graph.adjacency_lists
        ghosts: list[np.ndarray] = []
        for i in range(M):
            gs: set[int] = set()
            own = set(owned[i].tolist())
            for v in owned[i]:
                for u in adj[int(v)]:
                    if u not in own:
                        gs.add(u)
            ghosts.append(np.asarray(sorted(gs), dtype=np.int64))
        O = max(1, max(len(o) for o in owned))
        G = max(1, max(len(g) for g in ghosts)) if any(len(g) for g in ghosts) else 1
        R = O + G

        g2l = [dict() for _ in range(M)]   # global id -> local slot
        local_to_global = np.full((M, R), -1, dtype=np.int64)
        for i in range(M):
            for s, v in enumerate(owned[i]):
                g2l[i][int(v)] = s
                local_to_global[i, s] = v
            for s, v in enumerate(ghosts[i]):
                g2l[i][int(v)] = O + s
                local_to_global[i, O + s] = v

        # ---- local edges: every edge incident to an owned vertex ----
        e2l = [dict() for _ in range(M)]
        ledges: list[list[int]] = [[] for _ in range(M)]
        for e, (u, v) in enumerate(edges):
            for i in {int(assignment[u]), int(assignment[v])}:
                e2l[i][e] = len(ledges[i])
                ledges[i].append(e)
        E_loc = max(1, max(len(l) for l in ledges))
        ledge_to_global = np.full((M, E_loc), -1, dtype=np.int64)
        for i in range(M):
            ledge_to_global[i, : len(ledges[i])] = ledges[i]

        # ---- local adjacency for owned rows ----
        padded = graph.to_padded()       # host build works on the flat view
        h_nbrs = np.asarray(padded.nbrs)
        h_mask = np.asarray(padded.nbr_mask)
        h_eids = np.asarray(padded.edge_ids)
        h_issrc = np.asarray(padded.is_src)
        h_deg = np.asarray(graph.degree)
        nbrs_l = np.zeros((M, R, D), dtype=np.int32)
        mask_l = np.zeros((M, R, D), dtype=bool)
        eids_l = np.full((M, R, D), E_loc, dtype=np.int32)
        issrc_l = np.zeros((M, R, D), dtype=bool)
        deg_l = np.zeros((M, R), dtype=np.int32)
        owned_mask = np.zeros((M, R), dtype=bool)
        for i in range(M):
            for s, v in enumerate(owned[i]):
                owned_mask[i, s] = True
                deg_l[i, s] = h_deg[v]
                for j in range(D):
                    if not h_mask[v, j]:
                        continue
                    u = int(h_nbrs[v, j])
                    nbrs_l[i, s, j] = g2l[i][u]
                    mask_l[i, s, j] = True
                    eids_l[i, s, j] = e2l[i][int(h_eids[v, j])]
                    issrc_l[i, s, j] = h_issrc[v, j]

        # ---- per-color owned batches ----
        batches = [[np.asarray([s for s, v in enumerate(owned[i])
                                if colors[v] == c], dtype=np.int64)
                    for c in range(n_colors)] for i in range(M)]
        Cmax = max(1, max(len(b) for bi in batches for b in bi))
        color_ids = np.zeros((M, n_colors, Cmax), dtype=np.int32)
        color_valid = np.zeros((M, n_colors, Cmax), dtype=bool)
        for i in range(M):
            for c in range(n_colors):
                b = batches[i][c]
                color_ids[i, c, : len(b)] = b
                color_valid[i, c, : len(b)] = True

        # ---- vertex ghost exchange (owner -> ghost), per color ----
        sends: dict = {}
        for i in range(M):
            for v in ghosts[i]:
                j = int(assignment[v])        # owner
                c = int(colors[v])
                sends.setdefault((c, j, i), []).append(int(v))
        Hv = max(1, max((len(v) for v in sends.values()), default=1))
        send_idx = np.zeros((M, n_colors, M, Hv), dtype=np.int32)
        send_mask = np.zeros((M, n_colors, M, Hv), dtype=bool)
        recv_idx = np.full((M, n_colors, M, Hv), R, dtype=np.int32)
        for (c, j, i), vs in sends.items():    # j owner sends to i
            for t, v in enumerate(vs):
                send_idx[j, c, i, t] = g2l[j][v]
                send_mask[j, c, i, t] = True
                recv_idx[i, c, j, t] = g2l[i][v]

        # ---- cut-edge replica push (color-c endpoint owner -> peer) ----
        esends: dict = {}
        for e, (u, v) in enumerate(edges):
            iu, iv = int(assignment[u]), int(assignment[v])
            if iu == iv:
                continue
            for (w, ow, peer) in ((u, iu, iv), (v, iv, iu)):
                c = int(colors[int(w)])
                esends.setdefault((c, ow, peer), []).append(e)
        He = max(1, max((len(v) for v in esends.values()), default=1))
        esend_idx = np.zeros((M, n_colors, M, He), dtype=np.int32)
        esend_mask = np.zeros((M, n_colors, M, He), dtype=bool)
        erecv_idx = np.full((M, n_colors, M, He), E_loc, dtype=np.int32)
        for (c, ow, peer), es in esends.items():
            for t, e in enumerate(es):
                esend_idx[ow, c, peer, t] = e2l[ow][e]
                esend_mask[ow, c, peer, t] = True
                erecv_idx[peer, c, ow, t] = e2l[peer][e]

        # ---- task backflow (ghost flags -> owner), color independent ----
        tsends: dict = {}
        for i in range(M):
            for v in ghosts[i]:
                j = int(assignment[v])
                tsends.setdefault((i, j), []).append(int(v))
        Hg = max(1, max((len(v) for v in tsends.values()), default=1))
        tsend_idx = np.zeros((M, M, Hg), dtype=np.int32)
        tsend_mask = np.zeros((M, M, Hg), dtype=bool)
        trecv_idx = np.full((M, M, Hg), R, dtype=np.int32)
        for (i, j), vs in tsends.items():      # i holds ghosts of j's vertices
            for t, v in enumerate(vs):
                tsend_idx[i, j, t] = g2l[i][v]
                tsend_mask[i, j, t] = True
                trecv_idx[j, i, t] = g2l[j][v]

        # ---- color-independent cut-edge replica exchange (locking) ----
        # Shard iu writes edge e = (u, v) only through u's update (ghosts
        # never execute), so each replica pair needs one directed push
        # per endpoint owner.  Entries are appended pairwise, so slot t
        # of (iu -> iv) and of (iv -> iu) name the same edge — the
        # symmetry all_to_all relies on.
        cesends: dict = {}
        for e, (u, v) in enumerate(edges):
            iu, iv = int(assignment[u]), int(assignment[v])
            if iu == iv:
                continue
            cesends.setdefault((iu, iv), []).append(e)
            cesends.setdefault((iv, iu), []).append(e)
        Hc = max(1, max((len(v) for v in cesends.values()), default=1))
        cesend_idx = np.zeros((M, M, Hc), dtype=np.int32)
        cesend_mask = np.zeros((M, M, Hc), dtype=bool)
        cerecv_idx = np.full((M, M, Hc), E_loc, dtype=np.int32)
        for (ow, peer), es in cesends.items():
            for t, e in enumerate(es):
                cesend_idx[ow, peer, t] = e2l[ow][e]
                cesend_mask[ow, peer, t] = True
                cerecv_idx[peer, ow, t] = e2l[peer][e]

        # global vertex ids per local row — the partition-independent
        # total order the locking engine's min-id claims compare in
        global_ids = np.where(local_to_global >= 0, local_to_global,
                              NO_CLAIM).astype(np.int32)

        # ---- degree-bucket the shard-local adjacency ----
        # Bucket shapes must be uniform across shards (SPMD), so each
        # bucket is padded to its max row count over shards; ghost and
        # padding rows carry no slots and land in the first bucket.
        # A hub-split source graph (DESIGN.md §10) splits each shard's
        # local rows the same way: virtual rows are shard-local (hub
        # chunks never cross a shard boundary), NVirt / chunk count /
        # bucket sizes are maxed over shards, and dummy virtual rows
        # (empty, owned by the R sentinel) pad the difference.
        w_cap = graph.ell.w_cap
        if w_cap is not None:
            splits = [split_hub_rows(nbrs_l[i], mask_l[i], eids_l[i],
                                     issrc_l[i], E_loc, w_cap)
                      for i in range(M)]
            NVirt = max(s[4].shape[0] for s in splits)
            n_chunks_max = max(int((s[5][1:] - s[5][:-1]).max())
                               for s in splits)
            widths_all = default_bucket_widths(w_cap)
            counts = np.zeros((M, len(widths_all)), np.int64)
            for i, s in enumerate(splits):
                cnt = s[1].sum(axis=1)            # chunk slot counts
                counts[i] = np.bincount(bucket_index(widths_all, cnt),
                                        minlength=len(widths_all))
                counts[i, 0] += NVirt - len(cnt)  # dummy virtual rows
            sizes_all = counts.max(axis=0)
            keep = [b for b in range(len(widths_all)) if sizes_all[b] > 0]
            kwidths = tuple(widths_all[b] for b in keep)
            ksizes = [int(sizes_all[b]) for b in keep]
            ells = [build_split_ell(nbrs_l[i], mask_l[i], eids_l[i],
                                    issrc_l[i], pad_edge=E_loc,
                                    w_cap=w_cap, widths=kwidths,
                                    bucket_sizes=ksizes, n_virtual=NVirt)
                    for i in range(M)]
        else:
            n_chunks_max = 1
            widths_all = default_bucket_widths(D)
            slot_cnt = mask_l.sum(axis=-1)                   # [M, R]
            bidx = bucket_index(widths_all, slot_cnt)
            counts = np.stack([(bidx == b).sum(axis=1)
                               for b in range(len(widths_all))], axis=1)
            sizes_all = counts.max(axis=0)                   # [n_buckets]
            keep = [b for b in range(len(widths_all)) if sizes_all[b] > 0]
            kwidths = tuple(widths_all[b] for b in keep)
            ksizes = [int(sizes_all[b]) for b in keep]
            ells = [build_sliced_ell(nbrs_l[i], mask_l[i], eids_l[i],
                                     issrc_l[i], pad_edge=E_loc,
                                     widths=kwidths, bucket_sizes=ksizes)
                    for i in range(M)]
        stack = lambda field: tuple(
            jnp.stack([getattr(ells[i], field)[b] for i in range(M)])
            for b in range(len(kwidths)))

        return ShardPlan(
            M=M, R=R, E_loc=E_loc, n_colors=n_colors, Cmax=Cmax,
            Hv=Hv, He=He, Hg=Hg, Hc=Hc,
            global_ids=jnp.asarray(global_ids),
            cesend_idx=jnp.asarray(cesend_idx),
            cesend_mask=jnp.asarray(cesend_mask),
            cerecv_idx=jnp.asarray(cerecv_idx),
            ell_widths=kwidths, ell_starts=ells[0].starts,
            ell_nbrs=stack("nbrs"), ell_nbr_mask=stack("nbr_mask"),
            ell_edge_ids=stack("edge_ids"), ell_is_src=stack("is_src"),
            ell_perm=jnp.stack([e.perm for e in ells]),
            ell_inv_perm=jnp.stack([e.inv_perm for e in ells]),
            degree=jnp.asarray(deg_l), owned_mask=jnp.asarray(owned_mask),
            color_ids=jnp.asarray(color_ids), color_valid=jnp.asarray(color_valid),
            send_idx=jnp.asarray(send_idx), send_mask=jnp.asarray(send_mask),
            recv_idx=jnp.asarray(recv_idx),
            esend_idx=jnp.asarray(esend_idx), esend_mask=jnp.asarray(esend_mask),
            erecv_idx=jnp.asarray(erecv_idx),
            tsend_idx=jnp.asarray(tsend_idx), tsend_mask=jnp.asarray(tsend_mask),
            trecv_idx=jnp.asarray(trecv_idx),
            local_to_global=local_to_global, ledge_to_global=ledge_to_global,
            assignment=assignment,
            ell_max_deg=int(D) if w_cap is not None else None,
            ell_w_cap=int(w_cap) if w_cap is not None else None,
            ell_n_chunks_max=n_chunks_max,
            ell_owner_of_vrow=(jnp.stack([e.owner_of_vrow for e in ells])
                               if w_cap is not None else None),
            ell_vrow_offset=(jnp.stack([e.vrow_offset for e in ells])
                             if w_cap is not None else None),
        )

    # ------------------------------------------------------------------
    @property
    def partition_fingerprint(self) -> str:
        """Content hash of (M, assignment): the identity a sharded
        snapshot (repro.ft) records so a restore onto a *different*
        partition — whose local row spaces would silently misalign —
        is refused at load, not discovered as wrong numbers."""
        import hashlib
        h = hashlib.sha256()
        h.update(str(self.M).encode())
        h.update(np.ascontiguousarray(self.assignment,
                                      dtype=np.int64).tobytes())
        return h.hexdigest()[:16]

    # ------------------------------------------------------------------
    @property
    def sliced_slots(self) -> int:
        """Per-shard stored slot count ``sum_b R_b * W_b`` — the bucket
        path's per-dispatch compute, the cost model's other arm."""
        return sliced_slot_count(self.ell_starts, self.ell_widths)

    @property
    def bucket_launches(self) -> tuple[tuple[int, int], ...]:
        """Per-shard ``(width, rows)`` launch sequence of one
        bucket-mode dispatch (shard-uniform shapes), for fitted
        cost-model pricing — mirrors ``SlicedEll.bucket_launches``."""
        return tuple(
            (int(self.ell_widths[b]),
             int(self.ell_starts[b + 1] - self.ell_starts[b]))
            for b in range(len(self.ell_widths)))

    def ell_arrays(self) -> dict:
        """The sliced-ELL device arrays, keyed for a shard_map plan dict."""
        out = dict(
            ell_nbrs=self.ell_nbrs, ell_nbr_mask=self.ell_nbr_mask,
            ell_edge_ids=self.ell_edge_ids, ell_is_src=self.ell_is_src,
            ell_perm=self.ell_perm, ell_inv_perm=self.ell_inv_perm)
        if self.ell_w_cap is not None:
            out.update(ell_owner_of_vrow=self.ell_owner_of_vrow,
                       ell_vrow_offset=self.ell_vrow_offset)
        return out

    def local_ell(self, plan_b: dict) -> SlicedEll:
        """Rebuild one shard's ``SlicedEll`` from squeezed plan blocks
        (inside ``shard_map``, leading M dim removed).  Unsplit, the
        owner width is the widest stored bucket (bit-compat with the
        pre-split engine traces); split, it is the explicit owner-space
        ``ell_max_deg`` — the widest stored bucket is only ``w_cap``."""
        return SlicedEll(
            widths=self.ell_widths, starts=self.ell_starts,
            n_rows=self.R,
            max_deg=(self.ell_widths[-1] if self.ell_max_deg is None
                     else self.ell_max_deg),
            pad_edge=self.E_loc,
            nbrs=plan_b["ell_nbrs"], nbr_mask=plan_b["ell_nbr_mask"],
            edge_ids=plan_b["ell_edge_ids"], is_src=plan_b["ell_is_src"],
            perm=plan_b["ell_perm"], inv_perm=plan_b["ell_inv_perm"],
            w_cap=self.ell_w_cap, n_chunks_max=self.ell_n_chunks_max,
            owner_of_vrow=plan_b.get("ell_owner_of_vrow"),
            vrow_offset=plan_b.get("ell_vrow_offset"))

    def local_struct(self, plan_b: dict) -> LocalStruct:
        return LocalStruct(self.local_ell(plan_b), plan_b["degree"], self.R)

    # ------------------------------------------------------------------
    def shard_vertex_data(self, vertex_data: PyTree) -> PyTree:
        """Global [Nv, ...] -> local [M, R, ...] (owned + ghost copies)."""
        idx = np.where(self.local_to_global >= 0, self.local_to_global, 0)
        sel = jnp.asarray(idx)
        msk = jnp.asarray(self.local_to_global >= 0)
        def shard(a):
            out = a[sel.reshape(-1)].reshape((self.M, self.R) + a.shape[1:])
            return out * jnp.asarray(
                msk, out.dtype).reshape((self.M, self.R) + (1,) * (a.ndim - 1)) \
                if jnp.issubdtype(out.dtype, jnp.floating) else out
        return jax.tree.map(shard, vertex_data)

    def shard_edge_data(self, edge_data: PyTree) -> PyTree:
        idx = np.where(self.ledge_to_global >= 0, self.ledge_to_global, 0)
        sel = jnp.asarray(idx)
        def shard(a):
            out = a[sel.reshape(-1)].reshape(
                (self.M, self.E_loc) + a.shape[1:])
            pad = jnp.zeros((self.M, 1) + a.shape[1:], a.dtype)
            return jnp.concatenate([out, pad], axis=1)  # [M, E_loc+1, ...]
        return jax.tree.map(shard, edge_data)

    def unshard_vertex_data(self, local: PyTree, n_vertices: int) -> PyTree:
        """Local [M, R, ...] -> global [Nv, ...] from owned rows."""
        l2g = jnp.asarray(np.where(self.local_to_global >= 0,
                                   self.local_to_global, n_vertices))
        omask = np.asarray(self.owned_mask)
        tgt = jnp.asarray(np.where(omask, np.asarray(l2g), n_vertices))
        def unshard(a):
            flat = a.reshape((self.M * self.R,) + a.shape[2:])
            out = jnp.zeros((n_vertices,) + a.shape[2:], a.dtype)
            return out.at[tgt.reshape(-1)].set(flat, mode="drop")
        return jax.tree.map(unshard, local)


def task_backflow(active, priority, plan_b: dict, axis: str, R: int):
    """Ghost-row task flags/priorities -> owner, then clear the ghost
    copies (they now live at the owner).  Shared by the chromatic and
    locking engines; flags travel as a float32 stack with the priority
    so one ``all_to_all`` carries both."""
    tsidx, tsmask = plan_b["tsend_idx"], plan_b["tsend_mask"]
    tridx = plan_b["trecv_idx"]
    flags = active[tsidx] & tsmask                        # [M, Hg]
    prios = jnp.where(flags, priority[tsidx], -jnp.inf)
    fb = jax.lax.all_to_all(
        jnp.stack([flags.astype(jnp.float32), prios], -1),
        axis, 0, 0, tiled=True)                           # [M, Hg, 2]
    inflag = fb[..., 0] > 0.5
    active = active.at[tridx.reshape(-1)].max(
        inflag.reshape(-1), mode="drop")
    priority = priority.at[tridx.reshape(-1)].max(
        jnp.where(inflag, fb[..., 1], -jnp.inf).reshape(-1),
        mode="drop")
    active = active.at[jnp.where(tsmask, tsidx, R).reshape(-1)
                       ].set(False, mode="drop")
    return active, priority


def make_dist_sync_run(axis: str, M: int, owned_mask):
    """Distributed evaluation of one SyncOp: local Fold/Merge over the
    shard's owned rows, then all_gather + Merge across shards.  Shared
    by the chromatic and locking engines (passed to ``refresh_syncs``)."""
    def dist_sync_run(s_op, vd):
        part = s_op.local_reduce(vd, valid=owned_mask)
        parts = jax.tree.map(lambda x: jax.lax.all_gather(x, axis), part)
        acc = jax.tree.map(lambda x: x[0], parts)
        for m in range(1, M):
            acc = s_op.merge(acc, jax.tree.map(lambda x: x[m], parts))
        return s_op.finalize(acc)
    return dist_sync_run


# ======================================================================
@dataclasses.dataclass
class DistributedChromaticEngine:
    """Chromatic engine over a 1-D device mesh via shard_map."""

    graph: DataGraph
    plan: ShardPlan
    update_fn: UpdateFn
    syncs: Sequence[SyncOp] = ()
    max_supersteps: int = 100
    exchange_edges: bool = False   # app writes edge data on cut edges?
    axis: str = "shard"
    use_kernel: bool = True                 # aggregator fast path on?
    kernel_interpret: bool | None = None    # None -> auto (off-TPU: True)
    # color phases sweep whole shards: per-bucket row launches
    dispatch: str = "bucket"
    # fitted launch-time model for dispatch="auto" (DESIGN.md §11)
    cost_model: Any = None

    def __post_init__(self):
        validate_dispatch(self.dispatch)
        if self.graph.colors is None:
            raise ValueError("chromatic engine needs colors; call "
                             "graph.with_colors(...) (the locking engine "
                             "handles colorless graphs)")
        devs = jax.devices()
        if len(devs) < self.plan.M:
            raise ValueError(f"need {self.plan.M} devices, have {len(devs)}")
        self.mesh = Mesh(np.array(devs[: self.plan.M]), (self.axis,))

    # -- per-shard program (runs under shard_map; leading dim 1) --------
    def _build_step(self):
        plan, upd, axis = self.plan, self.update_fn, self.axis
        M = plan.M
        interpret = (self.kernel_interpret if self.kernel_interpret
                     is not None else default_interpret())
        use_kernel = self.use_kernel
        mode = choose_dispatch(self.dispatch, plan.Cmax,
                               plan.ell_widths[-1], plan.sliced_slots,
                               cost_model=self.cost_model,
                               bucket_launches=plan.bucket_launches)

        def color_phase(c, carry, struct, plan_b, globals_):
            ids = plan_b["color_ids"][c]
            valid = plan_b["color_valid"][c]
            # shared executor core: gather/kernel -> update -> scatter ->
            # task-set consume/reschedule (OOB sentinel = local row R)
            carry = apply_batch(
                struct, upd, carry, ids, valid, globals_,
                sentinel=plan.R, use_kernel=use_kernel, interpret=interpret,
                dispatch=mode)
            vdata, edata, active, priority, n_upd = carry

            # ---- ghost data push (owner -> ghost) ----
            sidx, smask = plan_b["send_idx"][c], plan_b["send_mask"][c]
            ridx = plan_b["recv_idx"][c]          # [M, Hv]
            def push_v(arr):
                buf = arr[sidx]                    # [M, Hv, ...]
                buf = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
                return arr.at[ridx.reshape(-1)].set(
                    buf.reshape((-1,) + buf.shape[2:]), mode="drop")
            vdata = jax.tree.map(push_v, vdata)

            if self.exchange_edges:
                esidx = plan_b["esend_idx"][c]
                eridx = plan_b["erecv_idx"][c]
                def push_e(arr):
                    buf = arr[esidx]
                    buf = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
                    return arr.at[eridx.reshape(-1)].set(
                        buf.reshape((-1,) + buf.shape[2:]), mode="drop")
                edata = jax.tree.map(push_e, edata)

            # ---- task backflow (ghost flags/priority -> owner) ----
            active, priority = task_backflow(active, priority, plan_b,
                                             axis, plan.R)
            return (vdata, edata, active, priority, n_upd)

        def superstep(state, struct, plan_b, n_colors):
            vdata, edata, active, priority, globals_, step, n_upd = state
            carry = (vdata, edata, active, priority, n_upd)
            carry = jax.lax.fori_loop(
                0, n_colors,
                lambda c, s: color_phase(c, s, struct, plan_b, globals_),
                carry)
            vdata, edata, active, priority, n_upd = carry

            new_globals = refresh_syncs(
                self.syncs, globals_, vdata, step,
                run_fn=make_dist_sync_run(axis, M, plan_b["owned_mask"]))
            return (vdata, edata, active, priority, new_globals,
                    step + 1, n_upd)

        return color_phase, superstep

    # ------------------------------------------------------------------
    # Carry-based execution: the superstep program over an explicit
    # state pytree.  ``init_carry`` -> (``step_chunk`` ...) ->
    # ``finalize`` lets a host driver stop at any superstep boundary —
    # the globally consistent cut the fault-tolerance layer (repro.ft)
    # snapshots at — while ``run()`` stays the one fused program.
    # ------------------------------------------------------------------

    CARRY_SHARDED = ("vertex_data", "edge_data", "active", "priority",
                     "n_updates")

    def init_carry(self, active: np.ndarray | None = None) -> dict:
        """Initial distributed state: per-shard blocks with leading
        ``[M, ...]`` dim (sharded over the mesh inside the program) plus
        the replicated ``globals`` / ``superstep``."""
        plan = self.plan
        nv = self.graph.n_vertices
        vdata0 = plan.shard_vertex_data(self.graph.vertex_data)
        # strip the global pad row before sharding edges
        edata_global = jax.tree.map(lambda a: a[:-1], self.graph.edge_data)
        edata0 = plan.shard_edge_data(edata_global)
        if active is None:
            active = np.ones(nv, bool)
        act0 = plan.shard_vertex_data({"a": jnp.asarray(active)})["a"] \
            & plan.owned_mask
        return dict(
            vertex_data=vdata0, edge_data=edata0, active=act0,
            priority=act0.astype(jnp.float32),
            globals={s.key: s.run(self.graph.vertex_data)
                     for s in self.syncs},
            superstep=jnp.int32(0),
            n_updates=jnp.zeros((plan.M,), jnp.int32))

    @property
    def _plan_arrays(self) -> dict:
        plan = self.plan
        return dict(
            degree=plan.degree, owned_mask=plan.owned_mask,
            color_ids=plan.color_ids, color_valid=plan.color_valid,
            send_idx=plan.send_idx, send_mask=plan.send_mask,
            recv_idx=plan.recv_idx, esend_idx=plan.esend_idx,
            esend_mask=plan.esend_mask, erecv_idx=plan.erecv_idx,
            tsend_idx=plan.tsend_idx, tsend_mask=plan.tsend_mask,
            trecv_idx=plan.trecv_idx,
            **plan.ell_arrays(),
        )

    def _carry_specs(self):
        spec_s, spec_r = P(self.axis), P()
        return dict(vertex_data=spec_s, edge_data=spec_s, active=spec_s,
                    priority=spec_s, globals=spec_r, superstep=spec_r,
                    n_updates=spec_s)

    def _state_from_carry(self, carry, squeeze):
        return (squeeze(carry["vertex_data"]), squeeze(carry["edge_data"]),
                carry["active"][0], carry["priority"][0], carry["globals"],
                carry["superstep"], carry["n_updates"][0])

    def _state_to_carry(self, state, expand):
        vdata, edata, act, prio, globals_, step, n_upd = state
        return dict(vertex_data=expand(vdata), edge_data=expand(edata),
                    active=act[None], priority=prio[None],
                    globals=globals_, superstep=step,
                    n_updates=n_upd[None])

    def _program(self, fixed: int | None, ignore_active: bool = False):
        """Jitted shard_map program ``(plan_arrays, carry, stop_at) ->
        carry``.  ``fixed=N`` unrolls exactly N supersteps (``run``'s
        ``num_supersteps`` form, ``stop_at`` ignored); ``fixed=None``
        while-loops to ``superstep == stop_at`` — and, unless
        ``ignore_active``, stops early when the global task set drains.
        Programs are cached per (fixed, ignore_active)."""
        key = (fixed, ignore_active)
        cache = self.__dict__.setdefault("_program_cache", {})
        if key in cache:
            return cache[key]
        _, superstep = self._build_step()
        plan, axis, n_colors = self.plan, self.axis, self.plan.n_colors

        def shard_fn(plan_blk, carry, stop_at):
            # blocks arrive with leading dim 1; squeeze it
            plan_b = jax.tree.map(lambda a: a[0], plan_blk)
            squeeze = lambda t: jax.tree.map(lambda a: a[0], t)
            struct = plan.local_struct(plan_b)
            state = self._state_from_carry(carry, squeeze)

            def body(state):
                return superstep(state, struct, plan_b, n_colors)

            if fixed is not None:
                for _ in range(fixed):
                    state = body(state)
            else:
                def cond(state):
                    below = state[5] < stop_at
                    if ignore_active:
                        return below
                    act_l = state[2] & plan_b["owned_mask"]
                    total = jax.lax.psum(act_l.sum(dtype=jnp.int32), axis)
                    return (total > 0) & below
                state = jax.lax.while_loop(cond, body, state)
            expand = lambda t: jax.tree.map(lambda a: a[None], t)
            return self._state_to_carry(state, expand)

        from jax.experimental.shard_map import shard_map
        fn = shard_map(
            shard_fn, mesh=self.mesh,
            in_specs=(P(self.axis), self._carry_specs(), P()),
            out_specs=self._carry_specs(),
            check_rep=False)
        cache[key] = jax.jit(fn)
        return cache[key]

    def _commit_carry(self, carry: dict) -> dict:
        """Place carry leaves with the program's shardings.  Fresh
        ``init_carry`` / snapshot-restored leaves are uncommitted
        single-device arrays, which key a *separate* jit cache entry
        from program-returned carries — without this, the first chunk
        run on a returned carry pays a full recompile.  No-copy no-op
        for already-committed carries."""
        from jax.sharding import NamedSharding
        specs = self._carry_specs()
        return {k: jax.device_put(v, NamedSharding(self.mesh, specs[k]))
                for k, v in carry.items()}

    def step_chunk(self, carry: dict, stop_at: int,
                   ignore_active: bool = False) -> dict:
        """Advance ``carry`` to superstep ``stop_at`` (or until the task
        set drains, unless ``ignore_active``).  Chunking a run this way
        is bitwise-identical to the fused ``run()`` — the loop body is
        the same traced program, only the cut points differ.

        ``fault_hook`` (set by ``repro.ft.runner`` when a FaultPlan is
        active; absent otherwise — zero cost) fires host-side at this
        superstep boundary, before the chunk launches: the compiled
        program never branches on it."""
        hook = getattr(self, "fault_hook", None)
        if hook is not None:
            hook("superstep", superstep=int(carry["superstep"]))
        prog = self._program(None, ignore_active)
        with jax.transfer_guard("allow"):
            return prog(self._plan_arrays, self._commit_carry(carry),
                        jnp.int32(stop_at))

    def carry_active_any(self, carry: dict) -> bool:
        return bool((np.asarray(carry["active"])
                     & np.asarray(self.plan.owned_mask)).any())

    def finalize(self, carry: dict) -> dict:
        plan = self.plan
        return dict(
            vertex_data=plan.unshard_vertex_data(
                carry["vertex_data"], self.graph.n_vertices),
            local_vertex_data=carry["vertex_data"],
            local_edge_data=carry["edge_data"],
            globals=carry["globals"],
            supersteps=int(carry["superstep"]),
            n_updates=int(np.asarray(carry["n_updates"]).sum()),
            active_any=self.carry_active_any(carry),
        )

    def run(self, active: np.ndarray | None = None,
            num_supersteps: int | None = None):
        carry = self.init_carry(active)
        prog = self._program(num_supersteps)
        with jax.transfer_guard("allow"):
            carry = prog(self._plan_arrays, carry,
                         jnp.int32(self.max_supersteps))
        return self.finalize(carry)


# the locking engine registers its own shard_map variant in
# repro.core.engine_locking; the two registry halves join at lookup
register_distributed("chromatic", DistributedChromaticEngine)
