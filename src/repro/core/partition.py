"""Two-phase distributed graph partitioning (paper §4.1).

Phase 1: over-partition the graph into k >> M *atoms* (the paper uses an
expert or Metis; we implement BFS region growing, which gives connected,
balanced atoms — adequate for the paper's purposes and dependency-free).

Phase 2: build the weighted *meta-graph* (atom weight = data size, edge
weight = #cut edges) and balance atoms onto M machines with a greedy
LPT + affinity heuristic.  Because phase 1 is machine-count independent,
one over-partitioning is reused for any cluster size — the paper's
motivating property for cloud elasticity.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class MetaGraph:
    k: int
    vertex_weight: np.ndarray       # [k] data size per atom
    edge_weight: dict               # {(a, b): #cut edges}, a < b
    atom_of: np.ndarray             # [Nv] atom assignment


def over_partition(n_vertices: int, edges: np.ndarray, k: int,
                   vertex_weight: np.ndarray | None = None,
                   seed: int = 0) -> np.ndarray:
    """BFS region growing into k atoms of ~equal weight."""
    if vertex_weight is None:
        vertex_weight = np.ones(n_vertices)
    adj: list[list[int]] = [[] for _ in range(n_vertices)]
    for u, v in np.asarray(edges, dtype=np.int64):
        if u != v:
            adj[int(u)].append(int(v))
            adj[int(v)].append(int(u))
    target = vertex_weight.sum() / k
    atom_of = np.full(n_vertices, -1, dtype=np.int64)
    rng = np.random.default_rng(seed)
    order = rng.permutation(n_vertices)
    cur_atom, cur_w = 0, 0.0
    from collections import deque
    frontier: deque[int] = deque()
    ptr = 0
    while True:
        if not frontier:
            while ptr < n_vertices and atom_of[order[ptr]] >= 0:
                ptr += 1
            if ptr >= n_vertices:
                break
            frontier.append(int(order[ptr]))
        v = frontier.popleft()
        if atom_of[v] >= 0:
            continue
        atom_of[v] = cur_atom
        cur_w += vertex_weight[v]
        for u in adj[v]:
            if atom_of[u] < 0:
                frontier.append(u)
        if cur_w >= target and cur_atom < k - 1:
            cur_atom += 1
            cur_w = 0.0
            frontier.clear()
    return atom_of


def build_meta_graph(atom_of: np.ndarray, edges: np.ndarray, k: int,
                     vertex_weight: np.ndarray | None = None) -> MetaGraph:
    nv = len(atom_of)
    if vertex_weight is None:
        vertex_weight = np.ones(nv)
    vw = np.zeros(k)
    np.add.at(vw, atom_of, vertex_weight)
    ew: dict = {}
    for u, v in np.asarray(edges, dtype=np.int64):
        a, b = atom_of[int(u)], atom_of[int(v)]
        if a == b:
            continue
        key = (min(a, b), max(a, b))
        ew[key] = ew.get(key, 0) + 1
    return MetaGraph(k=k, vertex_weight=vw, edge_weight=ew, atom_of=atom_of)


def balance_meta_graph(meta: MetaGraph, n_machines: int) -> np.ndarray:
    """Greedy LPT with edge-affinity tie-breaking: assign heavy atoms
    first to the least-loaded machine, preferring machines already holding
    neighboring atoms (reduces the cut, i.e. ghost volume)."""
    k = meta.k
    nbrs: list[dict] = [dict() for _ in range(k)]
    for (a, b), w in meta.edge_weight.items():
        nbrs[a][b] = w
        nbrs[b][a] = w
    load = np.zeros(n_machines)
    machine_of = np.full(k, -1, dtype=np.int64)
    for a in np.argsort(-meta.vertex_weight, kind="stable"):
        affinity = np.zeros(n_machines)
        for b, w in nbrs[a].items():
            if machine_of[b] >= 0:
                affinity[machine_of[b]] += w
        # least loaded among machines, nudged by affinity
        score = load - 1e-9 * affinity
        m = int(np.argmin(score))
        machine_of[a] = m
        load[m] += meta.vertex_weight[a]
    return machine_of


def two_phase_partition(n_vertices: int, edges: np.ndarray, n_machines: int,
                        k: int | None = None,
                        vertex_weight: np.ndarray | None = None,
                        seed: int = 0) -> np.ndarray:
    """Returns [Nv] machine assignment via atoms -> meta-graph -> LPT."""
    if k is None:
        k = min(max(4 * n_machines, 8), n_vertices)
    atom_of = over_partition(n_vertices, edges, k, vertex_weight, seed)
    meta = build_meta_graph(atom_of, edges, k, vertex_weight)
    machine_of_atom = balance_meta_graph(meta, n_machines)
    return machine_of_atom[atom_of]


def split_slot_weight(degrees: np.ndarray, w_cap: int) -> np.ndarray:
    """Per-vertex slot cost under hub splitting, for ``vertex_weight=``.

    With rows wider than ``w_cap`` chunked into virtual rows
    (``graph.split_hub_rows``), a vertex's storage/compute footprint on
    its shard is the padded slots of its chunks — full chunks cost
    exactly ``w_cap``, the remainder rounds up to its covering
    power-of-two bucket — not its raw degree.  Feeding this to
    ``two_phase_partition`` balances shards by post-split work, so one
    hub no longer forces its whole ``max_deg`` onto a single machine's
    load estimate.
    """
    deg = np.maximum(np.asarray(degrees, dtype=np.int64), 1)
    if w_cap < 2 or (w_cap & (w_cap - 1)):
        raise ValueError(
            f"w_cap={w_cap!r}: legal values are a power of two >= 2 "
            "(e.g. 2, 4, ..., 64)")
    full, rem = deg // w_cap, deg % w_cap
    # smallest power of two covering the remainder (0 -> no extra chunk)
    rem_pad = np.where(rem > 0, 2 ** np.ceil(np.log2(np.maximum(rem, 2))), 0)
    return (full * w_cap + rem_pad.astype(np.int64)).astype(np.int64)


def random_partition(n_vertices: int, n_machines: int, seed: int = 0) -> np.ndarray:
    """The paper's baseline for dense bipartite graphs (Netflix, NER)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_machines, n_vertices)


def cut_edges(assignment: np.ndarray, edges: np.ndarray) -> int:
    a = np.asarray(assignment)
    e = np.asarray(edges, dtype=np.int64)
    return int((a[e[:, 0]] != a[e[:, 1]]).sum())
