"""Two-phase distributed graph partitioning (paper §4.1).

Phase 1: over-partition the graph into k >> M *atoms* (the paper uses an
expert or Metis; we implement BFS region growing, which gives connected,
balanced atoms — adequate for the paper's purposes and dependency-free).

Phase 2: build the weighted *meta-graph* (atom weight = data size, edge
weight = #cut edges) and balance atoms onto M machines with a greedy
LPT + affinity heuristic.  Because phase 1 is machine-count independent,
one over-partitioning is reused for any cluster size — the paper's
motivating property for cloud elasticity.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class MetaGraph:
    k: int
    vertex_weight: np.ndarray       # [k] data size per atom
    edge_weight: dict               # {(a, b): #cut edges}, a < b
    atom_of: np.ndarray             # [Nv] atom assignment


def over_partition(n_vertices: int, edges: np.ndarray, k: int,
                   vertex_weight: np.ndarray | None = None,
                   seed: int = 0) -> np.ndarray:
    """BFS region growing into k atoms of ~equal weight."""
    if vertex_weight is None:
        vertex_weight = np.ones(n_vertices)
    adj: list[list[int]] = [[] for _ in range(n_vertices)]
    for u, v in np.asarray(edges, dtype=np.int64):
        if u != v:
            adj[int(u)].append(int(v))
            adj[int(v)].append(int(u))
    target = vertex_weight.sum() / k
    atom_of = np.full(n_vertices, -1, dtype=np.int64)
    rng = np.random.default_rng(seed)
    order = rng.permutation(n_vertices)
    cur_atom, cur_w = 0, 0.0
    from collections import deque
    frontier: deque[int] = deque()
    ptr = 0
    while True:
        if not frontier:
            while ptr < n_vertices and atom_of[order[ptr]] >= 0:
                ptr += 1
            if ptr >= n_vertices:
                break
            frontier.append(int(order[ptr]))
        v = frontier.popleft()
        if atom_of[v] >= 0:
            continue
        atom_of[v] = cur_atom
        cur_w += vertex_weight[v]
        for u in adj[v]:
            if atom_of[u] < 0:
                frontier.append(u)
        if cur_w >= target and cur_atom < k - 1:
            cur_atom += 1
            cur_w = 0.0
            frontier.clear()
    return atom_of


def build_meta_graph(atom_of: np.ndarray, edges: np.ndarray, k: int,
                     vertex_weight: np.ndarray | None = None) -> MetaGraph:
    nv = len(atom_of)
    if vertex_weight is None:
        vertex_weight = np.ones(nv)
    vw = np.zeros(k)
    np.add.at(vw, atom_of, vertex_weight)
    ew: dict = {}
    for u, v in np.asarray(edges, dtype=np.int64):
        a, b = atom_of[int(u)], atom_of[int(v)]
        if a == b:
            continue
        key = (min(a, b), max(a, b))
        ew[key] = ew.get(key, 0) + 1
    return MetaGraph(k=k, vertex_weight=vw, edge_weight=ew, atom_of=atom_of)


def balance_meta_graph(meta: MetaGraph, n_machines: int) -> np.ndarray:
    """Greedy LPT with edge-affinity tie-breaking: assign heavy atoms
    first to the least-loaded machine, preferring machines already holding
    neighboring atoms (reduces the cut, i.e. ghost volume)."""
    k = meta.k
    nbrs: list[dict] = [dict() for _ in range(k)]
    for (a, b), w in meta.edge_weight.items():
        nbrs[a][b] = w
        nbrs[b][a] = w
    load = np.zeros(n_machines)
    machine_of = np.full(k, -1, dtype=np.int64)
    for a in np.argsort(-meta.vertex_weight, kind="stable"):
        affinity = np.zeros(n_machines)
        for b, w in nbrs[a].items():
            if machine_of[b] >= 0:
                affinity[machine_of[b]] += w
        # least loaded among machines, nudged by affinity
        score = load - 1e-9 * affinity
        m = int(np.argmin(score))
        machine_of[a] = m
        load[m] += meta.vertex_weight[a]
    return machine_of


def two_phase_partition(n_vertices: int, edges: np.ndarray, n_machines: int,
                        k: int | None = None,
                        vertex_weight: np.ndarray | None = None,
                        seed: int = 0,
                        cost_model=None,
                        n_candidates: int = 4,
                        w_cap: int | None = None) -> np.ndarray:
    """Returns [Nv] machine assignment via atoms -> meta-graph -> LPT.

    With a fitted ``cost_model`` (DESIGN.md §11) the BFS seeding is no
    longer trusted blindly: ``n_candidates`` over-partitionings (seeds
    ``seed .. seed + n_candidates - 1``) are balanced and scored by
    :func:`predicted_step_time` — the model's per-shard compute plus
    ghost rows times the measured sync cost — and the cheapest wins.
    The edge-cut-affinity heuristic still shapes every candidate; the
    model only arbitrates between them, so ``cost_model=None`` (one
    candidate, today's objective) is bit-identical to the pre-model
    code.
    """
    if k is None:
        k = min(max(4 * n_machines, 8), n_vertices)

    def build(s):
        atom_of = over_partition(n_vertices, edges, k, vertex_weight, s)
        meta = build_meta_graph(atom_of, edges, k, vertex_weight)
        return balance_meta_graph(meta, n_machines)[atom_of]

    if cost_model is None or n_candidates <= 1:
        return build(seed)
    degrees = np.zeros(n_vertices, dtype=np.int64)
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    for col in (0, 1):
        np.add.at(degrees, e[:, col], 1)
    best = None
    for s in range(seed, seed + n_candidates):
        assignment = build(s)
        t = predicted_step_time(assignment, degrees, edges, n_machines,
                                cost_model, w_cap=w_cap)
        score = (np.inf if t is None else t, s)
        if best is None or score < best[0]:
            best = (score, assignment)
    return best[1]


def split_slot_weight(degrees: np.ndarray, w_cap: int) -> np.ndarray:
    """Per-vertex slot cost under hub splitting, for ``vertex_weight=``.

    With rows wider than ``w_cap`` chunked into virtual rows
    (``graph.split_hub_rows``), a vertex's storage/compute footprint on
    its shard is the padded slots of its chunks — full chunks cost
    exactly ``w_cap``, the remainder rounds up to its covering
    power-of-two bucket — not its raw degree.  Feeding this to
    ``two_phase_partition`` balances shards by post-split work, so one
    hub no longer forces its whole ``max_deg`` onto a single machine's
    load estimate.
    """
    deg = np.maximum(np.asarray(degrees, dtype=np.int64), 1)
    if w_cap < 2 or (w_cap & (w_cap - 1)):
        raise ValueError(
            f"w_cap={w_cap!r}: legal values are a power of two >= 2 "
            "(e.g. 2, 4, ..., 64)")
    full, rem = deg // w_cap, deg % w_cap
    # smallest power of two covering the remainder (0 -> no extra chunk)
    rem_pad = np.where(rem > 0, 2 ** np.ceil(np.log2(np.maximum(rem, 2))), 0)
    return (full * w_cap + rem_pad.astype(np.int64)).astype(np.int64)


def random_partition(n_vertices: int, n_machines: int, seed: int = 0) -> np.ndarray:
    """The paper's baseline for dense bipartite graphs (Netflix, NER)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_machines, n_vertices)


def cut_edges(assignment: np.ndarray, edges: np.ndarray) -> int:
    a = np.asarray(assignment)
    e = np.asarray(edges, dtype=np.int64)
    return int((a[e[:, 0]] != a[e[:, 1]]).sum())


def ghost_rows(assignment: np.ndarray, edges: np.ndarray,
               n_machines: int) -> np.ndarray:
    """Ghost vertices per machine: distinct foreign-owned vertices
    adjacent to each machine's owned set — the rows its every-superstep
    ghost sync must receive (Distributed GraphLab's comm volume; edge
    cut counts a shared vertex once per edge, ghosts count it once)."""
    a = np.asarray(assignment, dtype=np.int64)
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    # (reader machine, ghost vertex) pairs from both edge directions
    pairs = np.concatenate([
        np.stack([a[e[:, 0]], e[:, 1]], axis=1),
        np.stack([a[e[:, 1]], e[:, 0]], axis=1)])
    pairs = pairs[a[pairs[:, 1]] != pairs[:, 0]]
    if len(pairs):
        pairs = np.unique(pairs, axis=0)
    counts = np.bincount(pairs[:, 0], minlength=n_machines) \
        if len(pairs) else np.zeros(n_machines, dtype=np.int64)
    return counts.astype(np.int64)


def shard_bucket_launches(assignment: np.ndarray, degrees: np.ndarray,
                          n_machines: int,
                          w_cap: int | None = None) -> tuple:
    """The uniform per-bucket ``(width, rows)`` launch sequence a
    ``ShardPlan`` built from this assignment would run every superstep.

    ``ShardPlan.build`` pads every shard's buckets to the max row count
    over shards (shard-uniform shapes are what ``shard_map`` compiles),
    so the compute cost of a partition is one bucket sweep at
    ``rows_b = max_m count_m(b)`` — imbalance shows up as padded rows
    every shard pays for.  ``w_cap`` applies the hub-split chunking
    rule first (mirroring :func:`split_slot_weight`).
    """
    from repro.core.graph import bucket_index, default_bucket_widths
    a = np.asarray(assignment, dtype=np.int64)
    deg = np.maximum(np.asarray(degrees, dtype=np.int64), 0)
    md = max(int(deg.max()) if deg.size else 1, 1)
    if w_cap is not None and md > w_cap:
        widths = default_bucket_widths(w_cap)
    else:
        widths = default_bucket_widths(md)
        w_cap = None
    counts = np.zeros((n_machines, len(widths)), dtype=np.int64)
    for m in range(n_machines):
        dm = deg[a == m]
        if w_cap is not None:
            full, rem = dm // w_cap, dm % w_cap
            has_rem = (rem > 0) | (dm == 0)
            c = np.bincount(bucket_index(widths, rem[has_rem]),
                            minlength=len(widths))
            c[-1] += int(full.sum())
        else:
            c = np.bincount(bucket_index(widths, dm), minlength=len(widths))
        counts[m] = c
    uniform = counts.max(axis=0)
    return tuple((int(w), int(c)) for w, c in zip(widths, uniform) if c)


def predicted_step_time(assignment: np.ndarray, degrees: np.ndarray,
                        edges: np.ndarray, n_machines: int, cost_model,
                        w_cap: int | None = None) -> float | None:
    """Model-predicted distributed superstep microseconds (DESIGN.md §11).

    Compute: the cost model priced over the shard-uniform bucket
    launches (every shard runs the same padded shapes, so one sweep's
    prediction is the per-shard compute).  Communication: the slowest
    machine's ghost count times the measured per-row sync cost.
    ``None`` when the model cannot price the launch shapes — callers
    treat that as "no opinion" and keep the edge-cut objective.
    """
    launches = shard_bucket_launches(assignment, degrees, n_machines,
                                     w_cap=w_cap)
    compute = cost_model.predict_launches(launches)
    if compute is None:
        return None
    ghosts = ghost_rows(assignment, edges, n_machines)
    sync = float(getattr(cost_model, "sync_cost_us", 0.0))
    return compute + sync * float(ghosts.max() if len(ghosts) else 0)
