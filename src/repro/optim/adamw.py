"""AdamW with decoupled weight decay + cosine schedule (pure JAX).

Optimizer moments are fp32 regardless of param dtype (bf16 params +
fp32 m/v is the memory budget the roofline assumes: 2 + 8 bytes/param).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    m: PyTree
    v: PyTree
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def init(params: PyTree) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(m=jax.tree.map(z, params), v=jax.tree.map(z, params),
                      step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads: PyTree, state: AdamWState,
           params: PyTree):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step)
        vh = v / (1 - b2 ** step)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda o: isinstance(o, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    return new_params, AdamWState(new_m, new_v, step), {
        "grad_norm": gnorm, "lr": lr}
