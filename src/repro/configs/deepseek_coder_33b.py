"""DeepSeek-Coder 33B [arXiv:2401.14196]: llama-arch dense, GQA kv=8."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-coder-33b", arch_type="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256, rope_theta=1e5,
    serve_window=8192,
    source="arXiv:2401.14196"))
