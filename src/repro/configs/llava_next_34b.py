"""LLaVA-NeXT 34B [hf:llava-hf/llava-v1.6-mistral-7b-hf family].

Vision frontend is a stub per the brief: input_specs provides projected
anyres patch embeddings (base 576 + 4 tiles x 576 = 2880 tokens).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b", arch_type="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, rope_theta=5e6,
    frontend="vision", n_frontend_tokens=2880,
    serve_window=8192,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34B per assignment)"))
