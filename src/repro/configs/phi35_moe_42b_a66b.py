"""Phi-3.5-MoE 42B-A6.6B [hf:microsoft/Phi-3.5-MoE-instruct]: 16e top-2."""
from repro.configs.base import ModelConfig, MoECfg, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b", arch_type="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=0, vocab=32064, rope_theta=1e4,
    moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=6400),
    serve_window=8192,
    source="hf:microsoft/Phi-3.5-MoE-instruct"))
