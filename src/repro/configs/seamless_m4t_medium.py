"""SeamlessM4T-medium [arXiv:2308.11596]: encoder-decoder, multimodal.

The conv/mel audio frontend is a stub per the brief: input_specs provides
frame embeddings [B, T, d_model].  12 encoder + 12 decoder layers
(m4t-medium text stack); GQA kv=16 == MHA.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium", arch_type="audio",
    n_layers=12, n_enc_layers=12, enc_dec=True,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, rope_theta=1e4,
    frontend="audio", act="gelu",
    serve_window=8192,
    source="arXiv:2308.11596"))
