"""Assigned-architecture registry: import to populate REGISTRY."""
from repro.configs.base import (INPUT_SHAPES, REGISTRY, InputShape,
                                ModelConfig, MoECfg, SSMCfg)
from repro.configs import (qwen3_moe_235b_a22b, llava_next_34b, qwen3_4b,
                           phi35_moe_42b_a66b, deepseek_coder_33b,
                           seamless_m4t_medium, stablelm_3b,
                           falcon_mamba_7b, jamba_15_large_398b, gemma_7b)

ARCHS = sorted(REGISTRY)


def get(name: str) -> ModelConfig:
    return REGISTRY[name]
