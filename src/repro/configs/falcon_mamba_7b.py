"""Falcon-Mamba 7B [arXiv:2410.05355]: pure Mamba-1, attention-free."""
from repro.configs.base import ModelConfig, SSMCfg, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b", arch_type="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=65024,
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2),
    source="arXiv:2410.05355"))
