"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family; sizes per assignment].

128 experts top-8, GQA kv=4, qk_norm, head_dim=128 (Qwen3 family uses 128).
"""
from repro.configs.base import ModelConfig, MoECfg, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b", arch_type="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab=151936, qk_norm=True, rope_theta=1e6,
    moe=MoECfg(n_experts=128, top_k=8, d_ff_expert=1536),
    serve_window=8192,
    source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment)"))
