"""Gemma-7B [arXiv:2403.08295]: GeGLU, head_dim=256, tied embeddings."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-7b", arch_type="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000, geglu=True, act="gelu", rope_theta=1e4,
    tie_embeddings=True, serve_window=8192,
    source="arXiv:2403.08295"))
