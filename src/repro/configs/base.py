"""Architecture config schema + input-shape registry.

Every assigned architecture gets a ``configs/<id>.py`` exporting
``CONFIG`` (exact sizes from the assignment, source cited) and the four
global input shapes are defined here.  ``reduced()`` derives the smoke
variant (2 layers, d_model <= 512, <= 4 experts) exercised by per-arch
CPU tests; the full configs are touched only by the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    every: int = 1            # MoE every k-th layer (jamba: 2)


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None   # default ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    qk_norm: bool = False
    geglu: bool = False                  # GeGLU MLP (gemma)
    act: str = "silu"
    rope_theta: float = 1e6
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    attn_every: int = 1                  # hybrid: attention layer period
    window: int | None = None            # training-time sliding window
    serve_window: int | None = None      # serving window for long-context
    enc_dec: bool = False                # seamless: encoder-decoder
    n_enc_layers: int = 0
    frontend: str | None = None          # "vision" | "audio" stubs
    n_frontend_tokens: int = 0           # image/audio embedding positions
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    source: str = ""                     # citation from the assignment

    @property
    def dh(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand if self.ssm else 2) * self.d_model

    def is_attn_layer(self, i: int) -> bool:
        if self.arch_type == "ssm":
            return False
        if self.attn_every == 1:
            return True
        return i % self.attn_every == 0

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every
                                         == self.moe.every - 1)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, tiny sizes."""
        d = min(self.d_model, 256)
        nh = min(self.n_heads, 4)
        nkv = max(1, min(self.n_kv_heads, nh))
        layers = 2 if self.attn_every == 1 else min(self.n_layers,
                                                    self.attn_every)
        return dataclasses.replace(
            self,
            n_layers=layers,
            d_model=d,
            n_heads=nh,
            n_kv_heads=nkv,
            head_dim=(64 if self.head_dim else None),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            moe=(dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 256))
                if self.moe else None),
            n_enc_layers=min(self.n_enc_layers, 2),
            window=(min(self.window, 64) if self.window else None),
            serve_window=(min(self.serve_window, 64)
                          if self.serve_window else None),
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
        )

    # ------------------------------------------------------------------
    def param_count(self) -> dict:
        """Analytic parameter counts (total + active) for the roofline."""
        d, dh = self.d_model, self.dh
        attn = d * dh * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * dh * d
        mlp_mult = 3 if not self.geglu else 3   # gate+up+down
        dense_mlp = mlp_mult * d * self.d_ff if self.d_ff else 0
        mamba = 0
        if self.ssm is not None:
            di, ds = self.d_inner, self.ssm.d_state
            dtr = self.ssm.dt_rank or -(-d // 16)
            mamba = (d * 2 * di            # in_proj
                     + di * self.ssm.d_conv
                     + di * (dtr + 2 * ds)  # x -> dt, B, C
                     + dtr * di
                     + di * ds + di        # A, D
                     + di * d)             # out_proj
        total = 0
        active = 0
        layers = self.n_layers + self.n_enc_layers
        for i in range(self.n_layers):
            la = attn if self.is_attn_layer(i) else mamba
            if self.is_moe_layer(i):
                lm_total = 3 * d * self.moe.d_ff_expert * self.moe.n_experts
                lm_active = 3 * d * self.moe.d_ff_expert * self.moe.top_k
                lm_total += d * self.moe.n_experts   # router
                lm_active += d * self.moe.n_experts
            else:
                lm_total = lm_active = dense_mlp
            total += la + lm_total
            active += la + lm_active
        for i in range(self.n_enc_layers):
            total += attn + dense_mlp
            active += attn + dense_mlp
        if self.enc_dec:   # decoder cross-attention
            total += self.n_layers * attn
            active += self.n_layers * attn
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return {"total": total + emb, "active": active + emb,
                "embed": emb}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# populated by repro.configs.__init__
REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg
