"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b family]: kv=32 (MHA)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-3b", arch_type="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304, rope_theta=1e4,
    serve_window=8192,
    source="hf:stabilityai/stablelm-2-1_6b (3B sizes per assignment)"))
