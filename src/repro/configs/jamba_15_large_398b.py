"""Jamba-1.5-Large 398B [arXiv:2403.19887]: Mamba+attention 1:7, MoE 16e top-2.

Every 8th layer is attention (attn_every=8), MoE on every 2nd layer
(moe.every=2), head_dim=128.
"""
from repro.configs.base import ModelConfig, MoECfg, SSMCfg, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b", arch_type="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536, rope_theta=1e6,
    moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=24576, every=2),
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2),
    attn_every=8,
    source="arXiv:2403.19887"))
