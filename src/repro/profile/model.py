"""Fitted per-bucket-width launch cost model (DESIGN.md §11).

``fit_cost_model`` least-squares a line ``t(W, B) ~= a_W + b_W * B * W``
per distinct launch width over a trace's warm launch records, plus one
pooled line over all widths (the fallback for widths never measured)
and a per-ghost-row sync cost from the trace's ``sync`` records.

Fits are clamped so every predicted curve is monotone non-decreasing in
the padded slot count ``B * W`` for fixed ``W``: a negative slope —
always measurement noise at these scales, never physics — collapses to
the flat line through the sample mean.  That clamp is what makes the
model safe to hand to ``choose_dispatch``: predictions order the same
way slot counts do within a width, so a degenerate trace can bias the
batch/bucket crossover but never invert it arbitrarily.

``predict`` returns ``None`` (never a guess) when the model has no
data for a shape and no pooled fallback; every consumer treats ``None``
as "fall back to the static slot-count rule", which keeps the
zero-trace behavior bit-for-bit identical to the pre-model code.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib

import numpy as np

from repro.profile.trace import results_dir


def _fit_line(x: np.ndarray, y: np.ndarray) -> tuple[float, float]:
    """Least-squares ``y ~= a + b*x`` with ``b >= 0`` and ``a >= 0``.

    Under one distinct x (or a negative fitted slope) the fit collapses
    to the flat mean line — monotone by construction.
    """
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    if len(np.unique(x)) < 2:
        return float(max(y.mean(), 0.0)), 0.0
    b, a = np.polyfit(x, y, 1)
    if b < 0:
        return float(max(y.mean(), 0.0)), 0.0
    a = max(float(a), 0.0)
    return a, float(b)


def _usable_fit_records(records) -> list[dict]:
    """Warm single-launch records: ``launch`` kind, or single-phase
    batch-mode ``step`` records (one launch, so shape is known)."""
    out = []
    for r in records:
        if r.get("cold") or "width" not in r or "rows" not in r:
            continue
        if r.get("kind") == "launch":
            out.append(r)
        elif (r.get("kind") == "step" and r.get("mode") == "batch"
              and r.get("phases", 1) == 1):
            out.append(r)
    return out


@dataclasses.dataclass
class CostModel:
    """Predicted launch microseconds from (width, rows) shapes.

    ``coef[W] = (a_W, b_W)`` per measured width; ``pooled`` covers
    unmeasured widths; ``sync_cost_us`` prices one ghost row's exchange
    in the partition objective.  An empty model predicts ``None``
    everywhere — the contract that keeps zero-trace callers on the
    static rule.
    """
    device: str = "unknown"
    coef: dict = dataclasses.field(default_factory=dict)  # {W: (a, b)}
    pooled: tuple | None = None                           # (a, b)
    sync_cost_us: float = 0.0
    n_records: int = 0

    def predict(self, width: int, rows: int) -> float | None:
        """Predicted wall time (us) of one ``[rows, width]`` launch."""
        ab = self.coef.get(int(width), self.pooled)
        if ab is None:
            return None
        a, b = ab
        return a + b * float(rows) * float(width)

    def predict_launches(self, launches) -> float | None:
        """Predicted total for a ``[(W, rows), ...]`` launch sequence
        (e.g. ``SlicedEll.bucket_launches``); ``None`` if any launch
        is unpredictable."""
        total = 0.0
        for w, rows in launches:
            t = self.predict(w, rows)
            if t is None:
                return None
            total += t
        return total

    def to_json(self) -> dict:
        return {"schema": 1, "device": self.device,
                "coef": {str(w): list(ab) for w, ab in
                         sorted(self.coef.items())},
                "pooled": list(self.pooled) if self.pooled else None,
                "sync_cost_us": self.sync_cost_us,
                "n_records": self.n_records}

    def save(self, path: str | os.PathLike | None = None) -> pathlib.Path:
        if path is None:
            path = results_dir() / f"COSTMODEL_{self.device}.json"
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=1))
        return path

    @classmethod
    def load(cls, path: str | os.PathLike) -> "CostModel":
        doc = json.loads(pathlib.Path(path).read_text())
        return cls(device=doc.get("device", "unknown"),
                   coef={int(w): tuple(ab)
                         for w, ab in doc.get("coef", {}).items()},
                   pooled=tuple(doc["pooled"]) if doc.get("pooled") else None,
                   sync_cost_us=float(doc.get("sync_cost_us", 0.0)),
                   n_records=int(doc.get("n_records", 0)))


def fit_cost_model(records, device: str = "unknown") -> CostModel:
    """Fit a :class:`CostModel` from trace records (see module doc)."""
    usable = _usable_fit_records(records)
    coef: dict[int, tuple[float, float]] = {}
    xs_all, ys_all = [], []
    by_width: dict[int, list[dict]] = {}
    for r in usable:
        by_width.setdefault(int(r["width"]), []).append(r)
    for w, rs in by_width.items():
        x = np.array([float(r["rows"]) * w for r in rs])
        y = np.array([r["wall_us"] for r in rs])
        coef[w] = _fit_line(x, y)
        xs_all.append(x)
        ys_all.append(y)
    pooled = None
    if xs_all:
        pooled = _fit_line(np.concatenate(xs_all), np.concatenate(ys_all))
    syncs = [r for r in records
             if r.get("kind") == "sync" and not r.get("cold")
             and r.get("rows")]
    sync_cost = 0.0
    if syncs:
        # per-row slope, clamped >= 0; one sample degrades to wall/rows
        x = np.array([float(r["rows"]) for r in syncs])
        y = np.array([r["wall_us"] for r in syncs])
        if len(np.unique(x)) >= 2:
            b = np.polyfit(x, y, 1)[0]
            sync_cost = float(max(b, 0.0))
        else:
            sync_cost = float(max((y / x).mean(), 0.0))
    return CostModel(device=device, coef=coef, pooled=pooled,
                     sync_cost_us=sync_cost, n_records=len(usable))


def default_device() -> str:
    import jax
    return jax.devices()[0].platform


def load_cost_model(device: str | None = None,
                    path: str | os.PathLike | None = None
                    ) -> CostModel | None:
    """Load ``results/COSTMODEL_<device>.json`` if one exists."""
    if path is None:
        if device is None:
            device = default_device()
        path = results_dir() / f"COSTMODEL_{device}.json"
    path = pathlib.Path(path)
    if not path.exists():
        return None
    return CostModel.load(path)


#: Entry-point group out-of-tree cost models register under
#: (``core/registry.py`` plugin discovery).
COST_MODEL_PLUGIN_GROUP = "repro.cost_models"


def resolve_cost_model(spec) -> CostModel | None:
    """Normalize a ``cost_model=`` argument to a model instance or None.

    Accepts: ``None`` / ``"static"`` (no model — static dispatch rule),
    a :class:`CostModel` (or any object with ``predict`` /
    ``predict_launches``), ``"measured"`` (this device's persisted
    calibration), a path to a ``COSTMODEL_*.json``, or the name of a
    ``repro.cost_models`` entry point (plugin packages).
    """
    if spec is None or spec == "static":
        return None
    if hasattr(spec, "predict") and hasattr(spec, "predict_launches"):
        return spec
    if not isinstance(spec, str):
        raise ValueError(
            f"cost_model must be None, 'static', 'measured', a CostModel, "
            f"a COSTMODEL_*.json path, or a {COST_MODEL_PLUGIN_GROUP!r} "
            f"entry-point name; got {spec!r}")
    if spec == "measured":
        model = load_cost_model()
        if model is None:
            raise ValueError(
                "cost_model='measured' but no "
                f"{results_dir()}/COSTMODEL_*.json exists for this device; "
                "record one with `python -m repro.profile.calibrate` or "
                "api.run(..., profile=True)")
        return model
    p = pathlib.Path(spec)
    if p.suffix == ".json" or p.exists():
        return CostModel.load(p)
    from repro.core.registry import load_plugin
    plugin = load_plugin(COST_MODEL_PLUGIN_GROUP, spec)
    if plugin is not None:
        model = plugin() if callable(plugin) else plugin
        return resolve_cost_model(model)
    raise ValueError(
        f"unknown cost_model {spec!r}: not 'static'/'measured', not an "
        f"existing model file, and no {COST_MODEL_PLUGIN_GROUP!r} "
        f"entry point provides it")
