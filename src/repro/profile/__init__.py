"""Trace-driven cost modelling (DESIGN.md §11).

``trace`` records per-launch wall times (and optional HLO op counts)
from the host stepping loop; ``model`` fits the per-bucket-width linear
cost model ``t(W, B) ~= a_W + b_W * B * W`` that ``choose_dispatch``,
``from_edges(width_policy="measured")`` and ``two_phase_partition``
consume; ``calibrate`` is the CLI that bootstraps a model from
microbenchmarks when no run has been profiled yet.

Only the light, numpy-only halves are re-exported here — importing
``repro.profile`` must not pull in jax or the apps.
"""
from repro.profile.model import (CostModel, fit_cost_model,  # noqa: F401
                                 load_cost_model, resolve_cost_model)
from repro.profile.trace import (SCHEMA_VERSION, TraceRecorder,  # noqa: F401
                                 hlo_counts, load_trace)
