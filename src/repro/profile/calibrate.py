"""Bootstrap a cost model from microbenchmarks: ``python -m
repro.profile.calibrate [--smoke]``.

Replays ``benchmarks/dispatch_window.py``-shaped launches with the
shapes *controlled* instead of scheduler-chosen: for every bucket width
``W`` of a Zipf graph's ladder, windows of ``B`` vertices are sampled
from that bucket's rows (so ``window_bucket`` resolves the batch path
to exactly ``W``) and one full jitted ``apply_batch`` is wall-clocked
per ``(W, B)`` point — the same gather -> kernel -> update -> scatter
-> bookkeeping pipeline a real engine step runs.  Ghost-sync cost is
measured as the per-row slope of a jitted scatter at two sizes.
Optionally each launch's lowered HLO is walked (``roofline/hlo_parse``)
so the trace carries op counts in the shared schema.

Writes ``results/TRACE_<device>.json`` and fits + writes
``results/COSTMODEL_<device>.json`` (see ``repro.profile.model``).
Calibration is strictly off the hot path: nothing here runs unless
invoked, and consuming the model never re-times anything.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.profile.model import CostModel, fit_cost_model
from repro.profile.trace import TraceRecorder, results_dir

SMOKE_SIZES = dict(nv=400, cap=32, batch_sizes=(4, 16, 64), iters=3)
FULL_SIZES = dict(nv=10_000, cap=192, batch_sizes=(8, 64, 512, 4096),
                  iters=5)


def _time_us(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Best-of-N microseconds (same statistic as dispatch_window)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _batch_fn(g, upd, ids, mode: str):
    """One jitted conflict-free batch (dispatch_window's shape)."""
    import jax
    import jax.numpy as jnp

    from repro.core.exec import apply_batch
    nv = g.n_vertices
    valid = jnp.ones(ids.shape, bool)

    def run(vdata):
        carry = (vdata, g.edge_data, jnp.ones((nv,), bool),
                 jnp.ones((nv,), jnp.float32), jnp.int32(0))
        out = apply_batch(g, upd, carry, ids, valid, {}, sentinel=nv,
                          use_kernel=True, interpret=True, dispatch=mode)
        return out[0]
    return jax.jit(run)


def _hlo_of(jfn, *args):
    """HLO op counts of a jitted fn at these args; None on any failure
    (interpret-mode lowerings may not expose a walkable module)."""
    try:
        from repro.roofline.hlo_parse import analyze
        return analyze(jfn.lower(*args).compile().as_text())
    except Exception:
        return None


def _bucket_windows(ell, b: int, batch_sizes, seed: int):
    """Sorted id windows drawn from bucket ``b``'s owned rows (with
    replacement past the bucket's row count, so every ``B`` is
    reachable); all-bucket-``b`` windows pin the batch path's
    ``window_bucket`` to width ``widths[b]``."""
    import jax.numpy as jnp
    s, e = int(ell.starts[b]), int(ell.starts[b + 1])
    rows = np.asarray(ell.perm)[s:e]
    if ell.is_split:
        rows = rows[rows < ell.n_virtual]
        rows = np.asarray(ell.owner_of_vrow)[rows]
    owners = np.unique(rows[rows < ell.n_rows])
    if owners.size == 0:
        return []
    rng = np.random.default_rng(seed + b)
    out = []
    for B in batch_sizes:
        pick = (rng.choice(owners, size=B, replace=B > owners.size)
                if B != owners.size else owners)
        out.append((B, jnp.asarray(np.sort(pick), jnp.int32)))
    return out


def _measure_sync(nv: int, recorder: TraceRecorder, iters: int) -> None:
    """Per-ghost-row sync cost: a jitted row scatter at two sizes."""
    import jax
    import jax.numpy as jnp
    arr = jnp.zeros((nv, 4), jnp.float32)
    fn = jax.jit(lambda a, i, v: a.at[i].set(v))
    for rows in sorted({max(nv // 8, 1), max(nv // 2, 2)}):
        idx = jnp.arange(rows, dtype=jnp.int32)
        vals = jnp.ones((rows, 4), jnp.float32)
        wall = _time_us(fn, arr, idx, vals, iters=iters)
        recorder.record_sync(rows=rows, wall_us=wall)


def calibrate(nv: int, cap: int, batch_sizes, iters: int = 5,
              with_hlo: bool = True, seed: int = 0,
              emit=print) -> tuple[TraceRecorder, CostModel]:
    """Record the microbenchmark trace and fit a model (pure function
    of sizes; callers decide whether to persist)."""
    from repro.apps import pagerank
    from repro.core.graph import zipf_edges
    g = pagerank.make_graph(zipf_edges(nv, alpha=2.0, max_deg=cap,
                                       seed=seed), nv)
    upd = pagerank.make_update(1e-6)
    ell = g.ell
    recorder = TraceRecorder()
    for b, w in enumerate(ell.widths):
        for B, ids in _bucket_windows(ell, b, batch_sizes, seed):
            fn = _batch_fn(g, upd, ids, "batch")
            wall = _time_us(fn, g.vertex_data, iters=iters)
            hlo = _hlo_of(fn, g.vertex_data) if with_hlo else None
            recorder.record_launch(mode="batch", width=w, rows=B,
                                   wall_us=wall, hlo=hlo)
            emit(f"calibrate_w{w}_B{B},{wall:.1f},slots={B * w}")
    # one full bucket sweep for replay/validation (not a fit point)
    import jax.numpy as jnp
    ids_all = jnp.arange(g.n_vertices, dtype=jnp.int32)
    fn = _batch_fn(g, upd, ids_all, "bucket")
    recorder.record_step(mode="bucket", wall_us=_time_us(
        fn, g.vertex_data, iters=iters), launches=ell.bucket_launches)
    _measure_sync(nv, recorder, iters)
    model = fit_cost_model(recorder.records, device=recorder.device)
    return recorder, model


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="record a launch-cost trace and fit "
                    "results/COSTMODEL_<device>.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--nv", type=int, default=None)
    ap.add_argument("--cap", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip HLO op-count capture")
    args = ap.parse_args(argv)
    sizes = dict(SMOKE_SIZES if args.smoke else FULL_SIZES)
    for key in ("nv", "cap", "iters"):
        if getattr(args, key) is not None:
            sizes[key] = getattr(args, key)
    recorder, model = calibrate(with_hlo=not args.no_hlo,
                                seed=args.seed, **sizes)
    tpath = recorder.save()
    mpath = model.save()
    print(f"# {len(recorder.records)} records -> {tpath}")
    print(f"# fitted {len(model.coef)} widths, "
          f"sync={model.sync_cost_us:.4f} us/row -> {mpath}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
