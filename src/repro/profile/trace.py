"""Per-launch timing traces: the raw material the cost model fits.

A trace is a list of flat JSON records sharing one schema with the
``results/hlo/`` artifacts (``roofline/reanalyze.py`` nests the same
``hlo_counts`` dict under the same ``"hlo"`` key).  Three record kinds:

* ``launch`` — one timed kernel launch at a known shape:
  ``{"kind": "launch", "mode": "batch"|"bucket", "width": W,
  "rows": B, "wall_us": t, "cold": bool, "hlo": {...}?}``.
  ``width * rows`` is the padded slot count the model regresses on.
* ``step`` — one engine superstep from ``api.run(profile=True)``:
  same fields plus ``"phases"`` and, for bucket-mode steps, a
  ``"launches": [[W_b, rows_b], ...]`` composite instead of a single
  ``width``/``rows`` pair.  Only single-launch batch steps are usable
  as fit points; composite steps are kept for replay/validation.
* ``sync`` — one timed ghost-write-sized scatter:
  ``{"kind": "sync", "rows": H, "wall_us": t}``; fits the per-ghost-row
  ``sync_cost_us`` the partition objective charges.

Recording happens only on the host stepping path (``api.run`` with
``profile=True``), never inside the fused while-loop — see DESIGN.md
§11 for why calibration lives off the hot path.
"""
from __future__ import annotations

import json
import os
import pathlib

SCHEMA_VERSION = 1

#: Keys of the shared HLO-count schema (subset of roofline's ``Cost``).
HLO_KEYS = ("flops", "hbm_bytes", "coll_bytes")


def results_dir() -> pathlib.Path:
    """Artifact directory: ``$REPRO_RESULTS_DIR`` or ``./results``."""
    return pathlib.Path(os.environ.get("REPRO_RESULTS_DIR", "results"))


def hlo_counts(cost) -> dict:
    """Project a roofline ``Cost`` onto the shared trace schema.

    Accepts anything with ``flops`` / ``bytes`` / ``coll_bytes``
    attributes; both the timing traces here and the reanalyzed
    ``results/hlo/`` rows carry this dict under an ``"hlo"`` key, so
    one reader serves both artifact families.
    """
    d = {"flops": int(cost.flops), "hbm_bytes": int(cost.bytes),
         "coll_bytes": int(cost.coll_bytes)}
    br = getattr(cost, "coll_breakdown", None)
    if br:
        d["coll_breakdown"] = {k: int(v) for k, v in dict(br).items()}
    return d


class TraceRecorder:
    """Append-only launch/step/sync record sink with JSON persistence."""

    def __init__(self, device: str | None = None):
        if device is None:
            import jax
            device = jax.devices()[0].platform
        self.device = device
        self.records: list[dict] = []

    def record_launch(self, *, mode: str, width: int, rows: int,
                      wall_us: float, cold: bool = False, hlo=None,
                      **extra) -> dict:
        rec = {"kind": "launch", "mode": mode, "width": int(width),
               "rows": int(rows), "wall_us": float(wall_us),
               "cold": bool(cold), **extra}
        if hlo is not None:
            rec["hlo"] = hlo_counts(hlo) if hasattr(hlo, "flops") else hlo
        self.records.append(rec)
        return rec

    def record_step(self, *, mode: str, wall_us: float, rows=None,
                    width=None, launches=None, phases: int = 1,
                    cold: bool = False, **extra) -> dict:
        rec = {"kind": "step", "mode": mode, "wall_us": float(wall_us),
               "phases": int(phases), "cold": bool(cold), **extra}
        if rows is not None:
            rec["rows"] = int(rows)
        if width is not None:
            rec["width"] = int(width)
        if launches is not None:
            rec["launches"] = [[int(w), int(r)] for w, r in launches]
        self.records.append(rec)
        return rec

    def record_sync(self, *, rows: int, wall_us: float,
                    cold: bool = False, **extra) -> dict:
        rec = {"kind": "sync", "rows": int(rows),
               "wall_us": float(wall_us), "cold": bool(cold), **extra}
        self.records.append(rec)
        return rec

    def to_json(self) -> dict:
        return {"schema": SCHEMA_VERSION, "device": self.device,
                "records": self.records}

    def save(self, path: str | os.PathLike | None = None) -> pathlib.Path:
        if path is None:
            path = results_dir() / f"TRACE_{self.device}.json"
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=1))
        return path


def load_trace(path: str | os.PathLike) -> TraceRecorder:
    doc = json.loads(pathlib.Path(path).read_text())
    rec = TraceRecorder(device=doc.get("device", "unknown"))
    rec.records = list(doc.get("records", ()))
    return rec
