"""Re-run the HLO cost walker over cached dry-run HLO (no recompile).

    PYTHONPATH=src python -m repro.roofline.reanalyze \
        [--hlo-dir results/hlo] [--out results/dryrun_16x16.jsonl]

Rewrites the roofline rows for every cached (arch, shape, mesh) whose
memory_analysis fields are merged from the existing JSONL if present.

Output rows nest the walker's op counts under an ``"hlo"`` key in the
shared trace schema (``repro.profile.trace.hlo_counts``) — the same
dict ``results/TRACE_*.json`` launch records carry — so one reader
serves both artifact families.  ``--merge-from`` accepts files in
either layout: the pre-schema flat form (top-level ``coll_breakdown``)
or this nested form.
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro import configs
from repro.configs.base import INPUT_SHAPES
from repro.roofline import analysis, hlo_parse

# row keys carried over verbatim from a --merge-from file (measured on
# real hardware; a reanalysis cannot recompute them)
_MERGE_KEYS = ("memory_analysis", "compile_s", "lower_s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo-dir", default="results/hlo")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--out", default=None)
    ap.add_argument("--merge-from", default=None,
                    help="existing jsonl to take memory_analysis from")
    args = ap.parse_args()

    old = {}
    if args.merge_from and os.path.exists(args.merge_from):
        for line in open(args.merge_from):
            row = json.loads(line)
            old[(row["name"], row["mesh"])] = row

    rows = []
    for path in sorted(glob.glob(f"{args.hlo_dir}/*__{args.mesh}.txt.gz")):
        base = os.path.basename(path)[: -len(".txt.gz")]
        arch, shape_name, mesh_name = base.split("__")
        cfg = configs.get(arch)
        shape = INPUT_SHAPES[shape_name]
        chips = 1
        for part in mesh_name.split("x"):
            chips *= int(part)
        with gzip.open(path, "rt") as f:
            hlo = f.read()
        walked = hlo_parse.analyze(hlo)
        prev = old.get((f"{arch}:{shape_name}", mesh_name), {})
        rf = analysis.Roofline(
            name=f"{arch}:{shape_name}", mesh=mesh_name, chips=chips,
            hlo_flops=walked.flops * chips, hlo_bytes=walked.bytes * chips,
            coll_bytes=walked.coll_bytes * chips,
            model_flops=analysis.model_flops(cfg, shape),
            bytes_per_chip=prev.get("hbm_per_chip_gb", 0) * 1e9)
        row = rf.row()
        row["hlo"] = walked.scaled(chips).counts()
        for key in _MERGE_KEYS:
            if key in prev:
                row[key] = prev[key]
        rows.append(row)
        print(f"{row['name']:45s} Tc={row['t_compute_s']:.3e} "
              f"Tm={row['t_memory_s']:.3e} Tx={row['t_collective_s']:.3e} "
              f"-> {row['bottleneck']}")
    if args.out:
        with open(args.out, "w") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")


if __name__ == "__main__":
    main()
