"""Roofline terms from compiled dry-run artifacts (no hardware needed).

    compute   = HLO_FLOPs / (chips * 197e12)        [bf16 v5e]
    memory    = HLO_bytes / (chips * 819e9)         [HBM]
    collective= collective_bytes / (chips * 50e9)   [per-link ICI, serial]

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
NOT in cost_analysis, so we parse the optimized HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.  ``MODEL_FLOPS`` (6·N·D train dense, 6·N_active·D
MoE, 2·N·D decode) gives the usefulness ratio that flags remat/redundancy
waste.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tf32": 4, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,512,128]{2,1,0} all-gather(...)"
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum of *output* operand bytes per collective kind (counting each
    op once; -start/-done pairs deduped by counting only -start or the
    sync form)."""
    out: dict = {k: 0 for k in _COLLECTIVES}
    counts: dict = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done(" in line:
            continue   # count the -start only
        m = None
        kind = None
        for k in _COLLECTIVES:
            if f" {k}(" in line or f" {k}-start(" in line:
                kind = k
                break
        if kind is None:
            continue
        # left-hand side shape(s)
        lhs = line.split("=")[0] if "=" in line else ""
        rhs = line.split("=", 1)[1] if "=" in line else line
        shapes = _SHAPE_RE.findall(rhs.split(kind)[0])
        total = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] += total
        counts[kind] += 1
    out["counts"] = counts
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    name: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    bytes_per_chip: float        # peak HBM from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def usefulness(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    def row(self) -> dict:
        return {
            "name": self.name, "mesh": self.mesh, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "usefulness": self.usefulness,
            "hbm_per_chip_gb": self.bytes_per_chip / 1e9,
        }


def model_flops(cfg, shape) -> float:
    """6·N·D (train), 2·N·D (forward/decode) with N = active params."""
    pc = cfg.param_count()
    n_active = pc["active"]
    # enc-dec: each token passes the encoder OR the decoder, and the
    # train-seq budget is split between frames and tokens -> halve.
    encdec = 0.5 if cfg.enc_dec else 1.0
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len * encdec
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per request
    return 2.0 * n_active * shape.global_batch


def parse_memory_analysis(mem) -> float:
    """Extract peak bytes per chip from compiled.memory_analysis()."""
    for attr in ("temp_size_in_bytes",):
        if hasattr(mem, attr):
            tot = (getattr(mem, "argument_size_in_bytes", 0)
                   + getattr(mem, "output_size_in_bytes", 0)
                   + getattr(mem, "temp_size_in_bytes", 0)
                   - getattr(mem, "alias_size_in_bytes", 0))
            return float(tot)
    return 0.0
