"""HLO cost walker: FLOPs / HBM bytes / collective bytes with loop trips.

``compiled.cost_analysis()`` visits every computation ONCE — a scan over
94 layers is costed as one layer, making roofline terms meaningless for
scan-over-layers models.  This walker parses the optimized (post-SPMD)
HLO text and accounts properly:

  * ``while`` ops: body cost x trip count (trip count recovered from the
    loop-condition's comparison constant — scans lower to counted loops);
  * ``fusion``: one kernel — FLOPs recurse into the fused computation,
    HBM bytes counted at the fusion boundary only (operands + outputs),
    which is *more* faithful than cost_analysis' per-op bytes;
  * ``dot``: 2 x prod(output) x prod(contracting dims);
  * elementwise arithmetic: 1 FLOP/element; data movement: 0;
  * collectives: output bytes (per-partition shapes), x trips.

All results are PER DEVICE (post-SPMD shapes).  The roofline layer
multiplies by chip count where the spec's global formulas expect totals.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
}

_ARITH_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "sign",
    "floor", "ceil", "round-nearest-afz", "clamp", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "atan2", "power",
}
_ARITH_XFLOP = {"exponential": 4, "log": 4, "rsqrt": 2, "sqrt": 2,
                "tanh": 6, "logistic": 6, "cosine": 4, "sine": 4,
                "expm1": 4, "log1p": 4, "erf": 6, "cbrt": 4,
                "exponential-minus-one": 4}
_DATA_MOVE = {
    "copy", "bitcast", "transpose", "reshape", "slice", "dynamic-slice",
    "dynamic-update-slice", "broadcast", "iota", "constant", "parameter",
    "get-tuple-element", "tuple", "concatenate", "pad", "reverse",
    "convert", "gather", "scatter", "reduce", "reduce-window", "map",
    "sort", "rng", "rng-bit-generator", "after-all", "custom-call",
    "bitcast-convert", "optimization-barrier", "copy-start", "copy-done",
    "partition-id", "replica-id", "domain", "infeed", "outfeed",
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPCODE_RE = re.compile(r"^\(?[a-z0-9]+\[[0-9,]*\][^\s]*\s+([a-z0-9\-]+)\(")
_TUPLE_OPCODE_RE = re.compile(r"^\([^)]*\)\s+([a-z0-9\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_list_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_list_elems(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt == "pred" or dt.startswith(("s", "u")):
            pass
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_text: str          # shape portion of the RHS before the opcode
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    defs: dict             # name -> out_text (shape text)


def parse_computations(hlo: str) -> tuple[dict, str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict = {}
    entry = None
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line or line.startswith(("//", "#")):
            continue
        # computation header: "%name (args) -> type {" or "ENTRY ..."
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                # parameters with shapes are in the header; record them
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|"
                                      r"[a-z0-9]+\[[0-9,]*\][^,)]*)", line):
                    cur.defs[pm.group(1)] = pm.group(2)
            continue
        if line == "}" or line.startswith("}"):
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # opcode = token right before the first '('
        om = _OPCODE_RE.match(rhs) or _TUPLE_OPCODE_RE.match(rhs)
        if om:
            opcode = om.group(1)
        else:
            om2 = re.match(r"^.*?\s([a-z0-9\-]+)\(", rhs)
            opcode = om2.group(1) if om2 else "unknown"
        out_text = rhs.split(opcode + "(")[0]
        cur.defs[name] = out_text
        cur.ops.append(Op(name, opcode, out_text, line))
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Scan loops compare the induction var against a constant bound."""
    consts = []
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                consts.append(int(m.group(1)))
    # header-declared constants too
    for line_consts in re.findall(r"constant\((-?\d+)\)",
                                  " ".join(o.line for o in cond.ops)):
        consts.append(int(line_consts))
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    bytes_by_op: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_breakdown.items():
            self.coll_breakdown[k] += v
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] += v
        return self

    def scaled(self, k: float) -> "Cost":
        c = Cost(self.flops * k, self.bytes * k, self.coll_bytes * k)
        c.coll_breakdown = defaultdict(
            float, {kk: v * k for kk, v in self.coll_breakdown.items()})
        c.bytes_by_op = defaultdict(
            float, {kk: v * k for kk, v in self.bytes_by_op.items()})
        return c

    def counts(self) -> dict:
        """This cost in the shared trace schema (the ``"hlo"`` dict both
        ``results/TRACE_*.json`` launch records and reanalyzed
        ``results/hlo/`` rows carry — ``repro.profile.trace``)."""
        from repro.profile.trace import hlo_counts
        return hlo_counts(self)


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _shape_list_elems(op.out_text)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    operands = _OPERAND_RE.findall(op.line.split(op.opcode + "(", 1)[1])
    contract = 1
    if m and operands:
        lhs_text = comp.defs.get(operands[0], "")
        sm = _SHAPE_RE.search(lhs_text)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contract *= dims[int(ci)]
    return 2.0 * out_elems * contract


def _operand_bytes(op: Op, comp: Computation) -> list:
    rest = op.line.split(op.opcode + "(", 1)
    if len(rest) != 2:
        return []
    arg_text = rest[1].split(")")[0]
    return [_shape_list_bytes(comp.defs.get(nm, ""))
            for nm in _OPERAND_RE.findall(arg_text)]


def _op_hbm_bytes(op: Op, comp: Computation) -> float:
    """Fusion-boundary bytes: output + operand buffer sizes.

    In-place / sparse-access ops must NOT be charged their full buffer
    (XLA aliases them; cost_analysis does the same):
      * dynamic-update-slice: read+write of the updated slice only;
      * scatter: updates x2 + indices (target aliased in place);
      * gather / dynamic-slice: output x2 + indices.
    """
    out_b = _shape_list_bytes(op.out_text)
    ops_b = _operand_bytes(op, comp)
    if op.opcode == "dynamic-update-slice":
        upd = ops_b[1] if len(ops_b) > 1 else 0
        return 2 * upd + sum(ops_b[2:])
    if op.opcode == "scatter":
        upd = ops_b[2] if len(ops_b) > 2 else 0
        idx = ops_b[1] if len(ops_b) > 1 else 0
        return 2 * upd + idx
    if op.opcode in ("gather", "dynamic-slice"):
        return 2 * out_b + sum(ops_b[1:])
    return out_b + sum(ops_b)


def _comp_cost(comp_name: str, comps: dict, memo: dict,
               flops_only: bool = False, depth: int = 0) -> Cost:
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    cost = Cost()
    if comp is None:
        return cost
    memo[comp_name] = cost   # provisional (cycles shouldn't occur)
    for op in comp.ops:
        oc = op.opcode
        if oc == "while":
            body_m = _CALL_RE.search(op.line)
            cond_m = _COND_RE.search(op.line)
            trips = 1
            if cond_m and cond_m.group(1) in comps:
                trips = _trip_count(comps[cond_m.group(1)])
            if body_m:
                sub = _comp_cost(body_m.group(1), comps, {},
                                 depth=depth + 1)
                cost += sub.scaled(trips)
        elif oc == "fusion":
            call_m = _CALL_RE.search(op.line)
            fused = comps.get(call_m.group(1)) if call_m else None
            if call_m:
                sub = _comp_cost(call_m.group(1), comps, memo,
                                 flops_only=True)
                cost.flops += sub.flops
                cost.coll_bytes += sub.coll_bytes
                for k, v in sub.coll_breakdown.items():
                    cost.coll_breakdown[k] += v
            # in-place DUS-rooted fusions (scan stacking, cache inserts):
            # charge the updated slices, not the whole aliased buffer.
            dus_updates = 0
            sliced_params = {}
            if fused is not None:
                for fop in fused.ops:
                    if fop.opcode == "dynamic-update-slice":
                        obs = _operand_bytes(fop, fused)
                        if len(obs) > 1:
                            dus_updates += obs[1]
                # scan-body slicing pattern: a fusion operand consumed
                # only through dynamic-slice reads touches slice bytes,
                # not the whole stacked buffer.
                consumers: dict = defaultdict(set)
                slice_out: dict = defaultdict(int)
                for fop in fused.ops:
                    rest = fop.line.split(fop.opcode + "(", 1)
                    if len(rest) != 2:
                        continue
                    for nm in _OPERAND_RE.findall(rest[1].split(")")[0]):
                        consumers[nm].add(fop.opcode)
                        if fop.opcode == "dynamic-slice":
                            slice_out[nm] += _shape_list_bytes(fop.out_text)
                for pname, ocs in consumers.items():
                    if ocs == {"dynamic-slice"}:
                        sliced_params[pname] = slice_out[pname]
            if dus_updates or sliced_params:
                out_b = _shape_list_bytes(op.out_text)
                # map fusion operands -> fused-computation parameter names
                rest = op.line.split("fusion(", 1)
                operand_names = (_OPERAND_RE.findall(
                    rest[1].split(")")[0]) if len(rest) == 2 else [])
                fused_params = {}
                if fused:
                    for o in fused.ops:
                        if o.opcode == "parameter":
                            pm = re.search(r"parameter\((\d+)\)", o.line)
                            if pm:
                                fused_params[int(pm.group(1))] = o.name
                b = 2 * dus_updates if dus_updates else 0
                if not dus_updates:
                    b += out_b
                for i, nm in enumerate(operand_names):
                    ob = _shape_list_bytes(comp.defs.get(nm, ""))
                    pname = fused_params.get(i)
                    if dus_updates and ob == out_b:
                        continue   # aliased in-place buffer
                    if pname in sliced_params:
                        b += sliced_params[pname]
                    else:
                        b += ob
                cost.bytes += b
                tag = "fusion-inplace" if depth < 2 else \
                    "fusion-inplace-innerloop"
                cost.bytes_by_op[tag] += b
            else:
                b = _op_hbm_bytes(op, comp)
                cost.bytes += b
                cost.bytes_by_op[
                    "fusion" if depth < 2 else "fusion-innerloop"] += b
        elif oc in ("call", "conditional", "async-start"):
            call_m = _CALL_RE.search(op.line)
            if call_m:
                cost += _comp_cost(call_m.group(1), comps, {})
        elif oc.startswith(tuple(_COLLECTIVES)):
            base = oc.replace("-start", "").replace("-done", "")
            if oc.endswith("-done"):
                continue
            b = _shape_list_bytes(op.out_text)
            cost.coll_bytes += b
            cost.coll_breakdown[base] += b
            if not flops_only:
                hb = _op_hbm_bytes(op, comp)
                cost.bytes += hb
                cost.bytes_by_op["collective"] += hb
        elif oc in ("dot", "convolution"):
            cost.flops += _dot_flops(op, comp)
            if not flops_only:
                b = _op_hbm_bytes(op, comp)
                cost.bytes += b
                cost.bytes_by_op["dot"] += b
        elif oc in _ARITH_1FLOP:
            cost.flops += _shape_list_elems(op.out_text)
            if not flops_only:
                b = _op_hbm_bytes(op, comp)
                cost.bytes += b
                cost.bytes_by_op[
                    "arith" if depth < 2 else "arith-innerloop"] += b
        elif oc in _ARITH_XFLOP:
            cost.flops += _ARITH_XFLOP[oc] * _shape_list_elems(op.out_text)
            if not flops_only:
                b = _op_hbm_bytes(op, comp)
                cost.bytes += b
                cost.bytes_by_op["arith"] += b
        elif oc in _DATA_MOVE:
            if not flops_only and oc not in ("parameter", "constant",
                                             "get-tuple-element", "tuple",
                                             "bitcast", "after-all"):
                b = _op_hbm_bytes(op, comp)
                cost.bytes += b
                cost.bytes_by_op[oc if oc in (
                    "copy", "transpose", "gather", "scatter", "reduce",
                    "dynamic-update-slice", "dynamic-slice", "convert",
                    "broadcast", "concatenate") else "data-move"] += b
        else:
            if not flops_only:
                b = _op_hbm_bytes(op, comp)
                cost.bytes += b
                cost.bytes_by_op["other"] += b
    memo[comp_name] = cost
    return cost


def analyze(hlo: str) -> Cost:
    """Per-device cost of the entry computation, loops unrolled."""
    comps, entry = parse_computations(hlo)
    if entry is None:
        return Cost()
    # top-level: only cost computations reachable from entry (fusion and
    # while bodies are reached via recursion)
    return _comp_cost(entry, comps, {})
