"""Hand-written "MPI-style" distributed ALS (paper §6.2 comparison).

The paper compares GraphLab to a from-scratch MPI implementation using
synchronous collectives.  The JAX analogue of that programming style is a
bare ``shard_map`` program with explicit ``all_gather``: shard the user
and movie blocks over devices, and each half-iteration all-gathers the
*entire* opposing factor matrix (the classic dense-replication MPI ALS).
No framework, no data graph, no ghosts, no adaptivity — the yardstick for
"does the abstraction cost anything?".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.apps.als import ALSProblem


def _pad_to(x: np.ndarray, n: int) -> np.ndarray:
    pad = n - x.shape[0]
    if pad <= 0:
        return x
    return np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)], 0)


def als_mpi(problem: ALSProblem, n_iters: int, n_devices: int | None = None,
            lam: float = 0.02):
    """Returns (w_users, w_movies) after n_iters; runs on all local devices."""
    devs = jax.devices()
    M = n_devices or len(devs)
    mesh = Mesh(np.array(devs[:M]), ("mpi",))
    d = problem.d
    nU, nV = problem.n_users, problem.n_movies
    nUp = ((nU + M - 1) // M) * M
    nVp = ((nV + M - 1) // M) * M

    w = np.asarray(problem.graph.vertex_data["w"])
    wU = jnp.asarray(_pad_to(w[:nU], nUp))
    wV = jnp.asarray(_pad_to(w[nU:], nVp))

    # per-destination padded rating lists (ELL, like the data graph)
    def ell(pairs_dst, pairs_src, n_dst_pad, n_src):
        deg = np.zeros(n_dst_pad, np.int64)
        np.add.at(deg, pairs_dst, 1)
        D = max(1, int(deg.max()))
        idx = np.zeros((n_dst_pad, D), np.int32)
        rat = np.zeros((n_dst_pad, D), np.float32)
        msk = np.zeros((n_dst_pad, D), bool)
        cur = np.zeros(n_dst_pad, np.int64)
        for e, (t, s) in enumerate(zip(pairs_dst, pairs_src)):
            idx[t, cur[t]] = s
            rat[t, cur[t]] = problem.ratings[e]
            msk[t, cur[t]] = True
            cur[t] += 1
        return jnp.asarray(idx), jnp.asarray(rat), jnp.asarray(msk)

    uidx, urat, umask = ell(problem.pairs[:, 0], problem.pairs[:, 1], nUp, nV)
    vidx, vrat, vmask = ell(problem.pairs[:, 1], problem.pairs[:, 0], nVp, nU)

    def solve_block(w_other_full, idx, rat, msk):
        X = w_other_full[idx] * msk[..., None]
        A = jnp.einsum("bdi,bdj->bij", X, X)
        n_obs = msk.sum(axis=1).astype(X.dtype)
        A = A + (lam * jnp.maximum(n_obs, 1.0))[:, None, None] * jnp.eye(d, dtype=X.dtype)
        b = jnp.einsum("bdi,bd->bi", X, rat * msk)
        return jnp.linalg.solve(A, b[..., None])[..., 0], n_obs

    def step(wU, wV, uidx, urat, umask, vidx, vrat, vmask):
        # update movies given users: all-gather the user factors (MPI style)
        wU_full = jax.lax.all_gather(wU, "mpi", tiled=True)
        wV_new, nV_obs = solve_block(wU_full, vidx, vrat, vmask)
        wV = jnp.where(nV_obs[:, None] > 0, wV_new, wV)
        wV_full = jax.lax.all_gather(wV, "mpi", tiled=True)
        wU_new, nU_obs = solve_block(wV_full, uidx, urat, umask)
        wU = jnp.where(nU_obs[:, None] > 0, wU_new, wU)
        return wU, wV

    spec = P("mpi")
    step_sm = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(spec,) * 8, out_specs=(spec, spec), check_rep=False))

    for _ in range(n_iters):
        wU, wV = step_sm(wU, wV, uidx, urat, umask, vidx, vrat, vmask)
    comm_bytes_per_iter = (nUp + nVp) * d * 4 * (M - 1)  # all-gather volume
    return (np.asarray(wU[:nU]), np.asarray(wV[:nV]),
            {"bytes_per_iter": comm_bytes_per_iter})
