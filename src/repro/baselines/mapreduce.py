"""MapReduce-style (Hadoop-equivalent) implementations (paper §6.2).

The paper attributes much of its 20–60x win to a *mechanism* gap, not
just Java-vs-C++: "the Map only serves to emit the vertex probability
table for every edge in the graph, which corresponds to over 100
gigabytes of HDFS writes".  We reproduce that mechanism on identical
hardware: each iteration is Map (every edge materializes a full copy of
its endpoint's data) -> Shuffle (group by destination) -> Reduce
(recompute the vertex).  The computation is algorithmically identical to
the GraphLab update; only the data movement differs, and
``bytes_shuffled`` accounts for it so benchmarks can compare against the
chromatic engine's ghost traffic.

These baselines are bulk-synchronous and non-adaptive (no task set), like
their Hadoop counterparts.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.als import ALSProblem
from repro.apps.coem import CoEMProblem


@dataclasses.dataclass
class MRStats:
    bytes_shuffled_per_iter: int
    messages_per_iter: int


# ----------------------------------------------------------------------
# ALS
# ----------------------------------------------------------------------

def _als_solve_side(w_src, w_dst_old, pairs_dst, pairs_src, ratings, n_dst,
                    d, lam):
    """One MapReduce job: every rating edge emits (dst, src_factor, r);
    reduce solves the normal equations per destination vertex."""
    # Map: materialize messages [Ne, d+1]   <-- the HDFS-write analogue
    msg_w = w_src[pairs_src]                   # [Ne, d]
    msg_r = ratings                            # [Ne]
    # Shuffle+Reduce: segment-sum the outer products per destination
    outer = msg_w[:, :, None] * msg_w[:, None, :]        # [Ne, d, d]
    A = jax.ops.segment_sum(outer, pairs_dst, n_dst)     # [n_dst, d, d]
    b = jax.ops.segment_sum(msg_w * msg_r[:, None], pairs_dst, n_dst)
    cnt = jax.ops.segment_sum(jnp.ones_like(msg_r), pairs_dst, n_dst)
    A = A + (lam * jnp.maximum(cnt, 1.0))[:, None, None] * jnp.eye(d, dtype=w_src.dtype)
    w_new = jnp.linalg.solve(A, b[..., None])[..., 0]
    return jnp.where(cnt[:, None] > 0, w_new, w_dst_old)


@partial(jax.jit, static_argnames=("n_users", "n_movies", "d"))
def als_mapreduce_iteration(w_users, w_movies, pairs, ratings,
                            n_users: int, n_movies: int, d: int,
                            lam: float = 0.02):
    """Two MR jobs (movies given users, then users given movies) — the
    standard Hadoop ALS iteration (Mahout-style)."""
    w_movies = _als_solve_side(w_users, w_movies, pairs[:, 1], pairs[:, 0],
                               ratings, n_movies, d, lam)
    w_users = _als_solve_side(w_movies, w_users, pairs[:, 0], pairs[:, 1],
                              ratings, n_users, d, lam)
    return w_users, w_movies


def als_mapreduce(problem: ALSProblem, n_iters: int, lam: float = 0.02):
    d = problem.d
    w = np.asarray(problem.graph.vertex_data["w"])
    w_users = jnp.asarray(w[: problem.n_users])
    w_movies = jnp.asarray(w[problem.n_users:])
    pairs = jnp.asarray(problem.pairs)
    ratings = jnp.asarray(problem.ratings)
    for _ in range(n_iters):
        w_users, w_movies = als_mapreduce_iteration(
            w_users, w_movies, pairs, ratings,
            problem.n_users, problem.n_movies, d, lam)
    ne = len(problem.pairs)
    stats = MRStats(
        # both jobs emit one (factor + rating) message per edge
        bytes_shuffled_per_iter=2 * ne * (d + 1) * 4,
        messages_per_iter=2 * ne,
    )
    return {"w_users": w_users, "w_movies": w_movies}, stats


# ----------------------------------------------------------------------
# CoEM / NER
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_phrases", "n_contexts"))
def coem_mapreduce_iteration(p_phr, p_ctx, pairs, counts, seeds_phr,
                             p_phr0, n_phrases: int, n_contexts: int):
    def side(src_p, dst_n, src_idx, dst_idx):
        msg = src_p[src_idx] * counts[:, None]           # materialized
        num = jax.ops.segment_sum(msg, dst_idx, dst_n)
        den = jax.ops.segment_sum(counts, dst_idx, dst_n)
        p = num / jnp.maximum(den, 1e-9)[:, None]
        return p / jnp.maximum(p.sum(-1, keepdims=True), 1e-9)
    p_ctx = side(p_phr, n_contexts, pairs[:, 0], pairs[:, 1])
    p_phr_new = side(p_ctx, n_phrases, pairs[:, 1], pairs[:, 0])
    p_phr = jnp.where(seeds_phr[:, None] > 0, p_phr0, p_phr_new)
    return p_phr, p_ctx


def coem_mapreduce(problem: CoEMProblem, n_iters: int):
    nP, nC = problem.n_phrases, problem.n_contexts
    p0 = np.asarray(problem.graph.vertex_data["p"])
    seeds = jnp.asarray(
        np.asarray(problem.graph.vertex_data["is_seed"])[:nP])
    p_phr, p_ctx = jnp.asarray(p0[:nP]), jnp.asarray(p0[nP:])
    p_phr0 = p_phr
    edges = problem.graph.edges_np
    pairs = jnp.asarray(
        np.stack([edges[:, 0], edges[:, 1] - nP], axis=1))
    counts = problem.graph.edge_data["count"][:-1]
    for _ in range(n_iters):
        p_phr, p_ctx = coem_mapreduce_iteration(
            p_phr, p_ctx, pairs, counts, seeds, p_phr0, nP, nC)
    ne = len(edges)
    T = p0.shape[1]
    stats = MRStats(
        bytes_shuffled_per_iter=2 * ne * T * 4,  # probability table per edge
        messages_per_iter=2 * ne,
    )
    return {"p": jnp.concatenate([p_phr, p_ctx], axis=0)}, stats
