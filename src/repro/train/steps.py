"""Training / serving step functions — the units the launcher lowers."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.optim import adamw
from repro.serve import engine as serve_engine


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, mets = model_lib.forward(p, cfg, batch, remat=True)
            return loss, mets
        (loss, mets), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, opt_mets = adamw.update(
            opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **mets, **opt_mets}
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return model_lib.prefill(params, cfg, batch)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, token, state):
        return serve_engine.decode_step(params, cfg, token, state)
    return serve_step
