"""Trainer: config-driven loop with checkpointing + eval (CPU-runnable)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import pipeline
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train import checkpoint as ckpt_lib
from repro.train.steps import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 128
    log_every: int = 10
    ckpt_every: int = 0            # 0 = only final
    ckpt_path: str = ""
    seed: int = 0
    opt: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)


def train(cfg: ModelConfig, tcfg: TrainerConfig):
    key = jax.random.PRNGKey(tcfg.seed)
    params = model_lib.init_params(key, cfg)
    opt_state = adamw.init(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg.opt))
    history = []
    t0 = time.time()
    for step in range(tcfg.steps):
        batch = pipeline.make_batch(cfg, tcfg.batch, tcfg.seq_len,
                                    seed=tcfg.seed * 100003 + step)
        params, opt_state, mets = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            loss = float(mets["loss"])
            history.append((step, loss))
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"lr {float(mets['lr']):.2e} "
                  f"gnorm {float(mets['grad_norm']):.3f} "
                  f"({time.time() - t0:.1f}s)")
        if tcfg.ckpt_every and step and step % tcfg.ckpt_every == 0 \
                and tcfg.ckpt_path:
            ckpt_lib.save(tcfg.ckpt_path, params, step=step)
    if tcfg.ckpt_path:
        ckpt_lib.save(tcfg.ckpt_path, params, step=tcfg.steps)
    return params, opt_state, history
