"""Checkpointing: flat-path npz snapshots of arbitrary pytrees.

Also provides the paper's §8 sketch — "a globally consistent snapshot
mechanism can be easily performed using the Sync operation": the graph
engines are superstep-synchronous, so snapshotting EngineState between
supersteps IS the consistent snapshot; ``snapshot_engine_state`` does
exactly that.

Writes are atomic (tmp file + ``os.replace``): a kill mid-save leaves
either the previous checkpoint or none, never a truncated archive.
``restore`` raises :class:`CheckpointError` — naming the missing key,
the mismatched shape, or the corrupt archive — instead of leaking
``KeyError``/``zipfile`` tracebacks.  Sharded multi-device snapshots
live in ``repro.ft.snapshot``, built on the same conventions.
"""
from __future__ import annotations

import os
import zipfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "::"

# Bump when the set of keys snapshot_engine_state writes (or their
# meaning) changes; restore_engine_state refuses other versions.
ENGINE_SNAPSHOT_SCHEMA = 2


class CheckpointError(Exception):
    """A checkpoint could not be read back: missing file, corrupt
    archive, missing key, shape mismatch, or schema mismatch."""


def _flatten(tree: PyTree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            arr = arr.astype(np.float32)   # npz-safe; restore() recasts
        flat[key] = arr
    return flat


def _atomic_savez(path: str, flat: dict) -> None:
    """np.savez to ``path`` such that ``path`` is never truncated: the
    archive is built under a tmp name and published with os.replace."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save(path: str, tree: PyTree, step: int | None = None) -> None:
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    _atomic_savez(path, flat)


def _load_npz(path: str):
    path = path if path.endswith(".npz") else path + ".npz"
    if not os.path.exists(path):
        raise CheckpointError(f"checkpoint not found: {path}")
    try:
        data = np.load(path)
        data.files  # forces the zip directory read
        return data
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise CheckpointError(
            f"corrupt checkpoint archive {path}: {e}") from e


def restore(path: str, like: PyTree) -> tuple[PyTree, int | None]:
    """Restore into the structure of ``like`` (dtypes preserved)."""
    data = _load_npz(path)
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_elems, leaf in leaves_like:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path_elems)
        if key not in data:
            raise CheckpointError(
                f"checkpoint {path} is missing key {key!r}; "
                f"it has {sorted(data.files)[:8]}...")
        raw = data[key]
        want = np.shape(leaf)
        if tuple(raw.shape) != tuple(want):
            raise CheckpointError(
                f"checkpoint {path} key {key!r} has shape "
                f"{tuple(raw.shape)}, expected {tuple(want)}")
        arr = jnp.asarray(raw).astype(leaf.dtype)
        out.append(arr)
    step = int(data["__step__"]) if "__step__" in data else None
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out), step


def snapshot_engine_state(path: str, state) -> None:
    """Consistent snapshot of a graph-engine EngineState (between
    supersteps — the paper's §8 Sync-based snapshot).

    Saves everything a bit-identical resume needs: data, the task set,
    priorities, sync results, and the update counter; the superstep goes
    into ``__step__``.  The snapshot is stamped with a schema version
    and the EngineState field set so a restore against a different
    engine-state layout fails loudly.  ``restore_engine_state`` is the
    inverse."""
    from repro.core.exec import engine_state_field_names
    flat = _flatten({
        "vertex_data": state.vertex_data,
        "edge_data": state.edge_data,
        "active": state.active,
        "priority": state.priority,
        "globals": state.globals,
        "n_updates": state.n_updates,
    })
    flat["__step__"] = np.asarray(int(state.superstep))
    flat["__schema__"] = np.asarray(ENGINE_SNAPSHOT_SCHEMA)
    flat["__fields__"] = np.asarray(",".join(engine_state_field_names()))
    _atomic_savez(path, flat)


def restore_engine_state(path: str, like):
    """Restore a ``snapshot_engine_state`` snapshot into an EngineState
    shaped like ``like`` (e.g. ``engine.init_state()``).

    Superstep boundaries are globally consistent cuts, so
    ``engine.resume(restore_engine_state(path, engine.init_state()))``
    continues bit-identically to a run that never stopped
    (``tests/test_optim_ckpt.py`` asserts this)."""
    import dataclasses

    from repro.core.exec import engine_state_field_names
    data = _load_npz(path)
    if "__schema__" not in data:
        raise CheckpointError(
            f"{path} is not a versioned engine snapshot (no __schema__ "
            f"field); re-save it with snapshot_engine_state")
    schema = int(data["__schema__"])
    if schema != ENGINE_SNAPSHOT_SCHEMA:
        raise CheckpointError(
            f"{path} has engine-snapshot schema {schema}, this build "
            f"reads {ENGINE_SNAPSHOT_SCHEMA}")
    saved_fields = str(data["__fields__"]) if "__fields__" in data else ""
    want_fields = ",".join(engine_state_field_names())
    if saved_fields != want_fields:
        missing = set(want_fields.split(",")) - set(saved_fields.split(","))
        extra = set(saved_fields.split(",")) - set(want_fields.split(","))
        raise CheckpointError(
            f"{path} EngineState field set mismatch: snapshot has "
            f"[{saved_fields}], this build has [{want_fields}]"
            + (f"; missing {sorted(missing)}" if missing else "")
            + (f"; unknown {sorted(extra)}" if extra else ""))
    tree = {
        "vertex_data": like.vertex_data,
        "edge_data": like.edge_data,
        "active": like.active,
        "priority": like.priority,
        "globals": like.globals,
        "n_updates": like.n_updates,
    }
    restored, step = restore(path, tree)
    return dataclasses.replace(
        like,
        vertex_data=restored["vertex_data"],
        edge_data=restored["edge_data"],
        active=restored["active"],
        priority=restored["priority"],
        globals=restored["globals"],
        n_updates=restored["n_updates"],
        superstep=jnp.int32(step if step is not None else 0),
    )
