"""Checkpointing: flat-path npz snapshots of arbitrary pytrees.

Also provides the paper's §8 sketch — "a globally consistent snapshot
mechanism can be easily performed using the Sync operation": the graph
engines are superstep-synchronous, so snapshotting EngineState between
supersteps IS the consistent snapshot; ``snapshot_engine_state`` does
exactly that.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "::"


def _flatten(tree: PyTree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            arr = arr.astype(np.float32)   # npz-safe; restore() recasts
        flat[key] = arr
    return flat


def save(path: str, tree: PyTree, step: int | None = None) -> None:
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)


def restore(path: str, like: PyTree) -> tuple[PyTree, int | None]:
    """Restore into the structure of ``like`` (dtypes preserved)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_elems, leaf in leaves_like:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path_elems)
        arr = jnp.asarray(data[key]).astype(leaf.dtype)
        out.append(arr)
    step = int(data["__step__"]) if "__step__" in data else None
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out), step


def snapshot_engine_state(path: str, state) -> None:
    """Consistent snapshot of a graph-engine EngineState (between
    supersteps — the paper's §8 Sync-based snapshot).

    Saves everything a bit-identical resume needs: data, the task set,
    priorities, sync results, and the update counter; the superstep goes
    into ``__step__``.  ``restore_engine_state`` is the inverse."""
    save(path, {
        "vertex_data": state.vertex_data,
        "edge_data": state.edge_data,
        "active": state.active,
        "priority": state.priority,
        "globals": state.globals,
        "n_updates": state.n_updates,
    }, step=int(state.superstep))


def restore_engine_state(path: str, like):
    """Restore a ``snapshot_engine_state`` snapshot into an EngineState
    shaped like ``like`` (e.g. ``engine.init_state()``).

    Superstep boundaries are globally consistent cuts, so
    ``engine.resume(restore_engine_state(path, engine.init_state()))``
    continues bit-identically to a run that never stopped
    (``tests/test_optim_ckpt.py`` asserts this)."""
    import dataclasses
    tree = {
        "vertex_data": like.vertex_data,
        "edge_data": like.edge_data,
        "active": like.active,
        "priority": like.priority,
        "globals": like.globals,
        "n_updates": like.n_updates,
    }
    restored, step = restore(path, tree)
    return dataclasses.replace(
        like,
        vertex_data=restored["vertex_data"],
        edge_data=restored["edge_data"],
        active=restored["active"],
        priority=restored["priority"],
        globals=restored["globals"],
        n_updates=restored["n_updates"],
        superstep=jnp.int32(step if step is not None else 0),
    )
