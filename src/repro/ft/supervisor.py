"""Supervised execution: retry with exponential backoff + restore.

The supervisor is deliberately dumb (Distributed GraphLab §5 restarts
the whole run from the last snapshot; so do we): it calls an *attempt
function* until one attempt returns, retrying on the restartable
exception set with exponentially-backed-off sleeps, and keeps a
structured :class:`RestartRecord` log that ends up on
``RunResult.restarts``.  Where to restore from is the attempt
function's business (``repro.ft.runner`` restores from the latest
valid snapshot) — the supervisor only decides *whether to try again*.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

from repro.ft.faults import InjectedFault


@dataclasses.dataclass
class RestartRecord:
    """One supervised restart: which attempt died, of what, how long we
    backed off, and (filled by the attempt function) which superstep
    the next attempt restored to — ``None`` means from scratch."""
    attempt: int
    error_type: str
    error: str
    backoff_s: float
    restored_superstep: int | None = None


class SupervisorGaveUp(Exception):
    """More failures than ``max_restarts``; the last error is chained."""


def supervised(attempt_fn: Callable, *, max_restarts: int = 3,
               backoff_base_s: float = 0.01, backoff_factor: float = 2.0,
               backoff_max_s: float = 1.0,
               restartable: Sequence[type] = (InjectedFault,),
               sleep: Callable[[float], None] = time.sleep):
    """Run ``attempt_fn(attempt_no, restarts) -> result`` under
    restart-on-failure.  Returns ``(result, restarts)``.

    ``restarts`` is the shared restart log; the record for the failure
    that caused the current attempt is ``restarts[-1]``, which the
    attempt function should annotate with ``restored_superstep`` once
    it knows where it resumed from.
    """
    restartable = tuple(restartable)
    restarts: list[RestartRecord] = []
    attempt = 0
    while True:
        try:
            return attempt_fn(attempt, restarts), restarts
        except restartable as e:
            if attempt >= max_restarts:
                raise SupervisorGaveUp(
                    f"giving up after {attempt} restart(s); last error: "
                    f"{type(e).__name__}: {e}") from e
            backoff = min(backoff_base_s * backoff_factor ** attempt,
                          backoff_max_s)
            restarts.append(RestartRecord(
                attempt=attempt, error_type=type(e).__name__,
                error=str(e), backoff_s=backoff))
            sleep(backoff)
            attempt += 1
