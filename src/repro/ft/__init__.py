"""Fault tolerance for distributed runs (paper §8; Distributed
GraphLab §5): sharded consistent snapshots at superstep boundaries,
deterministic fault injection, and a supervised restart loop.

The three layers (DESIGN.md §12):

* :mod:`repro.ft.snapshot` — per-shard checkpoints of a distributed
  carry, written atomically with a digest-carrying manifest.
* :mod:`repro.ft.faults` — a seeded :class:`FaultPlan` of injected
  kills / transient errors / stragglers / checkpoint-write failures,
  zero-cost when absent.
* :mod:`repro.ft.supervisor` — retry/backoff around an attempt
  function, restoring from the latest valid snapshot.
* :mod:`repro.ft.runner` — the checkpointed drivers ``api.run(...,
  checkpoint_every=, resume_from=, faults=)`` routes to.
* :mod:`repro.ft.sync_snapshot` — the paper-fidelity §8 variant where
  the snapshot itself runs as an update function through the engine.
"""
from repro.ft.faults import (CheckpointWriteFault, FaultEvent, FaultPlan,
                             InjectedFault, InjectedKill, TransientFault)
from repro.ft.snapshot import (SnapshotError, latest_valid_snapshot,
                               load_carry, read_manifest, validate_snapshot,
                               write_snapshot)
from repro.ft.supervisor import RestartRecord, SupervisorGaveUp, supervised

__all__ = [
    "CheckpointWriteFault", "FaultEvent", "FaultPlan", "InjectedFault",
    "InjectedKill", "TransientFault", "SnapshotError",
    "latest_valid_snapshot", "load_carry", "read_manifest",
    "validate_snapshot", "write_snapshot", "RestartRecord",
    "SupervisorGaveUp", "supervised",
]
