"""The paper-fidelity snapshot: a snapshot *as a GraphLab program*.

Paper §8: "a globally consistent snapshot mechanism can be easily
performed using the Sync operation" — and Distributed GraphLab §5
spells it out: the snapshot is itself an update function scheduled
over every vertex.  ``repro.ft.snapshot`` is the fast engineering
path (copy the carry at a superstep boundary); this module is the
paper's path: each vertex's update copies its own data into shadow
``snap__<field>`` columns under VERTEX consistency, one superstep over
the full task set commits the cut, and the shadow columns *are* the
snapshot.  Both express the same consistency argument — a superstep
boundary is a global cut — and ``tests/test_ft.py`` asserts they agree
bitwise.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

from repro.core.update import Consistency, UpdateFn, UpdateResult


def snapshot_update(fields: Sequence[str]) -> UpdateFn:
    """The snapshot program: copy own data into shadow columns.

    VERTEX consistency — the snapshot reads and writes only the central
    vertex, so any engine may run every vertex in one conflict-free
    sweep (single color suffices; finer colorings are just as safe).
    No rescheduling: the task set drains after one pass."""
    fields = tuple(fields)

    def fn(scope) -> UpdateResult:
        v = dict(scope.v_data)
        for k in fields:
            v[f"snap__{k}"] = scope.v_data[k]
        return UpdateResult(v_data=v)

    return UpdateFn(fn, consistency=Consistency.VERTEX, name="snapshot")


def snapshot_as_program(graph, *, fields: Sequence[str] | None = None,
                        scheduler: str = "chromatic", n_shards: int = 1,
                        partition=None, **options) -> dict:
    """Take a consistent snapshot of ``graph.vertex_data`` by running
    the §8 snapshot program through the named engine; returns
    ``{field: snapshotted array}``.

    The graph is widened with zeroed ``snap__*`` shadow columns, the
    snapshot update runs for exactly one superstep over all vertices,
    and the shadows are stripped back out."""
    from repro import api

    fields = tuple(fields if fields is not None
                   else graph.vertex_data.keys())
    shadow = {f"snap__{k}": jnp.zeros_like(graph.vertex_data[k])
              for k in fields}
    widened = dataclasses.replace(
        graph, vertex_data={**graph.vertex_data, **shadow})
    res = api.run(widened, snapshot_update(fields), scheduler=scheduler,
                  n_shards=n_shards, partition=partition,
                  num_supersteps=1, **options)
    return {k: res.vertex_data[f"snap__{k}"] for k in fields}
