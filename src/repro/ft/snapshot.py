"""Sharded consistent snapshots of a distributed engine carry.

The distributed engines are superstep-synchronous: between supersteps
every shard has applied the same prefix of work and the ghost exchange
for that prefix has completed, so a cut at a superstep boundary is a
globally consistent snapshot (paper §8; DESIGN.md §12).  A snapshot is
one directory per boundary::

    <ckpt_dir>/step_00000012/
        shard_00000.npz ... shard_{M-1:05d}.npz   # per-shard carry rows
        host.npz                                  # globals, superstep,
                                                  # partition assignment
        MANIFEST.json                             # written LAST

The manifest carries a schema version, shard count, scheduler name,
partition fingerprint, per-key dtypes/shapes, and a sha256 digest of
every file.  It is written last inside a hidden tmp directory that is
published with a single ``os.replace`` — so a torn write (kill or an
injected ``checkpoint_fail``) leaves either the previous snapshot or an
unpublished tmp dir, never a half-snapshot that ``step_*`` scans can
see.  Every failure mode at load is a :class:`SnapshotError` naming
what was wrong; ``latest_valid_snapshot`` skips damaged directories.

What must be saved is exactly the engine carry: owned vertex/edge rows,
the task set and priorities, sync globals — and, for the locking
engine, the ghost *version counters* (``version`` / ``eversion`` /
``sent_ver`` / ``esent_ver``).  Dropping the counters would desync the
delta-shipping protocol after restore: owners would skip rows ghosts
never received (wrong data) or re-ship everything (wrong traffic
stats), either way breaking bitwise resume.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from glob import glob
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

SCHEMA = 1
_SEP = "::"
# carry keys replicated across shards (everything else is [M, ...])
_REPLICATED = ("globals", "superstep")
_RECAST = ("bfloat16", "float8_e4m3fn", "float8_e5m2")


class SnapshotError(Exception):
    """A sharded snapshot could not be written or read back: torn
    directory, digest mismatch, schema/partition/shard-count mismatch,
    or missing/mis-shaped keys."""


def _flat_keys(carry: Any) -> list[tuple[str, Any]]:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(carry)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((key, leaf))
    return out


def _is_replicated(key: str) -> bool:
    return key.split(_SEP, 1)[0] in _REPLICATED


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _write_npz(path: str, arrays: dict) -> None:
    with open(path, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())


def write_snapshot(ckpt_dir: str, carry: dict, *, scheduler: str,
                   partition: str, assignment: np.ndarray,
                   faults=None) -> str:
    """Write one snapshot of ``carry`` under ``ckpt_dir``; returns the
    published ``step_*`` directory path.

    ``partition`` is ``ShardPlan.partition_fingerprint``;
    ``assignment`` the ``[Nv]`` shard assignment (saved so a resume can
    rebuild the identical plan).  ``faults`` (a ``FaultPlan``) gets a
    ``checkpoint_write`` firing opportunity before every shard file —
    an injected failure leaves the tmp dir torn and the previous
    snapshot untouched.
    """
    flat, fields = {}, {}
    for key, leaf in _flat_keys(carry):
        arr = np.asarray(leaf)
        fields[key] = {"dtype": arr.dtype.name, "shape": list(arr.shape)}
        if arr.dtype.name in _RECAST:
            arr = arr.astype(np.float32)   # npz-safe; load_carry recasts
        flat[key] = arr
    step = int(flat["superstep"])
    n_shards = int(flat["n_updates"].shape[0])

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)

    digests = {}
    for s in range(n_shards):
        if faults is not None:
            faults.fire("checkpoint_write", superstep=step, shard=s)
        name = f"shard_{s:05d}.npz"
        _write_npz(os.path.join(tmp, name),
                   {k: v[s] for k, v in flat.items()
                    if not _is_replicated(k)})
        digests[name] = _sha256(os.path.join(tmp, name))
    host = {k: v for k, v in flat.items() if _is_replicated(k)}
    host["__assignment__"] = np.asarray(assignment, dtype=np.int64)
    _write_npz(os.path.join(tmp, "host.npz"), host)
    digests["host.npz"] = _sha256(os.path.join(tmp, "host.npz"))

    manifest = {"schema": SCHEMA, "superstep": step, "n_shards": n_shards,
                "scheduler": scheduler, "partition": partition,
                "fields": fields, "files": digests}
    mpath = os.path.join(tmp, "MANIFEST.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())

    shutil.rmtree(final, ignore_errors=True)
    os.replace(tmp, final)
    return final


def read_manifest(path: str) -> dict:
    mpath = os.path.join(path, "MANIFEST.json")
    if not os.path.exists(mpath):
        raise SnapshotError(f"{path}: no MANIFEST.json (torn or not a "
                            "snapshot directory)")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise SnapshotError(f"{path}: unreadable manifest: {e}") from e
    if manifest.get("schema") != SCHEMA:
        raise SnapshotError(
            f"{path}: snapshot schema {manifest.get('schema')!r}, this "
            f"build reads {SCHEMA}")
    return manifest


def validate_snapshot(path: str, *, expect_partition: str | None = None,
                      expect_scheduler: str | None = None,
                      expect_n_shards: int | None = None) -> dict:
    """Full integrity + identity check; returns the manifest.

    Digest-checks every file named by the manifest, then checks the
    snapshot identity against the expectations — a snapshot taken on a
    different partition (local row spaces would silently misalign),
    scheduler (different carry layout), or shard count is refused here,
    not discovered as wrong numbers after resume.
    """
    manifest = read_manifest(path)
    for name, digest in manifest["files"].items():
        fpath = os.path.join(path, name)
        if not os.path.exists(fpath):
            raise SnapshotError(f"{path}: missing file {name}")
        actual = _sha256(fpath)
        if actual != digest:
            raise SnapshotError(
                f"{path}: digest mismatch for {name} (manifest "
                f"{digest[:12]}…, file {actual[:12]}… — torn or "
                "corrupted write)")
    if (expect_partition is not None
            and manifest["partition"] != expect_partition):
        raise SnapshotError(
            f"{path}: partition fingerprint {manifest['partition']} "
            f"does not match this run's plan ({expect_partition}); "
            "rebuild the plan from the snapshot's stored assignment")
    if (expect_scheduler is not None
            and manifest["scheduler"] != expect_scheduler):
        raise SnapshotError(
            f"{path}: snapshot was taken by scheduler "
            f"{manifest['scheduler']!r}, this run is "
            f"{expect_scheduler!r}")
    if (expect_n_shards is not None
            and manifest["n_shards"] != expect_n_shards):
        raise SnapshotError(
            f"{path}: snapshot has {manifest['n_shards']} shards, this "
            f"run has {expect_n_shards}")
    return manifest


def read_assignment(path: str) -> tuple[np.ndarray, dict]:
    """The stored ``[Nv]`` shard assignment + manifest — what
    ``api.run(resume_from=...)`` needs to rebuild the ShardPlan."""
    manifest = validate_snapshot(path)
    host = np.load(os.path.join(path, "host.npz"))
    if "__assignment__" not in host:
        raise SnapshotError(f"{path}: host.npz has no __assignment__")
    return host["__assignment__"], manifest


def load_carry(path: str, like_carry: dict, *,
               expect_partition: str | None = None,
               expect_scheduler: str | None = None) -> tuple[dict, int]:
    """Validate + load a snapshot into the structure/dtypes of
    ``like_carry`` (e.g. ``engine.init_carry()``); returns
    ``(carry, superstep)``.  Original dtypes are restored — bfloat16 /
    float8 leaves were stored as float32 and are recast here."""
    leaves = _flat_keys(like_carry)
    manifest = validate_snapshot(
        path, expect_partition=expect_partition,
        expect_scheduler=expect_scheduler,
        expect_n_shards=int(np.asarray(like_carry["n_updates"]).shape[0]))
    n_shards = manifest["n_shards"]
    shards = [np.load(os.path.join(path, f"shard_{s:05d}.npz"))
              for s in range(n_shards)]
    host = np.load(os.path.join(path, "host.npz"))
    out = []
    for key, leaf in leaves:
        if key not in manifest["fields"]:
            raise SnapshotError(
                f"{path}: snapshot has no key {key!r}; it has "
                f"{sorted(manifest['fields'])[:8]}… (engine carry "
                "layout changed?)")
        if _is_replicated(key):
            arr = host[key]
        else:
            arr = np.stack([sh[key] for sh in shards])
        want = tuple(np.shape(leaf))
        if tuple(arr.shape) != want:
            raise SnapshotError(
                f"{path}: key {key!r} has shape {tuple(arr.shape)}, "
                f"this plan expects {want}")
        out.append(jnp.asarray(arr).astype(leaf.dtype))
    carry = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_carry), out)
    return carry, manifest["superstep"]


def latest_valid_snapshot(ckpt_dir: str, *,
                          expect_partition: str | None = None,
                          expect_scheduler: str | None = None,
                          expect_n_shards: int | None = None) -> str | None:
    """Newest ``step_*`` directory under ``ckpt_dir`` that passes
    ``validate_snapshot``; damaged/mismatched ones are skipped (this is
    what makes an injected checkpoint-write failure recoverable: the
    torn attempt never published, the previous snapshot still wins)."""
    for path in sorted(glob(os.path.join(ckpt_dir, "step_*")),
                       reverse=True):
        try:
            validate_snapshot(path, expect_partition=expect_partition,
                              expect_scheduler=expect_scheduler,
                              expect_n_shards=expect_n_shards)
            return path
        except SnapshotError:
            continue
    return None
