"""Checkpointed, supervised run drivers — what ``api.run(...,
checkpoint_every= / resume_from= / faults= / max_restarts=)`` routes to.

Both drivers share one shape: an *attempt function* (restore from the
newest valid snapshot, else start fresh) wrapped in
``repro.ft.supervisor.supervised``.  The distributed driver executes
the engine in **chunks** of the same compiled while-loop program the
fused run uses, splitting exactly at checkpoint multiples and at the
fault plan's next trigger; because each chunk continues from the
previous chunk's carry and the traced superstep body is identical,
chunked == fused == resumed, bitwise (``tests/test_ft.py``).
"""
from __future__ import annotations

import os
from glob import glob
from typing import Any, Callable

import numpy as np

from repro.ft import snapshot as snap
from repro.ft.supervisor import supervised


def _chunk_target(step: int, limit: int, checkpoint_every: int | None,
                  faults) -> int:
    """Where the next chunk must stop: the run limit, capped to the
    next checkpoint multiple and the next fault trigger."""
    target = limit
    if checkpoint_every:
        target = min(target, (step // checkpoint_every + 1)
                     * checkpoint_every)
    if faults is not None:
        nt = faults.next_trigger(step)
        if nt is not None:
            target = min(target, nt)
    return target


# ----------------------------------------------------------------------
# Distributed runs: chunked shard_map program over the engine carry
# ----------------------------------------------------------------------

def run_distributed(engine, *, scheduler: str, active=None,
                    num_supersteps: int | None = None,
                    checkpoint_every: int | None = None,
                    checkpoint_dir: str | None = None,
                    resume_from: str | None = None,
                    faults=None, max_restarts: int = 3,
                    backoff_base_s: float = 0.01,
                    sleep: Callable[[float], None] | None = None
                    ) -> tuple[dict, list]:
    """Drive a distributed engine to completion under checkpointing,
    fault injection, and supervised restart.  Returns
    ``(engine.finalize(carry) result, restart log)``.

    ``num_supersteps`` is a *total* superstep budget (a resumed run
    does not restart the count); without it the run drains the task
    set or hits ``engine.max_supersteps``, exactly like
    ``engine.run()``.
    """
    plan = engine.plan
    limit = (num_supersteps if num_supersteps is not None
             else engine.max_supersteps)
    ignore_active = num_supersteps is not None
    expect = dict(expect_partition=plan.partition_fingerprint,
                  expect_scheduler=scheduler)
    if faults is not None:
        engine.fault_hook = faults.fire

    def attempt(attempt_no: int, restarts: list):
        carry = None
        if attempt_no == 0 and resume_from is not None:
            carry, _ = snap.load_carry(resume_from, engine.init_carry(active),
                                       **expect)
        elif attempt_no > 0 and checkpoint_dir is not None:
            latest = snap.latest_valid_snapshot(
                checkpoint_dir, expect_n_shards=plan.M, **expect)
            if latest is not None:
                carry, step = snap.load_carry(
                    latest, engine.init_carry(active), **expect)
                restarts[-1].restored_superstep = step
        if carry is None:
            carry = engine.init_carry(active)

        while True:
            step = int(carry["superstep"])
            # the boundary hook also fires inside step_chunk; firing
            # here first covers the break-before-stepping paths
            if faults is not None:
                faults.fire("superstep", superstep=step)
            if step >= limit:
                break
            if not ignore_active and not engine.carry_active_any(carry):
                break
            target = _chunk_target(step, limit, checkpoint_every, faults)
            carry = engine.step_chunk(carry, target, ignore_active)
            step = int(carry["superstep"])
            if (checkpoint_every and checkpoint_dir
                    and step % checkpoint_every == 0):
                snap.write_snapshot(
                    checkpoint_dir, carry, scheduler=scheduler,
                    partition=plan.partition_fingerprint,
                    assignment=plan.assignment, faults=faults)
        return carry

    kwargs = {} if sleep is None else {"sleep": sleep}
    carry, restarts = supervised(attempt, max_restarts=max_restarts,
                                 backoff_base_s=backoff_base_s, **kwargs)
    return engine.finalize(carry), restarts


# ----------------------------------------------------------------------
# Single-device runs: per-superstep stepping over EngineState
# ----------------------------------------------------------------------

def _latest_valid_state(ckpt_dir: str, like) -> tuple[Any, str | None]:
    """Newest restorable ``state_step_*.npz`` under ``ckpt_dir``
    (corrupt/mismatched ones are skipped, mirroring
    ``latest_valid_snapshot``)."""
    from repro.train.checkpoint import CheckpointError, restore_engine_state
    for f in sorted(glob(os.path.join(ckpt_dir, "state_step_*.npz")),
                    reverse=True):
        try:
            return restore_engine_state(f, like), f
        except CheckpointError:
            continue
    return None, None


def run_single(engine, *, active=None, priority=None,
               until: Callable[[dict], bool] | None = None,
               num_supersteps: int | None = None,
               checkpoint_every: int | None = None,
               checkpoint_dir: str | None = None,
               resume_from: str | None = None,
               faults=None, max_restarts: int = 3,
               backoff_base_s: float = 0.01,
               sleep: Callable[[float], None] | None = None):
    """Single-device counterpart of :func:`run_distributed`, stepping
    ``engine._step_jit`` superstep by superstep (the same loop the
    facade's ``until=``/``trace=`` path runs) with atomic
    ``snapshot_engine_state`` checkpoints.  Returns
    ``(EngineState, restart log)``."""
    from repro.train.checkpoint import snapshot_engine_state

    def attempt(attempt_no: int, restarts: list):
        state = None
        if attempt_no == 0 and resume_from is not None:
            from repro.train.checkpoint import restore_engine_state
            state = restore_engine_state(
                resume_from, engine.init_state(active, priority))
        elif attempt_no > 0 and checkpoint_dir is not None:
            state, _ = _latest_valid_state(
                checkpoint_dir, engine.init_state(active, priority))
            if state is not None:
                restarts[-1].restored_superstep = int(state.superstep)
        if state is None:
            state = engine.init_state(active, priority)

        while True:
            step = int(state.superstep)
            if faults is not None:
                faults.fire("superstep", superstep=step)
            if num_supersteps is not None:
                if step >= num_supersteps:
                    break
            elif (not bool(state.active.any())
                  or step >= engine.max_supersteps):
                break
            if until is not None and until(state.globals):
                break
            state = engine._step_jit(state)
            step = int(state.superstep)
            if (checkpoint_every and checkpoint_dir
                    and step % checkpoint_every == 0):
                if faults is not None:
                    faults.fire("checkpoint_write", superstep=step)
                snapshot_engine_state(
                    os.path.join(checkpoint_dir,
                                 f"state_step_{step:08d}.npz"), state)
        return state

    kwargs = {} if sleep is None else {"sleep": sleep}
    return supervised(attempt, max_restarts=max_restarts,
                      backoff_base_s=backoff_base_s, **kwargs)
