"""Deterministic fault injection for distributed runs.

A :class:`FaultPlan` is a list of seeded, one-shot events fired from
*host-side* hook sites (DESIGN.md §12) — device-traced code is never
branched on the plan, so a run with ``faults=None`` pays nothing and a
run with faults compiles the exact same programs:

* site ``"superstep"`` — fired by the engines' ``step_chunk`` at a
  superstep boundary, before launching the next chunk.  ``kill``
  raises :class:`InjectedKill` (a shard process dying mid-run),
  ``transient`` raises :class:`TransientFault` (a recoverable host
  error), ``straggle`` sleeps ``delay_s`` (a delayed ghost exchange:
  the boundary is where ghost data ships, so delaying the boundary IS
  delaying the exchange).
* site ``"checkpoint_write"`` — fired between per-shard snapshot file
  writes; ``checkpoint_fail`` raises :class:`CheckpointWriteFault`,
  leaving the snapshot tmp directory torn (the atomicity test).

Events fire **once** (``fired`` flips) so the supervisor's replay after
a restart does not re-kill the run at the same boundary — exactly how
a real crashed-once process behaves.  ``next_trigger`` tells the
driver where to split its chunks so a fault at superstep k interrupts
the run at k, not at the next checkpoint multiple.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

_BOUNDARY_KINDS = ("kill", "transient", "straggle")
KINDS = _BOUNDARY_KINDS + ("checkpoint_fail",)


class InjectedFault(Exception):
    """Base of every injected failure (the supervisor's default
    restartable set)."""


class InjectedKill(InjectedFault):
    """A shard process killed at a superstep boundary."""


class TransientFault(InjectedFault):
    """A transient host-loop error (flaky RPC, OOM-retry, ...)."""


class CheckpointWriteFault(InjectedFault):
    """A failure in the middle of writing a snapshot."""


@dataclasses.dataclass
class FaultEvent:
    kind: str                 # kill | transient | straggle | checkpoint_fail
    superstep: int            # boundary at (or after) which it fires
    shard: int = 0            # which shard "dies" (recorded, not selective:
                              # one host simulates all shards)
    delay_s: float = 0.0      # straggle sleep
    fired: bool = False

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")


class FaultPlan:
    """An ordered set of one-shot fault events."""

    def __init__(self, events: Sequence[FaultEvent]):
        self.events = list(events)
        self.log: list[str] = []

    @classmethod
    def seeded(cls, seed: int, *, n_shards: int, max_superstep: int,
               n_events: int = 1,
               kinds: Sequence[str] = ("kill",)) -> "FaultPlan":
        """Deterministically sample ``n_events`` events: uniform kind
        from ``kinds``, superstep in [1, max_superstep), shard in
        [0, n_shards)."""
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            events.append(FaultEvent(
                kind=str(rng.choice(list(kinds))),
                superstep=int(rng.integers(1, max(2, max_superstep))),
                shard=int(rng.integers(max(1, n_shards))),
                delay_s=float(rng.uniform(0.001, 0.01))))
        return cls(events)

    def next_trigger(self, step: int) -> int | None:
        """Earliest unfired boundary-event superstep strictly after
        ``step`` — the driver caps its chunk there."""
        pending = [e.superstep for e in self.events
                   if not e.fired and e.kind in _BOUNDARY_KINDS
                   and e.superstep > step]
        return min(pending) if pending else None

    def fire(self, site: str, *, superstep: int,
             shard: int | None = None) -> None:
        """Fire every due, unfired event for ``site``.  Raises for
        kill/transient/checkpoint_fail; sleeps for straggle."""
        for e in self.events:
            if e.fired or superstep < e.superstep:
                continue
            if site == "superstep" and e.kind in _BOUNDARY_KINDS:
                e.fired = True
                self.log.append(f"{e.kind}@{superstep}(shard {e.shard})")
                if e.kind == "kill":
                    raise InjectedKill(
                        f"injected kill of shard {e.shard} at superstep "
                        f"{superstep}")
                if e.kind == "transient":
                    raise TransientFault(
                        f"injected transient fault at superstep "
                        f"{superstep}")
                time.sleep(e.delay_s)       # straggle, then continue
            elif site == "checkpoint_write" and e.kind == "checkpoint_fail":
                e.fired = True
                self.log.append(
                    f"checkpoint_fail@{superstep}(shard {shard})")
                raise CheckpointWriteFault(
                    f"injected checkpoint-write failure at superstep "
                    f"{superstep}, shard file {shard}")

    @property
    def all_fired(self) -> bool:
        return all(e.fired for e in self.events)
