"""Primitive layers (pure functions over param pytrees; no flax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16):
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return jax.random.normal(key, (d_in, d_out), jnp.float32).astype(dtype) * scale


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return jax.random.normal(key, (vocab, d), jnp.float32).astype(dtype) * 0.02


def rmsnorm_init(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt((x * x).mean(-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def rope(x, positions, theta: float = 1e4):
    """x: [..., S, H, dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                            # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]
