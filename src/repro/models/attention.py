"""GQA attention: training (full/sliding-window causal) and cached decode."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import linear_init, rmsnorm, rmsnorm_init, rope


def init(key, cfg, dtype=jnp.bfloat16) -> dict:
    d, dh = cfg.d_model, cfg.dh
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], d, cfg.n_heads * dh, dtype),
        "wk": linear_init(ks[1], d, cfg.n_kv_heads * dh, dtype),
        "wv": linear_init(ks[2], d, cfg.n_kv_heads * dh, dtype),
        "wo": linear_init(ks[3], cfg.n_heads * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh)
        p["k_norm"] = rmsnorm_init(dh)
    return p


def _qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    dh = cfg.dh
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, dh)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, dh)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, n_rep: int):
    """q: [B,S,H,dh], k/v: [B,T,Hkv,dh]; mask [S,T] or [B,S,T] additive."""
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    s = s + mask
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", p, v)


def causal_mask(s: int, window: int | None = None):
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    ok = j <= i
    if window is not None:
        ok &= (i - j) < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


_FLASH_THRESHOLD = 2048
_QC = 512      # query chunk
_KC = 1024     # kv chunk


def flash_attention(q, k, v, causal: bool, window: int | None,
                    n_rep: int) -> jax.Array:
    """Memory-bounded attention: online softmax over KV chunks inside a
    scan over query chunks.  Peak live score block is [B,H,QC,KC] instead
    of [B,H,S,S] — required for the 32k/500k shapes.  (The Pallas
    window-attention kernel is the decode-path analogue.)"""
    b, s, h, dh = q.shape
    t = k.shape[1]
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    qc = min(_QC, s)
    kc = min(_KC, t)
    nq, nk = s // qc, t // kc          # shapes are pow2-padded upstream
    scale = dh ** -0.5
    qr = q.reshape(b, nq, qc, h, dh).transpose(1, 0, 3, 2, 4)  # [nq,B,H,qc,dh]
    kr = k.reshape(b, nk, kc, h, dh).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(b, nk, kc, h, dh).transpose(1, 0, 3, 2, 4)

    def q_block(qi, qb):
        qpos = qi * qc + jnp.arange(qc)

        def kv_step(carry, inp):
            m_p, l_p, acc = carry
            ki, kb, vb = inp
            kpos = ki * kc + jnp.arange(kc)
            # §Perf B1: score/prob tiles stored bf16 (the dominant HBM
            # stream at S^2 scale); the running max/denominator stay f32,
            # and the max-subtraction bounds |sc - m| so bf16's 8-bit
            # mantissa costs ~1e-2 relative on pr — validated by
            # test_flash_attention_matches_dense.
            sc = jnp.einsum("bhqd,bhkd->bhqk", qb, kb) * scale
            ok = jnp.ones((qc, kc), bool)
            if causal:
                ok &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                ok &= (qpos[:, None] - kpos[None, :]) < window
            neg = jnp.asarray(-jnp.inf, jnp.float32)
            sc32 = jnp.where(ok, sc.astype(jnp.float32), neg)
            m_c = jnp.maximum(m_p, sc32.max(-1))
            # fully-masked blocks keep m == -inf; guard the exps so the
            # running state stays finite (entries are masked to 0 anyway)
            m_safe = jnp.where(jnp.isfinite(m_c), m_c, 0.0)
            pr = jnp.exp((sc.astype(jnp.float32)
                          - m_safe[..., None])).astype(qb.dtype)
            pr = jnp.where(ok, pr, 0)
            alpha = jnp.where(jnp.isfinite(m_p), jnp.exp(m_p - m_safe), 0.0)
            l_c = alpha * l_p + pr.astype(jnp.float32).sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", pr, vb).astype(jnp.float32)
            return (m_c, l_c, acc), None

        m0 = jnp.full((b, h, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        a0 = jnp.zeros((b, h, qc, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kr, vr))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return out                                       # [B,H,qc,dh]

    # checkpoint each q block: backward recomputes one block's score
    # tiles instead of keeping all nq*nk of them live (memory parity
    # with a flash kernel's recompute strategy)
    q_block_ckpt = jax.checkpoint(
        q_block, policy=jax.checkpoint_policies.nothing_saveable)
    ob = jax.lax.map(lambda args: q_block_ckpt(*args), (jnp.arange(nq), qr))
    return ob.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dh)


def self_attention(p, cfg, x, positions, causal: bool = True,
                   window: int | None = "cfg") -> jax.Array:
    b, s, d = x.shape
    if window == "cfg":
        window = cfg.window
    q, k, v = _qkv(p, cfg, x, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    if s > _FLASH_THRESHOLD:
        o = flash_attention(q, k, v, causal, window, n_rep)
    else:
        if causal:
            mask = causal_mask(s, window)
        else:
            mask = jnp.zeros((s, s), jnp.float32)
        o = _sdpa(q, k, v, mask, n_rep)
    return o.reshape(b, s, -1) @ p["wo"]


def cross_attention_init(key, cfg, dtype=jnp.bfloat16) -> dict:
    return init(key, cfg, dtype)


def cross_attention(p, cfg, x, mem_k, mem_v, mem_mask) -> jax.Array:
    """x: [B,S,d]; mem_k/v precomputed [B,T,Hkv,dh]; mem_mask [B,T] bool."""
    b, s, _ = x.shape
    dh = cfg.dh
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = mem_k, mem_v
    if max(s, k.shape[1]) > _FLASH_THRESHOLD:
        # long memories: flash path (mem assumed fully valid — dry-run
        # and full-batch serving; ragged memories use the dense path)
        o = flash_attention(q, k, v, causal=False, window=None,
                            n_rep=n_rep)
        return o.reshape(b, s, -1) @ p["wo"]
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    mask = jnp.where(mem_mask[:, None, None, :], 0.0, -jnp.inf)  # [B,1,1,T]
    scale = dh ** -0.5
    sc = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    sc = sc + mask
    pr = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhst,bthd->bshd", pr, v)
    return o.reshape(b, s, -1) @ p["wo"]


def mem_kv(p, cfg, mem):
    """Precompute cross-attention K/V from encoder output [B,T,d]."""
    b, t, _ = mem.shape
    dh = cfg.dh
    k = (mem @ p["wk"]).reshape(b, t, cfg.n_kv_heads, dh)
    v = (mem @ p["wv"]).reshape(b, t, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return k, v


# ----------------------------------------------------------------------
# Decode path: one query token against a KV cache.
# ----------------------------------------------------------------------

def decode_attention(p, cfg, x, cache_k, cache_v, cache_len,
                     slot=None):
    """x: [B,1,d]; cache_k/v: [B,W,Hkv,dh]; cache_len: [B] valid rows.

    Insert-then-attend: the new token's K/V go into the ring slot FIRST
    and attention runs over the cache alone.  Keeping one contiguous
    [B,W,...] operand lets the W axis stay sharded end-to-end (scores are
    constrained to P(dp,·,·,model)); GSPMD then computes *partial*
    softmax/combine per shard with tiny all-reduces instead of
    all-gathering the whole cache per layer (§Perf iteration A2).  GQA is
    a grouped einsum — no head-repeat materialization.

    Returns (out [B,1,d], new_cache_k, new_cache_v).
    """
    from repro.launch import shardctx
    b = x.shape[0]
    dh = cfg.dh
    hkv = cfg.n_kv_heads
    n_rep = cfg.n_heads // hkv
    pos = cache_len.astype(jnp.int32)[:, None]           # position = len
    q = (x @ p["wq"]).reshape(b, 1, cfg.n_heads, dh)
    k = (x @ p["wk"]).reshape(b, 1, hkv, dh)
    v = (x @ p["wv"]).reshape(b, 1, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    w = cache_k.shape[1]
    if slot is None:
        slot = (cache_len % w).astype(jnp.int32)
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))
    t = jnp.arange(w)[None, :]
    # ring buffer: once cache_len wraps past W every row is valid; the
    # just-inserted slot is always valid
    valid = (t < jnp.minimum(cache_len + 1, w)[:, None]) \
        | (t == slot[:, None])                            # [B,W]
    qg = q.reshape(b, hkv, n_rep, dh)
    scale = dh ** -0.5
    s = jnp.einsum("bgrd,btgd->bgrt", qg, cache_k).astype(jnp.float32)
    s = s * scale
    s = shardctx.hint(s, shardctx.DP, None, None, shardctx.TP)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1, keepdims=True)
    pr = jnp.exp(s - m)
    pr = pr / pr.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bgrt,btgd->bgrd", pr.astype(x.dtype), cache_v)
    out = o.reshape(b, 1, -1) @ p["wo"]
    return out, cache_k, cache_v
