"""Mixture-of-Experts layer with sort-based dispatch (expert parallel).

The token->expert dispatch is a *bipartite data graph* — the GraphLab
view of MoE (DESIGN.md §5): tokens on one side, experts on the other,
the all_to_all is the ghost exchange, and the chromatic 2-coloring is the
(tokens-phase, experts-phase) alternation.  The router load-balance aux
loss is a sync operation (a global Fold/Merge of per-expert counts).

Dispatch avoids the O(N·E) one-hot matrices of the GShard formulation:
expert assignments are *sorted* (O(Nk log Nk)), positions within each
expert computed by searchsorted, and tokens scattered into the capacity
buffer [E, C, d] — dropping overflow like capacity-factor routing.
Expert compute is one batched einsum over the expert axis, shardable on
the "model" mesh axis (expert parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import shardctx
from repro.models.layers import act_fn, linear_init


def init(key, cfg, dtype=jnp.bfloat16) -> dict:
    m = cfg.moe
    d, dff, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 4)
    scale_in = (2.0 / (d + dff)) ** 0.5
    return {
        "router": linear_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, dff), jnp.float32)
                   * scale_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, dff), jnp.float32)
                 * scale_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, dff, d), jnp.float32)
                   * scale_in).astype(dtype),
    }


def apply(p, cfg, x):
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar).

    Dispatch is per *group* (one batch row = one group, vmapped), the
    GShard grouping that keeps sort/rank computation local to the data
    shard — a global argsort over all tokens would all-gather the whole
    token stream (observed as a 100x collective blow-up in the dry-run;
    see EXPERIMENTS.md §Perf)."""
    m = cfg.moe
    b0, s0, d = x.shape
    k = m.top_k
    e = m.n_experts
    # group selection: one batch row per group for training shapes; for
    # decode (s == 1) a per-row group would run EVERY expert on every
    # token (cap >= 1 each) — group the whole local batch instead.
    if s0 == 1:
        x = x.reshape(1, b0, d)
    b, s = x.shape[:2]
    cap = int(max(1, min(s, (s * k * m.capacity_factor) // e + 1)))

    logits = (x.astype(jnp.float32) @ p["router"])           # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                     # [B, S, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (the sync-op analogue): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))                             # [E]
    ce = jnp.zeros((e,), jnp.float32).at[eidx.reshape(-1)].add(
        jnp.ones((b * s * k,), jnp.float32)) / (b * s * k)
    aux = e * (me * ce).sum()

    def dispatch_row(xr, er):
        """xr: [S, d]; er: [S, k] -> buf [E, cap, d] + combine metadata."""
        flat_e = er.reshape(-1)                              # [S*k]
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        start = jnp.searchsorted(sorted_e, jnp.arange(e))
        pos_sorted = jnp.arange(s * k) - start[sorted_e]
        tok_sorted = order // k
        keep = pos_sorted < cap
        buf = jnp.zeros((e, cap, d), xr.dtype)
        scat_e = jnp.where(keep, sorted_e, e)
        buf = buf.at[scat_e, jnp.where(keep, pos_sorted, 0)].set(
            xr[tok_sorted], mode="drop")
        inv = jnp.argsort(order)
        return buf, pos_sorted[inv], keep[inv]

    buf, pos_u, keep_u = jax.vmap(dispatch_row)(x, eidx)     # [B,E,cap,d]
    buf = shardctx.hint(buf, shardctx.DP, shardctx.TP, None, None)

    # ---- expert FFN: batched over the (expert-parallel) expert axis ----
    act = act_fn(cfg.act)
    h = act(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) \
        * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])   # [B,E,cap,d]
    # reshard for the combine: gathers index the expert axis, so move the
    # sharding from E (expert-parallel, needed for the FFN einsums) to d
    # — otherwise GSPMD materializes full-d replicated gather results.
    out_buf = shardctx.hint(out_buf, shardctx.DP, None, None, shardctx.TP)

    def combine_row(out_r, er, pos_r, keep_r, gate_r):
        flat_e = er.reshape(-1)
        contrib = out_r[flat_e, jnp.clip(pos_r, 0, cap - 1)]  # [S*k, d]
        contrib = jnp.where(keep_r[:, None], contrib, 0.0)
        return (contrib.reshape(s, k, d)
                * gate_r[..., None].astype(out_r.dtype)).sum(axis=1)

    y = jax.vmap(combine_row)(out_buf, eidx, pos_u, keep_u, gate)
    return y.reshape(b0, s0, d), aux
