"""Composable model definition covering all six assigned arch families.

Pure-function style: ``init_params(key, cfg)`` builds a param pytree
(per-layer params stacked on a leading axis so the forward pass is a
``lax.scan`` over layers — essential to keep HLO size and compile time
bounded at 94 layers), ``forward`` / ``prefill`` / ``decode_step`` are
the three entry points the launcher lowers.

Families:
  dense / moe       uniform decoder layers (attention + MLP/MoE)
  ssm               uniform Mamba-1 layers (no attention, no MLP)
  hybrid (jamba)    scan over 8-layer periods: [attn, mamba x7], MoE on
                    odd layers (cfg.attn_every, cfg.moe.every)
  vlm               dense decoder consuming [projected patch embeddings;
                    token embeddings] (frontend stubbed per the brief)
  audio (enc-dec)   bidirectional encoder over frame embeddings (stub
                    frontend) + causal decoder with cross-attention
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import shardctx
from repro.models import attention, mamba, moe
from repro.models.layers import (act_fn, embed_init, linear_init, rmsnorm,
                                 rmsnorm_init)

PyTree = Any


def vocab_padded(cfg: ModelConfig) -> int:
    """Pad vocab to a shardable multiple (MaxText-style logit padding)."""
    return -(-cfg.vocab // 512) * 512


# ----------------------------------------------------------------------
# Layer init
# ----------------------------------------------------------------------

def _mlp_init(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": linear_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
        "w_up": linear_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
        "w_down": linear_init(ks[2], cfg.d_ff, cfg.d_model, dtype),
    }


def _mlp_apply(p, cfg, x):
    a = act_fn(cfg.act)
    return (a(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def init_layer(key, cfg, attn: bool, moe_layer: bool, cross: bool = False,
               dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 4)
    p = {"norm1": rmsnorm_init(cfg.d_model)}
    p["mix"] = (attention.init(ks[0], cfg, dtype) if attn
                else mamba.init(ks[0], cfg, dtype))
    if cross:
        p["norm_x"] = rmsnorm_init(cfg.d_model)
        p["cross"] = attention.cross_attention_init(ks[2], cfg, dtype)
    if moe_layer:
        p["norm2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = moe.init(ks[1], cfg, dtype)
    elif cfg.d_ff:
        p["norm2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = _mlp_init(ks[1], cfg, dtype)
    return p


def _stack_init(key, cfg, n: int, attn: bool, moe_layer: bool,
                cross: bool = False, dtype=jnp.bfloat16):
    keys = jax.random.split(key, n)
    return jax.vmap(
        lambda k: init_layer(k, cfg, attn, moe_layer, cross, dtype))(keys)


def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> PyTree:
    ks = jax.random.split(key, 8)
    vp = vocab_padded(cfg)
    params: dict = {
        "embed": embed_init(ks[0], vp, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["out"] = embed_init(ks[1], vp, cfg.d_model, dtype)
    if cfg.arch_type in ("dense", "vlm"):
        params["layers"] = _stack_init(ks[2], cfg, cfg.n_layers, True, False,
                                       dtype=dtype)
    elif cfg.arch_type == "moe":
        params["layers"] = _stack_init(ks[2], cfg, cfg.n_layers, True, True,
                                       dtype=dtype)
    elif cfg.arch_type == "ssm":
        params["layers"] = _stack_init(ks[2], cfg, cfg.n_layers, False,
                                       False, dtype=dtype)
    elif cfg.arch_type == "hybrid":
        period = cfg.attn_every
        n_periods = cfg.n_layers // period
        params["layers"] = {
            f"l{j}": _stack_init(
                jax.random.fold_in(ks[2], j), cfg, n_periods,
                attn=(j % period == 0), moe_layer=cfg.is_moe_layer(j),
                dtype=dtype)
            for j in range(period)
        }
    elif cfg.arch_type == "audio":
        params["enc_layers"] = _stack_init(ks[3], cfg, cfg.n_enc_layers,
                                           True, False, dtype=dtype)
        params["enc_norm"] = rmsnorm_init(cfg.d_model)
        params["layers"] = _stack_init(ks[2], cfg, cfg.n_layers, True,
                                       False, cross=True, dtype=dtype)
    else:
        raise ValueError(cfg.arch_type)
    if cfg.arch_type == "vlm":
        params["projector"] = {
            "w1": linear_init(ks[4], cfg.d_model, cfg.d_model, dtype),
            "w2": linear_init(ks[5], cfg.d_model, cfg.d_model, dtype),
        }
    return params


# ----------------------------------------------------------------------
# Forward (training / prefill)
# ----------------------------------------------------------------------

def _layer_apply(p, cfg, x, positions, attn: bool, moe_layer: bool,
                 causal: bool = True, mem=None):
    x = shardctx.residual_hint(x)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if attn:
        x = x + attention.self_attention(p["mix"], cfg, h, positions,
                                         causal=causal)
    else:
        x = x + mamba.apply_train(p["mix"], cfg, h)
    if mem is not None:
        hx = rmsnorm(x, p["norm_x"], cfg.norm_eps)
        mk, mv = attention.mem_kv(p["cross"], cfg, mem)
        mmask = jnp.ones(mem.shape[:2], bool)
        x = x + attention.cross_attention(p["cross"], cfg, hx, mk, mv, mmask)
    aux = jnp.float32(0.0)
    if "ffn" in p:
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if moe_layer:
            y, aux = moe.apply(p["ffn"], cfg, h2)
        else:
            y = _mlp_apply(p["ffn"], cfg, h2)
        x = x + y
    x = shardctx.residual_hint(x)
    return x, aux


def _run_stack(stacked, cfg, x, positions, attn: bool, moe_layer: bool,
               causal: bool = True, mem=None, remat: bool = True):
    def body(carry, lp):
        x, aux = carry
        fn = functools.partial(_layer_apply, cfg=cfg, attn=attn,
                               moe_layer=moe_layer, causal=causal)
        if remat:
            fn = jax.checkpoint(
                lambda lp_, x_, pos_, mem_: _layer_apply(
                    lp_, cfg, x_, pos_, attn, moe_layer, causal, mem_),
                policy=jax.checkpoint_policies.nothing_saveable)
            x2, a = fn(lp, x, positions, mem)
        else:
            x2, a = _layer_apply(lp, cfg, x, positions, attn, moe_layer,
                                 causal, mem)
        return (x2, aux + a), None
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return x, aux


def _run_hybrid(layers, cfg, x, positions, remat: bool = True):
    period = cfg.attn_every

    def body(carry, period_params):
        x, aux = carry
        for j in range(period):
            lp = period_params[f"l{j}"]
            attn = (j % period == 0)
            moe_layer = cfg.is_moe_layer(j)
            if remat:
                x, a = jax.checkpoint(
                    lambda lp_, x_, pos_, _a=attn, _m=moe_layer:
                        _layer_apply(lp_, cfg, x_, pos_, _a, _m),
                    policy=jax.checkpoint_policies.nothing_saveable,
                )(lp, x, positions)
            else:
                x, a = _layer_apply(lp, cfg, x, positions, attn, moe_layer)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), layers)
    return x, aux


def _trunk(params, cfg, x, positions, remat: bool = True, mem=None):
    if cfg.arch_type == "hybrid":
        return _run_hybrid(params["layers"], cfg, x, positions, remat)
    attn = cfg.arch_type != "ssm"
    moe_layer = cfg.arch_type == "moe"
    return _run_stack(params["layers"], cfg, x, positions, attn, moe_layer,
                      causal=True, mem=mem, remat=remat)


def _logits(params, cfg, x):
    out = params.get("out", params["embed"])
    # Gather the (small) FSDP-sharded d_model axis of the output embedding
    # instead of letting GSPMD all-reduce the (huge) [B,S,V] partial
    # logits over the data axis — see EXPERIMENTS.md §Perf.
    out = _hint(out, ("model", None))
    logits = jnp.einsum("bsd,vd->bsv", x, out).astype(jnp.float32)
    logits = _hint(logits, (_DATA_HINT, None, "model"))
    vp = vocab_padded(cfg)
    if vp != cfg.vocab:   # mask padded vocabulary rows
        logits = jnp.where(
            jnp.arange(vp) < cfg.vocab, logits, -1e9)
    return logits


_DATA_HINT = ("pod", "data")


def _hint(x, spec):
    """Sharding constraint applied only when the mesh axes exist (no-op in
    single-device smoke tests)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        names = set(mesh.axis_names)
        from jax.sharding import PartitionSpec as P
        def ok(ax):
            if ax is None:
                return None
            if isinstance(ax, tuple):
                sub = tuple(a for a in ax if a in names)
                return sub if sub else None
            return ax if ax in names else None
        cleaned = [ok(ax) for ax in spec]
        # drop axes that do not divide the dim
        sizes = dict(zip(mesh.axis_names, mesh.shape.values())) \
            if hasattr(mesh, "shape") else {}
        fixed = []
        for dim, ax in zip(x.shape, cleaned):
            if ax is None:
                fixed.append(None)
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axs:
                n *= sizes.get(a, 1)
            fixed.append(ax if n and dim % n == 0 else None)
        return jax.lax.with_sharding_constraint(x, P(*fixed))
    except Exception:
        return x


def _embed_tokens(params, cfg, tokens):
    return params["embed"][tokens]


def _encode(params, cfg, frames):
    """Audio encoder: bidirectional (windowed) self-attention stack."""
    pos = jnp.arange(frames.shape[1])[None]
    x, _ = _run_stack(params["enc_layers"], cfg, frames, pos, attn=True,
                      moe_layer=False, causal=False, remat=True)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch: dict, remat: bool = True):
    """Training forward: returns (loss, metrics)."""
    if cfg.arch_type == "audio":
        mem = _encode(params, cfg, batch["frames"].astype(jnp.bfloat16))
        x = _embed_tokens(params, cfg, batch["tokens"])
        pos = jnp.arange(x.shape[1])[None]
        x, aux = _trunk(params, cfg, x, pos, remat, mem=mem)
        label_mask = jnp.ones(batch["tokens"].shape, bool)
    elif cfg.arch_type == "vlm":
        patches = batch["patches"].astype(jnp.bfloat16)
        pr = params["projector"]
        patches = jax.nn.gelu(patches @ pr["w1"]) @ pr["w2"]
        toks = _embed_tokens(params, cfg, batch["tokens"])
        x = jnp.concatenate([patches, toks], axis=1)
        pos = jnp.arange(x.shape[1])[None]
        x, aux = _trunk(params, cfg, x, pos, remat)
        x = x[:, patches.shape[1]:]          # loss on text positions only
        label_mask = jnp.ones(batch["tokens"].shape, bool)
    else:
        x = _embed_tokens(params, cfg, batch["tokens"])
        pos = jnp.arange(x.shape[1])[None]
        x, aux = _trunk(params, cfg, x, pos, remat)
        label_mask = jnp.ones(batch["tokens"].shape, bool)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, x)
    labels = batch["labels"]
    # sharding-aware CE: no gather over the (model-sharded) vocab axis —
    # logsumexp reduces locally + psums, the label logit comes from a
    # fused one-hot contraction (never materializes unsharded logits).
    lse = jax.nn.logsumexp(logits, axis=-1)
    vp = logits.shape[-1]
    onehot = jax.nn.one_hot(labels, vp, dtype=logits.dtype)
    label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - label_logit
    loss = (nll * label_mask).sum() / jnp.maximum(label_mask.sum(), 1)
    aux_w = 0.01 if cfg.moe is not None else 0.0
    return loss + aux_w * aux, {"nll": loss, "aux": aux}


def prefill(params, cfg: ModelConfig, batch: dict):
    """Inference prefill: forward without loss; returns last-token logits.

    (KV-cache materialization from prefill is modeled for attention archs
    in serve.py; SSM/hybrid prefill returns logits only — see DESIGN.md.)
    """
    if cfg.arch_type == "audio":
        mem = _encode(params, cfg, batch["frames"].astype(jnp.bfloat16))
        x = _embed_tokens(params, cfg, batch["tokens"])
        pos = jnp.arange(x.shape[1])[None]
        x, _ = _trunk(params, cfg, x, pos, remat=True, mem=mem)
    elif cfg.arch_type == "vlm":
        patches = batch["patches"].astype(jnp.bfloat16)
        pr = params["projector"]
        patches = jax.nn.gelu(patches @ pr["w1"]) @ pr["w2"]
        toks = _embed_tokens(params, cfg, batch["tokens"])
        x = jnp.concatenate([patches, toks], axis=1)
        pos = jnp.arange(x.shape[1])[None]
        x, _ = _trunk(params, cfg, x, pos, remat=True)
    else:
        x = _embed_tokens(params, cfg, batch["tokens"])
        pos = jnp.arange(x.shape[1])[None]
        x, _ = _trunk(params, cfg, x, pos, remat=True)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return _logits(params, cfg, x)[:, 0]
