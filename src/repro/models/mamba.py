"""Mamba-1 selective SSM block (falcon-mamba, jamba mamba layers).

Training path uses a chunked ``lax.scan`` over time with an inner
``associative_scan`` per chunk: the diagonal recurrence
``h_t = a_t * h_{t-1} + b_t`` composes associatively as
(a, b) o (a', b') = (a*a', a'*b + b'), giving O(log chunk) depth on the
VPU while the chunk loop bounds memory — the TPU-native adaptation of the
paper-orthogonal CUDA selective-scan kernel (see DESIGN.md: the GraphLab
chromatic schedule on a chain graph would be odd/even coloring; the
associative scan is strictly better on TPU and we use it).

Decode is the O(1) recurrent step on a [B, d_inner, d_state] state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import linear_init

_CHUNK = 256


def init(key, cfg, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    ds = cfg.ssm.d_state
    dtr = cfg.ssm.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": linear_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.d_conv, di),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": linear_init(ks[2], di, dtr + 2 * ds, dtype),
        "dt_proj": linear_init(ks[3], dtr, di, jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32) + jnp.log(
            jnp.expm1(jnp.asarray(0.01))),
        "A_log": jnp.log(a),                  # [di, ds] fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": linear_init(ks[4], di, d, dtype),
    }


def _ssm_inputs(p, cfg, xz):
    """Common front half: conv + selective params.  xz: [B,S,2*di]."""
    di = cfg.d_inner
    ds = cfg.ssm.d_state
    dtr = cfg.ssm.dt_rank or -(-cfg.d_model // 16)
    x, z = xz[..., :di], xz[..., di:]
    # depthwise causal conv over time
    dc = cfg.ssm.d_conv
    pads = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    x = sum(pads[:, i: i + x.shape[1]] * p["conv_w"][i]
            for i in range(dc)) + p["conv_b"]
    x = jax.nn.silu(x)
    proj = x @ p["x_proj"]                                   # [B,S,dtr+2ds]
    dt = jax.nn.softplus(
        proj[..., :dtr].astype(jnp.float32) @ p["dt_proj"]
        + p["dt_bias"])                                      # [B,S,di]
    bmat = proj[..., dtr: dtr + ds].astype(jnp.float32)      # [B,S,ds]
    cmat = proj[..., dtr + ds:].astype(jnp.float32)          # [B,S,ds]
    return x, z, dt, bmat, cmat


def apply_train(p, cfg, x):
    """x: [B, S, d] -> [B, S, d]; chunked associative selective scan.

    The [B, chunk, di, ds] discretized-state tensors are built and
    consumed INSIDE the chunk loop so peak memory is one chunk's states,
    not the full sequence's (factor d_state saved — this is the VMEM-
    resident-state idea of the CUDA selective-scan kernel, expressed at
    the XLA level)."""
    b, s, d = x.shape
    di = cfg.d_inner
    ds = cfg.ssm.d_state
    xz = x @ p["in_proj"]
    xc, z, dt, bmat, cmat = _ssm_inputs(p, cfg, xz)
    a = -jnp.exp(p["A_log"])                                 # [di, ds]

    chunk = min(_CHUNK, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s

    def pad_seq(t, fill=0.0):
        if not pad:
            return t
        cfgpad = [(0, 0)] * t.ndim
        cfgpad[1] = (0, pad)
        return jnp.pad(t, cfgpad, constant_values=fill)

    def chunked(t):
        t = pad_seq(t)
        return t.reshape((b, n_chunks, chunk) + t.shape[2:]) \
                .swapaxes(0, 1)                              # [n,B,chunk,...]

    dt_c = chunked(dt)                                       # [n,B,c,di]
    b_c = chunked(bmat)                                      # [n,B,c,ds]
    c_c = chunked(cmat)
    x_c = chunked(xc.astype(jnp.float32))                    # [n,B,c,di]

    def outer(h0, inp):
        dtk, bk, ck, xk = inp
        da = jnp.exp(dtk[..., None] * a)                     # [B,c,di,ds]
        dbx = (dtk * xk)[..., None] * bk[..., None, :]       # [B,c,di,ds]

        def combine(l, r):
            return (l[0] * r[0], l[1] * r[0] + r[1])
        aa, hh = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        hh = hh + aa * h0[:, None]                           # inject carry
        yk = jnp.einsum("bcdn,bcn->bcd", hh, ck)             # [B,c,di]
        return hh[:, -1], yk

    # checkpoint the chunk body: without this, the chunk loop's backward
    # keeps every chunk's [B,c,di,ds] discretized states live at once
    # (observed: ~20GB/chip per mamba layer on jamba) — with it, one
    # chunk's states at a time.
    outer = jax.checkpoint(
        outer, policy=jax.checkpoint_policies.nothing_saveable)
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    _, ys = jax.lax.scan(outer, h0, (dt_c, b_c, c_c, x_c))
    y = ys.swapaxes(0, 1).reshape(b, n_chunks * chunk, di)[:, :s]
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"]


def init_decode_state(cfg, batch: int):
    di = cfg.d_inner
    return {
        "h": jnp.zeros((batch, di, cfg.ssm.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), jnp.bfloat16),
    }


def apply_decode(p, cfg, x, state):
    """x: [B, 1, d]; O(1) recurrent step.  Returns (y, new_state)."""
    b = x.shape[0]
    di = cfg.d_inner
    ds = cfg.ssm.d_state
    dtr = cfg.ssm.dt_rank or -(-cfg.d_model // 16)
    xz = x @ p["in_proj"]                                    # [B,1,2di]
    xr, z = xz[..., :di], xz[..., di:]
    # conv with remembered tail
    hist = jnp.concatenate([state["conv"].astype(xr.dtype), xr], axis=1)
    dc = cfg.ssm.d_conv
    xc = sum(hist[:, -dc + i] * p["conv_w"][i] for i in range(dc)) \
        + p["conv_b"]                                        # [B,di]
    xc = jax.nn.silu(xc)
    proj = xc @ p["x_proj"]
    dt = jax.nn.softplus(
        proj[..., :dtr].astype(jnp.float32) @ p["dt_proj"] + p["dt_bias"])
    bm = proj[..., dtr: dtr + ds].astype(jnp.float32)
    cm = proj[..., dtr + ds:].astype(jnp.float32)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt[..., None] * a)                          # [B,di,ds]
    h = state["h"] * da + (dt * xc.astype(jnp.float32))[..., None] \
        * bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cm) + xc.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    new_state = {"h": h, "conv": hist[:, 1:].astype(jnp.bfloat16)}
    return (y @ p["out_proj"])[:, None], new_state
