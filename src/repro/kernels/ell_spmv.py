"""Pallas TPU kernel: ELL sparse neighbor aggregation (gather-sum).

The inner loop of every sweep-style GraphLab update (PageRank Alg. 1,
CoEM, the BSP baselines) is

    y[v, :] = sum_j  w[v, j] * x[nbrs[v, j], :]        (padded slots w=0)

i.e. an SpMV with the matrix in ELLPACK layout and a feature axis.  On
GPU the classic implementation is one warp per row with texture-cache
gathers.  The TPU adaptation (see DESIGN.md §3): tile *vertices* into
VPU-aligned row blocks (grid dim 0), keep the *full* source feature
block resident in VMEM (graphs are partitioned per shard, so x is the
shard-local [R, F] block — the partitioner bounds R), and unroll the
neighbor-slot axis statically so each slot becomes a dense [TV, F]
gather + multiply-accumulate on the VPU.  Feature tiling (grid dim 1)
keeps the x block under the VMEM budget for wide features.

Generalized for the executor core's aggregator fast path (DESIGN.md §4):
an optional **active-row mask** gates rows of the task batch in-kernel
(the engines' ``sel`` mask — inactive / padded batch slots produce
zeros, and masked rows never contribute garbage weights).  Active rows
are multiplied by exactly 1.0, so the mask never perturbs results.

``ell_fold`` reduces *pre-gathered* ``[B, D, F]`` scope values with the
exact same compiled accumulation, by calling this kernel with trivial
indices over the flattened values.  That is what makes the engines'
dense-scope fallback bit-identical to the kernel fast path: floating
multiply-add chains are contraction-sensitive (FMA fusion differs
between compilation contexts), so the only robust route to bitwise
parity is to run both reductions through the same kernel (DESIGN.md §4).

Validated against ``ref.ell_spmv_ref`` in interpret mode (this container
is CPU-only; TPU is the target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU lane/sublane alignment
_TV = 128        # vertex rows per block
_TF = 128        # feature columns per tile


def _spmv_kernel(nbrs_ref, w_ref, rmask_ref, x_ref, y_ref, *,
                 max_deg: int, interpret: bool):
    nb = nbrs_ref[...]          # [TV, D] int32
    m = rmask_ref[...]          # [TV, 1] f32 row gate (1 active, 0 masked)
    w = w_ref[...] * m          # zero every slot of masked rows
    x = x_ref[...]              # [R, TF] full shard-local feature tile
    acc = jnp.zeros(y_ref.shape, jnp.float32)   # f32 accumulation
    for j in range(max_deg):    # static unroll over neighbor slots
        wj = w[:, j][:, None]   # [TV, 1]
        xi = x[nb[:, j]]        # [TV, TF] dense row gather
        prod = (wj * xi).astype(jnp.float32)
        if interpret:
            # Interpret mode inlines this body into the caller's XLA
            # computation, where the backend may contract ``acc + w*x``
            # into an FMA — skipping the product's rounding step —
            # depending on how the surrounding graph fuses, i.e. on
            # launch width and consumers.  Sliced-ELL parity needs every
            # launch width to round identically (DESIGN.md §7), so pin
            # the product behind a select: a select between mul and add
            # blocks FMA contraction and is bitwise-exact.  The
            # predicate must be runtime-derived or the compiler folds
            # the select away (and the FMA returns); ``w * 0 <= 0``
            # cannot be folded for runtime floats.  Finite weights —
            # already the kernel's contract for pad slots — make it
            # always true.  A compiled Mosaic kernel is an opaque unit
            # with uniform per-slot codegen and skips this.
            prod = jnp.where(wj * 0.0 <= 0.0, prod, 0.0)
        acc = acc + prod
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ell_spmv(nbrs: jax.Array, w: jax.Array, x: jax.Array,
             row_mask: jax.Array | None = None,
             interpret: bool = False) -> jax.Array:
    """y[v] = row_mask[v] * sum_j w[v, j] * x[nbrs[v, j]].

    nbrs:     [Nv, D] int32 (padded slots may point anywhere; w must be 0)
    w:        [Nv, D] float — finite values only: in interpret mode the
              FMA-blocking guard zeroes non-finite-weight slots instead
              of propagating them (a compiled Mosaic kernel propagates)
    x:        [R, F]  float (gather source; R >= max(nbrs)+1)
    row_mask: [Nv] bool/float or None — rows with a falsy mask yield 0
              (the engines' active-task mask; None means all rows on)
    returns y: [Nv, F]
    """
    nv, d = nbrs.shape
    r, f = x.shape
    tv = min(_TV, nv)
    tf = min(_TF, f)
    nv_pad = pl.cdiv(nv, tv) * tv
    f_pad = pl.cdiv(f, tf) * tf
    nbrs_p = jnp.zeros((nv_pad, d), nbrs.dtype).at[:nv].set(nbrs)
    w_p = jnp.zeros((nv_pad, d), w.dtype).at[:nv].set(w)
    x_p = jnp.zeros((r, f_pad), x.dtype).at[:, :f].set(x)
    if row_mask is None:
        rm_p = jnp.ones((nv_pad, 1), w.dtype)
    else:
        rm_p = jnp.zeros((nv_pad, 1), w.dtype).at[:nv, 0].set(
            row_mask.astype(w.dtype))

    grid = (nv_pad // tv, f_pad // tf)
    y = pl.pallas_call(
        functools.partial(_spmv_kernel, max_deg=d, interpret=interpret),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tv, d), lambda i, k: (i, 0)),
            pl.BlockSpec((tv, d), lambda i, k: (i, 0)),
            pl.BlockSpec((tv, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((r, tf), lambda i, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((tv, tf), lambda i, k: (i, k)),
        out_shape=jax.ShapeDtypeStruct((nv_pad, f_pad), x.dtype),
        interpret=interpret,
    )(nbrs_p, w_p, rm_p, x_p)
    return y[:nv, :f]


def ell_spmv_bucketed(nbrs_blocks, w_blocks, x: jax.Array,
                      row_masks=None, interpret: bool = False) -> jax.Array:
    """Sliced-ELL SpMV: one width-specialized launch per degree bucket.

    ``nbrs_blocks`` / ``w_blocks`` are per-bucket ``[Nv_b, W_b]`` arrays
    (a ``SlicedEll``'s blocks); ``row_masks`` optionally gates each
    bucket's rows (the engines' batch activation routed onto bucket rows
    via the OOB-sentinel scatter).  Each bucket gets its own
    ``pl.pallas_call`` whose static slot unroll is the bucket width
    ``W_b`` instead of the global ``max_deg`` — total compute is the
    sliced slot count ``sum_b Nv_b * W_b``, the whole point of the
    layout (DESIGN.md §7).  Per-row accumulation order equals the
    monolithic kernel's over the row's real slot prefix, and the
    monolithic layout's extra trailing slots all carry weight 0.0, so
    this computes the same *function* as a padded-width launch — to
    float tolerance only, NOT bitwise: excess-precision/FMA decisions
    vary with launch width.  Bitwise reproducibility holds between
    computations compiled at the *same* per-bucket shapes, which is
    how the executor pairs this entry with ``bucketed_dense_fold``
    (DESIGN.md §7).

    Returns ``y [sum_b Nv_b, F]`` in bucketed row order (concatenated
    blocks); callers translate through the ``SlicedEll`` permutation.
    """
    ys = []
    for b, (nb, w) in enumerate(zip(nbrs_blocks, w_blocks)):
        rm = None if row_masks is None else row_masks[b]
        if nb.shape[0] == 0:      # forced-size bucket empty on this shard
            ys.append(jnp.zeros((0, x.shape[1]), x.dtype))
            continue
        ys.append(ell_spmv(nb, w, x, row_mask=rm, interpret=interpret))
    return jnp.concatenate(ys, axis=0)


def ell_spmv_batched(nbrs: jax.Array, w: jax.Array, x: jax.Array,
                     row_mask: jax.Array | None = None,
                     interpret: bool = False) -> jax.Array:
    """Window-shaped SpMV: one ``[B, W]`` launch over a gathered scope.

    The batch-shaped dispatch path (DESIGN.md §8): instead of launching
    every bucket's ``[Nv_b, W_b]`` rows — ``O(sum_b Nv_b * W_b)`` work
    per dispatch regardless of how small the scheduler window is — the
    executor gathers the window's adjacency at its snapped bucket width
    ``W`` and launches once at ``[B, W]``, so per-dispatch compute is
    ``B * W``.  ``nbrs`` / ``w`` are the gathered window rows (pad
    slots: any index, weight exactly 0), ``x [R, F]`` the resident
    feature block, ``row_mask`` the window's selection gate.

    Deliberately delegates to the same launch as ``ell_spmv`` rather
    than growing a second kernel body: the dense fallback reduces the
    same window through ``ell_fold`` at the identical ``[B, W]`` shape,
    and bitwise dense-vs-kernel parity holds exactly because both paths
    run one compiled accumulation per shape (DESIGN.md §4, §7).  A
    separate kernel body would reintroduce the FMA-contraction drift
    the shared launch exists to pin down.
    """
    return ell_spmv(nbrs, w, x, row_mask=row_mask, interpret=interpret)


def segment_combine(y: jax.Array, seg_ids: jax.Array,
                    n_rows: int) -> jax.Array:
    """Hub-splitting stage 2 (DESIGN.md §10): sum virtual-row partials
    onto their owner rows, ``out[r] = sum_{v: seg_ids[v]==r} y[v]``.

    ``y`` is ``[n_virtual, ...]`` stage-1 partials (SpMV rows, or the
    ALS ``[n_virtual, d, d]`` normal-equation blocks — anything whose
    accumulation is linear in slots), ``seg_ids`` the owner map with
    the out-of-range ``n_rows`` sentinel on dummy/padding virtual rows,
    which ``mode="drop"`` discards.  One XLA scatter-add, deliberately
    *not* a Pallas kernel: the segment axis is tiny (``n_virtual`` is
    within 2x of ``n_rows``) and both dispatch paths — kernel and dense
    fallback — call this identical op on bitwise-equal stage-1 inputs,
    so same-shape bitwise parity is inherited for free (§10's parity
    argument).
    """
    out = jnp.zeros((n_rows,) + y.shape[1:], y.dtype)
    return out.at[seg_ids].add(y, mode="drop")


def ell_fold(w: jax.Array, vals: jax.Array,
             row_mask: jax.Array | None = None,
             interpret: bool = False) -> jax.Array:
    """y[b] = sum_j w[b, j] * vals[b, j]: the kernel's reduction applied
    to already-materialized scope values ``vals [B, D, F]``.

    Used by the dense-scope fallback of aggregator updates: reusing the
    kernel (with the identity gather ``idx[b, j] = b*D + j`` over the
    flattened values) guarantees the fallback's accumulation arithmetic
    is bit-identical to the fast path's, whatever the compiler does with
    multiply-add contraction.
    """
    b, d, f = vals.shape
    idx = (jnp.arange(b, dtype=jnp.int32)[:, None] * d
           + jnp.arange(d, dtype=jnp.int32)[None, :])
    return ell_spmv(idx, w, vals.reshape(b * d, f), row_mask=row_mask,
                    interpret=interpret)
