"""Pallas TPU kernel: sliding-window flash attention, decode step.

The serving hot loop for the long-context shapes (decode_32k, long_500k):
one new query token attends to the last ``window`` entries of a KV cache.
Flash-style online softmax over KV tiles keeps VMEM usage at one
[TK, dh] K tile + one [TK, dh] V tile per step regardless of window
length — the sub-quadratic serving path that lets full-attention
architectures run the long_500k shape (DESIGN.md §5).

Grid: (batch*heads, window tiles).  The running (max, denom, acc) state
lives in the output refs across the KV-tile grid axis (TPU grids are
sequential over the last axis, so carrying state is legal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TK = 512        # KV rows per tile


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, tk: int, scale: float):
    t = pl.program_id(1)
    q = q_ref[...]              # [1, dh]
    k = k_ref[0]                # [TK, dh]  (block carries a leading 1)
    v = v_ref[0]                # [TK, dh]
    kv_len = len_ref[0]         # valid cache length for this row

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = t * tk + jax.lax.iota(jnp.int32, tk)
    mask = pos < kv_len
    s = (q @ k.T) * scale                        # [1, TK]
    s = jnp.where(mask[None, :], s, -jnp.inf)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask[None, :], p, 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = alpha * l_prev + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur
    l_ref[...] = l_cur

    @pl.when(t == pl.num_programs(1) - 1)
    def _fini():
        o_ref[...] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_window_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                            kv_len: jax.Array,
                            interpret: bool = False) -> jax.Array:
    """q: [B, dh]; k/v: [B, W, dh]; kv_len: [B] valid lengths.

    Returns [B, dh].  B is batch*heads flattened; W the window capacity.
    """
    bh, dh = q.shape
    w = k.shape[1]
    tk = min(_TK, w)
    w_pad = pl.cdiv(w, tk) * tk
    if w_pad != w:
        zk = jnp.zeros((bh, w_pad - w, dh), k.dtype)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
    scale = 1.0 / (dh ** 0.5)
    grid = (bh, w_pad // tk)
    out, _, _, _ = pl.pallas_call(
        functools.partial(_decode_kernel, tk=tk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, dh), lambda b, t: (b, 0)),
            pl.BlockSpec((1, tk, dh), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, tk, dh), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1,), lambda b, t: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, dh), lambda b, t: (b, 0)),
            pl.BlockSpec((1,), lambda b, t: (b,)),
            pl.BlockSpec((1,), lambda b, t: (b,)),
            pl.BlockSpec((1, dh), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh,), jnp.float32),
            jax.ShapeDtypeStruct((bh,), jnp.float32),
            jax.ShapeDtypeStruct((bh, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), k, v, kv_len.astype(jnp.int32))
    return out
