"""Pallas TPU kernel: batched ALS normal-equation accumulation.

The O(d^3 + deg) ALS update (paper §5.1) splits into a deg-bound
accumulation (this kernel) and a d^3 solve (LAPACK / jnp.linalg.solve
outside).  Per vertex v with neighbor factors X_j = x[nbrs[v, j]]:

    A[v] = sum_j m[v,j] * X_j X_j^T        [d, d]
    b[v] = sum_j m[v,j] * r[v,j] * X_j     [d]

Tiling mirrors ell_spmv: vertex row blocks on the grid, full shard-local
factor block x resident in VMEM, static unroll over neighbor slots; the
rank-1 accumulations are VPU outer products (d is small, 4-64 — the
paper's Fig. 5a sweeps exactly this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TV = 128


def _als_kernel(nbrs_ref, m_ref, r_ref, x_ref, a_ref, b_ref, *, max_deg: int):
    nb = nbrs_ref[...]          # [TV, D]
    m = m_ref[...]              # [TV, D]
    r = r_ref[...]              # [TV, D]
    x = x_ref[...]              # [R, d]
    d = x.shape[1]
    tv = nb.shape[0]
    a = jnp.zeros((tv, d, d), x.dtype)
    b = jnp.zeros((tv, d), x.dtype)
    for j in range(max_deg):
        xi = x[nb[:, j]]                         # [TV, d]
        xm = xi * m[:, j][:, None]
        a = a + xm[:, :, None] * xi[:, None, :]  # masked outer product
        b = b + xm * r[:, j][:, None]
    a_ref[...] = a
    b_ref[...] = b


def als_normal_eq_bucketed(nbrs_blocks, mask_blocks, ratings_blocks,
                           x: jax.Array, interpret: bool = False):
    """Sliced-ELL normal equations: one width-specialized launch per
    degree bucket (mirrors ``ell_spmv_bucketed``).  Blocks are the
    per-bucket ``[Nv_b, W_b]`` slices of neighbor ids / mask / per-slot
    ratings; each bucket's static slot unroll is its own width, so the
    accumulation work is the sliced slot count instead of
    ``Nv * max_deg``.  Returns ``(A [sum Nv_b, d, d], b [sum Nv_b, d])``
    in bucketed row order.

    Under hub splitting the blocks are *virtual-row* slices and this
    function needs no change: the A/b accumulations are linear in the
    occupied slots, so summing each hub's chunk partials with
    ``segment_combine(A, owner_of_vrow, n_rows)`` (and likewise for b)
    reproduces the unsplit row accumulation exactly — same adds in the
    same per-chunk order as an unsplit slot unroll.
    """
    d = x.shape[1]
    As, bs = [], []
    for nb, mk, rt in zip(nbrs_blocks, mask_blocks, ratings_blocks):
        if nb.shape[0] == 0:
            As.append(jnp.zeros((0, d, d), x.dtype))
            bs.append(jnp.zeros((0, d), x.dtype))
            continue
        a, b = als_normal_eq(nb, mk, rt, x, interpret=interpret)
        As.append(a)
        bs.append(b)
    return jnp.concatenate(As, axis=0), jnp.concatenate(bs, axis=0)


def als_normal_eq_batched(nbrs: jax.Array, mask: jax.Array,
                          ratings: jax.Array, x: jax.Array,
                          interpret: bool = False):
    """Window-shaped normal equations: one ``[B, W]`` launch over a
    gathered scope (mirrors ``ell_spmv_batched``).  For a small
    scheduler window the per-bucket launches of
    ``als_normal_eq_bucketed`` still accumulate every bucket row; this
    entry accumulates only the window's ``B * W`` slots.  Delegates to
    the shared launch so any same-shape fallback reduction compiles to
    the identical accumulation (DESIGN.md §8).
    """
    return als_normal_eq(nbrs, mask, ratings, x, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def als_normal_eq(nbrs: jax.Array, mask: jax.Array, ratings: jax.Array,
                  x: jax.Array, interpret: bool = False):
    """Returns (A [Nv, d, d], b [Nv, d]); caller adds ridge and solves."""
    nv, dd = nbrs.shape
    r_, d = x.shape
    tv = min(_TV, nv)
    nv_pad = pl.cdiv(nv, tv) * tv
    pad = lambda arr: jnp.zeros((nv_pad, dd), arr.dtype).at[:nv].set(arr)
    m = mask.astype(x.dtype)
    a, b = pl.pallas_call(
        functools.partial(_als_kernel, max_deg=dd),
        grid=(nv_pad // tv,),
        in_specs=[
            pl.BlockSpec((tv, dd), lambda i: (i, 0)),
            pl.BlockSpec((tv, dd), lambda i: (i, 0)),
            pl.BlockSpec((tv, dd), lambda i: (i, 0)),
            pl.BlockSpec((r_, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tv, d, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((tv, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nv_pad, d, d), x.dtype),
            jax.ShapeDtypeStruct((nv_pad, d), x.dtype),
        ],
        interpret=interpret,
    )(pad(nbrs), pad(m), pad(ratings), x)
    return a[:nv], b[:nv]
