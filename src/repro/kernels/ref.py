"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmv_ref(nbrs: jax.Array, w: jax.Array, x: jax.Array,
                 row_mask: jax.Array | None = None) -> jax.Array:
    """y[v] = row_mask[v] * sum_j w[v,j] * x[nbrs[v,j]]."""
    gathered = x[nbrs]                        # [Nv, D, F]
    y = (w[..., None] * gathered).sum(axis=1)
    if row_mask is not None:
        y = y * row_mask.astype(y.dtype)[:, None]
    return y


def als_normal_eq_ref(nbrs, mask, ratings, x):
    xg = x[nbrs]                              # [Nv, D, d]
    m = mask.astype(x.dtype)
    xm = xg * m[..., None]
    a = jnp.einsum("vdi,vdj->vij", xm, xg)
    b = jnp.einsum("vdi,vd->vi", xm, ratings)
    return a, b


def decode_window_attention_ref(q, k, v, kv_len):
    """q: [B, dh]; k/v: [B, W, dh]; kv_len: [B]."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bd,bwd->bw", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(k.shape[1])[None, :]
    s = jnp.where(pos < kv_len[:, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bw,bwd->bd", p, v.astype(jnp.float32))
