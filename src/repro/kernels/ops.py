"""Jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels run in ``interpret=True`` mode (Python
emulation of the kernel body — the validation path the brief prescribes);
on a TPU backend they compile to Mosaic.  ``use_pallas=False`` falls back
to the pure-jnp oracle, which is also what the distributed engines use
when shapes are too small to be worth a kernel launch.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.ell_spmv import ell_spmv as _ell_spmv_kernel
from repro.kernels.ell_spmv import ell_spmv_batched as _ell_spmv_batched
from repro.kernels.ell_spmv import ell_spmv_bucketed as _ell_spmv_bucketed
from repro.kernels.ell_spmv import segment_combine as _segment_combine
from repro.kernels.als_normal_eq import als_normal_eq as _als_kernel
from repro.kernels.als_normal_eq import (
    als_normal_eq_batched as _als_batched)
from repro.kernels.als_normal_eq import (
    als_normal_eq_bucketed as _als_bucketed)
from repro.kernels.window_attention import (
    decode_window_attention as _window_kernel)


def default_interpret() -> bool:
    """Interpret-mode off-TPU; Mosaic on a real TPU backend."""
    return jax.default_backend() != "tpu"


_interpret = default_interpret


def ell_spmv(nbrs, w, x, row_mask=None, use_pallas: bool = True):
    if not use_pallas:
        return ref.ell_spmv_ref(nbrs, w, x, row_mask)
    return _ell_spmv_kernel(nbrs, w, x, row_mask, interpret=_interpret())


def ell_spmv_bucketed(nbrs_blocks, w_blocks, x, row_masks=None):
    """Sliced-ELL SpMV: width-specialized launch per degree bucket."""
    return _ell_spmv_bucketed(nbrs_blocks, w_blocks, x,
                              row_masks=row_masks, interpret=_interpret())


def ell_spmv_batched(nbrs, w, x, row_mask=None):
    """Window-shaped SpMV: one [B, W] launch over a gathered scope."""
    return _ell_spmv_batched(nbrs, w, x, row_mask=row_mask,
                             interpret=_interpret())


def segment_combine(y, seg_ids, n_rows: int):
    """Hub-splitting stage 2: virtual-row partials -> owner rows
    (identical op on both dispatch paths; see kernels/ell_spmv.py)."""
    return _segment_combine(y, seg_ids, n_rows)


def als_normal_eq(nbrs, mask, ratings, x, use_pallas: bool = True):
    if not use_pallas:
        return ref.als_normal_eq_ref(nbrs, mask, ratings, x)
    return _als_kernel(nbrs, mask, ratings, x, interpret=_interpret())


def als_normal_eq_bucketed(nbrs_blocks, mask_blocks, ratings_blocks, x):
    """Sliced-ELL ALS accumulation: one launch per degree bucket."""
    return _als_bucketed(nbrs_blocks, mask_blocks, ratings_blocks, x,
                         interpret=_interpret())


def als_normal_eq_batched(nbrs, mask, ratings, x):
    """Window-shaped ALS accumulation: one [B, W] launch."""
    return _als_batched(nbrs, mask, ratings, x, interpret=_interpret())


def decode_window_attention(q, k, v, kv_len, use_pallas: bool = True):
    if not use_pallas:
        return ref.decode_window_attention_ref(q, k, v, kv_len)
    return _window_kernel(q, k, v, kv_len, interpret=_interpret())
