"""LM-substrate driver: train a ~20M-param reduced Qwen3-family model on
the synthetic pipeline for a few hundred steps (CPU-sized).  The full
assigned configs are exercised by the dry-run
(``python -m repro.launch.dryrun --all``); this proves the train loop,
optimizer, data pipeline and checkpointing end to end on real hardware.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses

from repro import configs
from repro.optim import adamw
from repro.train import trainer as trainer_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-4b")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=4, vocab=2048)
    pc = cfg.param_count()
    print(f"training reduced {cfg.name}: {pc['total'] / 1e6:.1f}M params")

    tcfg = trainer_lib.TrainerConfig(
        steps=args.steps, batch=8, seq_len=128, log_every=20,
        ckpt_path="results/lm_ckpt.npz",
        opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=20,
                              total_steps=args.steps))
    params, opt_state, history = trainer_lib.train(cfg, tcfg)
    first, last = history[0][1], history[-1][1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
