"""Dynamic PageRank: live graph mutations served by ``api.serve``
(DESIGN.md §13).

The paper's engines converge a *static* data graph; this example keeps
the engine alive while the graph changes underneath it.  ``api.serve``
stores the graph with slack slots so edge inserts land in-place (no
rebuild, no recompile), tracks the mutated scopes, and seeds only the
dirty 1-hop closure into the scheduler on the next ``recompute()`` —
the same adaptive-scheduling machinery the paper uses for convergence,
reused for incremental maintenance.

Reads are snapshot-isolated: a pinned ``GraphSnapshot`` keeps serving
the last converged state while mutations and the recompute proceed.

The final assertion is the honest contract for float workloads: the
incremental fixed point matches a from-scratch rebuild up to the
eps-scaled tolerance of the adaptive threshold (int workloads like
connected components match bitwise — see tests/test_serve.py).

    PYTHONPATH=src python examples/dynamic_pagerank.py
"""
import numpy as np

from repro import api
from repro.apps import pagerank
from repro.core.graph import zipf_edges


def main() -> None:
    n = 150
    edges = zipf_edges(n, seed=7)
    graph, update, syncs = pagerank.build(edges, n, slack=4)
    serving = api.serve(graph, update, syncs=syncs, scheduler="chromatic",
                        slack=4)
    r = serving.recompute()
    print(f"serving {n} vertices, {len(edges)} edges "
          f"(capacity {serving.graph.edge_capacity}); initial converge: "
          f"{r['supersteps']} supersteps")

    # pin a snapshot, then mutate: reads below never see partial state
    snap = serving.snapshot()

    new_edges = np.asarray([[3, 77], [5, 90], [11, 42]], np.int64)
    serving.add_edges(new_edges,
                      {"w": np.zeros(len(new_edges), np.float32)})
    # this app's edge weights depend on endpoint degrees -> refresh the
    # incident ones (the engine dirties their scopes automatically)
    eids, vals = pagerank.refreshed_weights(serving,
                                            np.unique(new_edges.ravel()))
    serving.update_edge_data(eids, vals)

    r = serving.recompute()
    print(f"after +{len(new_edges)} edges: dirty scope {r['dirty']} of "
          f"{n} vertices, re-converged in {r['supersteps']} supersteps, "
          f"{r['updates']} update calls")

    # the pre-mutation snapshot still serves the old fixed point
    old = np.asarray(snap.read_vertex(np.arange(n), "rank"))
    new = np.asarray(serving.snapshot().read_vertex(np.arange(n), "rank"))
    moved = int(np.sum(np.abs(new - old) > 1e-3))
    ids, vals = serving.snapshot().top_k("rank", 3)
    print(f"snapshot isolation: pinned snapshot unchanged, "
          f"{moved} ranks moved in the new one; top-3: "
          + ", ".join(f"v{int(i)}={float(v):.3f}"
                      for i, v in zip(ids, vals)))

    # equivalence: full rebuild + from-scratch converge, same fixed
    # point up to the eps-adaptive tolerance
    all_edges = np.vstack([edges, new_edges])
    g2, u2, s2 = pagerank.build(all_edges, n)
    res = api.run(g2, u2, syncs=s2, scheduler="chromatic",
                  max_supersteps=2000)
    diff = float(np.abs(new - np.asarray(res.vertex_data["rank"])).max())
    print(f"incremental vs full rebuild: max |diff| = {diff:.2e}")
    assert diff < 5e-3, diff
    print("OK")


if __name__ == "__main__":
    main()
