"""End-to-end driver: the paper's Netflix experiment (§5.1) in miniature.

Full pipeline: synthetic ratings -> bipartite data graph -> two-phase
partitioning -> distributed chromatic engine (if >1 device) or
single-shard engine -> RMSE sync monitoring -> consistent snapshot
checkpoint -> comparison against the Hadoop-style and MPI-style
baselines on identical hardware.

    PYTHONPATH=src python examples/netflix_als.py
    # multi-device (the distributed engine path):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/netflix_als.py
"""
import os
import time

import jax
import numpy as np

from repro.apps import als
from repro.baselines.mapreduce import als_mapreduce
from repro.baselines.mpi_als import als_mpi
from repro.core import (ChromaticEngine, DistributedChromaticEngine,
                        ShardPlan, random_partition)
from repro.train import checkpoint as ckpt

D = 8
SWEEPS = 20


def main() -> None:
    prob = als.synthetic_netflix(n_users=300, n_movies=200, d=D,
                                 density=0.06, noise=0.08, seed=0)
    g = prob.graph
    print(f"Netflix-style problem: {prob.n_users} users x "
          f"{prob.n_movies} movies, {g.n_edges} ratings, d={D}")

    upd = als.make_update(D, lam=0.05, eps=1e-3)
    syncs = [als.rmse_sync()]

    n_dev = len(jax.devices())
    t0 = time.time()
    if n_dev > 1:
        # the paper's §5.1 setup: dense bipartite graph -> random partition
        asg = random_partition(g.n_vertices, n_dev, seed=1)
        plan = ShardPlan.build(g, asg, n_dev)
        ghost_rows = int(np.asarray(plan.send_mask).sum())
        print(f"distributed on {n_dev} shards: "
              f"{ghost_rows} ghost rows/superstep")
        eng = DistributedChromaticEngine(g, plan, upd, syncs=syncs,
                                         max_supersteps=SWEEPS)
        out = eng.run()
        vdata, globals_ = out["vertex_data"], out["globals"]
        n_updates, steps = out["n_updates"], out["supersteps"]
    else:
        eng = ChromaticEngine(g, upd, syncs=syncs, max_supersteps=SWEEPS)
        st = eng.run()
        vdata, globals_ = st.vertex_data, st.globals
        n_updates, steps = int(st.n_updates), int(st.superstep)
    t_gl = time.time() - t0
    rmse = als.dataset_rmse(prob, vdata)
    print(f"GraphLab ALS: {steps} supersteps, {n_updates} updates, "
          f"{t_gl:.2f}s | sync RMSE {float(globals_['rmse']):.4f} "
          f"(exact {rmse:.4f}, noise floor ~{prob.noise})")

    ckpt.save("results/netflix_factors.npz", vdata, step=steps)
    print("checkpoint written to results/netflix_factors.npz")

    # --- baselines (paper §6.2) ---
    t0 = time.time()
    out_mr, stats = als_mapreduce(prob, SWEEPS, lam=0.05)
    t_mr = time.time() - t0
    w = np.concatenate([np.asarray(out_mr["w_users"]),
                        np.asarray(out_mr["w_movies"])])
    print(f"Hadoop-style ALS: {t_mr:.2f}s | RMSE "
          f"{als.dataset_rmse(prob, {'w': w}):.4f} | shuffles "
          f"{stats.bytes_shuffled_per_iter / 1e6:.1f} MB/iter")

    t0 = time.time()
    wU, wV, info = als_mpi(prob, SWEEPS, lam=0.05)
    t_mpi = time.time() - t0
    print(f"MPI-style ALS: {t_mpi:.2f}s | RMSE "
          f"{als.dataset_rmse(prob, {'w': np.concatenate([wU, wV])}):.4f}")


if __name__ == "__main__":
    main()
