"""End-to-end driver: the paper's Netflix experiment (§5.1) in miniature.

Full pipeline: synthetic ratings -> bipartite data graph -> the
``repro.api`` facade choosing single-shard vs distributed chromatic
execution from ``n_shards=`` (engine classes never appear) -> RMSE sync
monitoring -> consistent snapshot checkpoint -> comparison against the
Hadoop-style and MPI-style baselines on identical hardware.

    PYTHONPATH=src python examples/netflix_als.py
    # multi-device (the distributed engine path):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/netflix_als.py
"""
import time

import jax
import numpy as np

from repro import api
from repro.apps import als
from repro.baselines.mapreduce import als_mapreduce
from repro.baselines.mpi_als import als_mpi
from repro.core import random_partition
from repro.train import checkpoint as ckpt

D = 8
SWEEPS = 20


def main() -> None:
    prob = als.synthetic_netflix(n_users=300, n_movies=200, d=D,
                                 density=0.06, noise=0.08, seed=0)
    g, upd, syncs = als.build(prob, lam=0.05, eps=1e-3)
    print(f"Netflix-style problem: {prob.n_users} users x "
          f"{prob.n_movies} movies, {g.n_edges} ratings, d={D}")

    n_dev = len(jax.devices())
    t0 = time.time()
    if n_dev > 1:
        # the paper's §5.1 setup: dense bipartite graph -> random partition
        out = api.run(g, upd, syncs=syncs, scheduler="chromatic",
                      n_shards=n_dev,
                      partition=random_partition(g.n_vertices, n_dev, seed=1),
                      max_supersteps=SWEEPS)
        ghost_rows = int(np.asarray(out.engine.plan.send_mask).sum())
        print(f"distributed on {n_dev} shards: "
              f"{ghost_rows} ghost rows/superstep")
    else:
        out = api.run(g, upd, syncs=syncs, scheduler="chromatic",
                      max_supersteps=SWEEPS)
    t_gl = time.time() - t0
    rmse = als.dataset_rmse(prob, out.vertex_data)
    print(f"GraphLab ALS: {out.superstep} supersteps, {out.n_updates} "
          f"updates, {t_gl:.2f}s | sync RMSE {float(out.globals['rmse']):.4f} "
          f"(exact {rmse:.4f}, noise floor ~{prob.noise})")

    ckpt.save("results/netflix_factors.npz", out.vertex_data,
              step=out.superstep)
    print("checkpoint written to results/netflix_factors.npz")

    # --- baselines (paper §6.2) ---
    t0 = time.time()
    out_mr, stats = als_mapreduce(prob, SWEEPS, lam=0.05)
    t_mr = time.time() - t0
    w = np.concatenate([np.asarray(out_mr["w_users"]),
                        np.asarray(out_mr["w_movies"])])
    print(f"Hadoop-style ALS: {t_mr:.2f}s | RMSE "
          f"{als.dataset_rmse(prob, {'w': w}):.4f} | shuffles "
          f"{stats.bytes_shuffled_per_iter / 1e6:.1f} MB/iter")

    t0 = time.time()
    wU, wV, info = als_mpi(prob, SWEEPS, lam=0.05)
    t_mpi = time.time() - t0
    print(f"MPI-style ALS: {t_mpi:.2f}s | RMSE "
          f"{als.dataset_rmse(prob, {'w': np.concatenate([wU, wV])}):.4f}")


if __name__ == "__main__":
    main()
