"""Kill a distributed PageRank run mid-flight and watch it recover —
bitwise — from its latest sharded snapshot (DESIGN.md §12).

Three acts, all through the ``repro.api`` facade:

1. an uninterrupted run: the ground truth;
2. the same run with ``checkpoint_every=`` snapshots and an injected
   kill at the halfway superstep — the supervisor restores the newest
   valid snapshot, replays the remaining supersteps, and the result
   matches act 1 to the bit (the restart log on ``RunResult.restarts``
   shows what happened);
3. an explicit ``resume_from=`` of one of those snapshots, the
   operator path after a *real* crash: the partition layout is rebuilt
   from the snapshot's stored assignment, so no plan arguments need
   repeating.

    PYTHONPATH=src python examples/kill_resume.py
"""
import os

# two virtual CPU devices for the two-shard mesh; must be set before
# jax initializes (which the repro import below triggers)
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=2")

import tempfile

import numpy as np

from repro import api
from repro.apps import pagerank
from repro.core.graph import zipf_edges
from repro.ft import FaultEvent, FaultPlan, latest_valid_snapshot

N, STEPS, KILL_AT = 400, 12, 6


def main() -> None:
    edges = zipf_edges(N, seed=7)
    graph, update, syncs = pagerank.build(edges, N)
    part = np.arange(N, dtype=np.int64) % 2      # two shards

    # --- act 1: the unfaulted ground truth ---------------------------
    base = api.run(graph, update, syncs=syncs, scheduler="chromatic",
                   n_shards=2, partition=part, num_supersteps=STEPS)
    rank = np.asarray(base.vertex_data["rank"])
    print(f"ground truth: {base.superstep} supersteps, "
          f"{base.n_updates} updates")

    with tempfile.TemporaryDirectory() as ckpt:
        # --- act 2: checkpoint + injected kill + supervised restart --
        faults = FaultPlan([FaultEvent("kill", superstep=KILL_AT)])
        rec = api.run(graph, update, syncs=syncs, scheduler="chromatic",
                      n_shards=2, partition=part, num_supersteps=STEPS,
                      checkpoint_every=2, checkpoint_dir=ckpt,
                      faults=faults)
        for r in rec.restarts:
            print(f"restart {r.attempt}: {r.error_type} "
                  f"({r.error}) -> restored superstep "
                  f"{r.restored_superstep}, backoff {r.backoff_s:.2f}s")
        same = np.array_equal(rank, np.asarray(rec.vertex_data["rank"]))
        print(f"recovered run bitwise-equal to ground truth: {same}")
        assert same

        # --- act 3: operator-style resume_from after a "crash" -------
        assert latest_valid_snapshot(ckpt) is not None
        snap = os.path.join(ckpt, f"step_{KILL_AT:08d}")   # mid-run one
        print(f"resuming from {os.path.basename(snap)} "
              "(partition rebuilt from the snapshot)")
        res = api.run(graph, update, syncs=syncs, scheduler="chromatic",
                      n_shards=2, resume_from=snap,
                      num_supersteps=STEPS)
        same = np.array_equal(rank, np.asarray(res.vertex_data["rank"]))
        print(f"resumed run bitwise-equal to ground truth: {same}")
        assert same


if __name__ == "__main__":
    main()
