"""Quickstart: the paper's running example (PageRank, Ex. 3.1 + §3.3)
through the one paper-shaped entry point, ``repro.api``.

GraphLab's programming surface is four objects (§3): a **data graph**,
an **update function**, **sync operations**, and an engine chosen by
*configuration* — the C++ API's ``set_scheduler_type`` / ``start()``.
The repo mirrors that exactly:

    graph, update, syncs = pagerank.build(edges, n)    # the data-graph
    result = api.run(graph, update, syncs=syncs,       # ... start()
                     scheduler="chromatic")            # set_scheduler_type

``scheduler=`` picks any registered strategy ("chromatic", "priority",
"bsp", "locking", or the "sequential" Def.-3.1 oracle — see
``api.list_schedulers()``); ``n_shards=`` switches to the shard_map
engines; ``until=`` terminates on a predicate over the sync results
(termination-by-sync).  Every run returns the same ``RunResult``.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import api
from repro.apps import pagerank


def main() -> None:
    rng = np.random.default_rng(0)
    n = 200
    # preferential-attachment-ish web graph
    edges = set()
    for v in range(1, n):
        for _ in range(rng.integers(1, 4)):
            u = int(rng.integers(0, v))
            edges.add((u, v))
    edges = np.asarray(sorted(edges))

    graph, update, syncs = pagerank.build(edges, n, eps=1e-5)
    print(f"data graph: {n} vertices, {len(edges)} edges, "
          f"{graph.n_colors} colors | schedulers: "
          f"{', '.join(api.list_schedulers())}")

    result = api.run(graph, update, syncs=syncs, scheduler="chromatic",
                     max_supersteps=100)

    ranks = np.asarray(result.vertex_data["rank"])
    top = np.argsort(-ranks)[:5]
    print(f"converged in {result.superstep} supersteps, "
          f"{result.n_updates} update-function calls "
          f"(adaptive: {result.n_updates / (result.superstep * n):.0%} "
          f"of a full-sweep schedule)")
    print("top pages:", [(int(v), round(float(ranks[v]), 3)) for v in top])
    second_rank, _ = result.globals["top2"]
    print(f"sync op 'second most popular page': rank={float(second_rank):.3f}"
          f" (oracle: {sorted(ranks)[-2]:.3f})")
    print(f"sync op 'total rank': {float(result.globals['total_rank']):.2f}")

    # the same program under a different strategy is one string away;
    # until= stops as soon as the total-rank sync stabilizes near its
    # fixed point (termination-by-sync, §3.3)
    target = float(result.globals["total_rank"])
    early = api.run(graph, update, syncs=syncs, scheduler="priority",
                    k_select=64, max_supersteps=5000,
                    until=lambda g: abs(float(g["total_rank"]) - target)
                    < 1e-3)
    print(f"priority engine, until |total_rank - fixed point| < 1e-3: "
          f"stopped after {early.superstep} supersteps, "
          f"{early.n_updates} updates")


if __name__ == "__main__":
    main()
