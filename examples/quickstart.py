"""Quickstart: the paper's running example (PageRank, Ex. 3.1 + §3.3).

Builds a small web graph, defines the Alg.-1 update function, attaches
the "second most popular page" sync, and runs the chromatic engine to
convergence.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.apps import pagerank
from repro.core import ChromaticEngine


def main() -> None:
    rng = np.random.default_rng(0)
    n = 200
    # preferential-attachment-ish web graph
    edges = set()
    for v in range(1, n):
        for _ in range(rng.integers(1, 4)):
            u = int(rng.integers(0, v))
            edges.add((u, v))
    edges = np.asarray(sorted(edges))

    graph = pagerank.make_graph(edges, n)
    print(f"data graph: {n} vertices, {len(edges)} edges, "
          f"{graph.n_colors} colors")

    engine = ChromaticEngine(
        graph,
        pagerank.make_update(eps=1e-5),
        syncs=[pagerank.second_most_popular_sync(),
               pagerank.total_rank_sync()],
        max_supersteps=100,
    )
    state = engine.run()

    ranks = np.asarray(state.vertex_data["rank"])
    top = np.argsort(-ranks)[:5]
    print(f"converged in {int(state.superstep)} supersteps, "
          f"{int(state.n_updates)} update-function calls "
          f"(adaptive: {int(state.n_updates) / (int(state.superstep) * n):.0%} "
          f"of a full-sweep schedule)")
    print("top pages:", [(int(v), round(float(ranks[v]), 3)) for v in top])
    second_rank, _ = state.globals["top2"]
    print(f"sync op 'second most popular page': rank={float(second_rank):.3f}"
          f" (oracle: {sorted(ranks)[-2]:.3f})")
    print(f"sync op 'total rank': {float(state.globals['total_rank']):.2f}")


if __name__ == "__main__":
    main()
