"""CoSeg (paper §5.2): residual-prioritized LBP + GMM sync — the workload
that needs the Locking Engine (run here both as the PriorityEngine
analogue and as the real claim-pass LockingEngine, DESIGN.md §6).

Shows the paper's claims on one problem:
  1. adaptive prioritized scheduling does far fewer updates than fixed
     sweeps for the same segmentation quality;
  2. the GMM parameters stay fresh through the sync operation while the
     asynchronous-style LBP iteration runs;
  3. the locking engine reaches the same segmentation with a bounded
     lock pipeline (max_pending) and no reliance on the coloring.

    PYTHONPATH=src python examples/coseg_priority.py
"""
import time

import numpy as np

from repro.apps import lbp
from repro.core import ChromaticEngine, LockingEngine, PriorityEngine

K = 4          # labels
FEAT = 3


def main() -> None:
    prob = lbp.synthetic_coseg(n_frames=6, h=6, w=12, n_labels=K,
                               n_feat=FEAT, noise=0.55, seed=0)
    g = prob.graph
    nv = g.n_vertices
    base = float((np.asarray(g.vertex_data["unary"]).argmax(1)
                  == prob.true_labels).mean())
    print(f"CoSeg grid {prob.shape}: {nv} super-pixels, {g.n_edges} edges, "
          f"{g.n_colors} colors | unary-only accuracy {base:.3f}")

    upd = lbp.make_update(K, beta=0.6, eps=5e-3)
    syncs = [lbp.gmm_sync(K, FEAT, tau=2)]

    t0 = time.time()
    chrom = ChromaticEngine(g, upd, syncs=syncs, max_supersteps=40).run()
    t_c = time.time() - t0
    acc_c = lbp.label_accuracy(prob, chrom.vertex_data)
    print(f"chromatic (fixed sweeps): {int(chrom.superstep)} supersteps, "
          f"{int(chrom.n_updates)} updates, {t_c:.2f}s, acc {acc_c:.3f}")

    t0 = time.time()
    prio = PriorityEngine(g, upd, syncs=syncs, k_select=64,
                          max_supersteps=20000).run()
    t_p = time.time() - t0
    acc_p = lbp.label_accuracy(prob, prio.vertex_data)
    print(f"priority (locking-engine analogue, k=64): "
          f"{int(prio.superstep)} supersteps, {int(prio.n_updates)} updates,"
          f" {t_p:.2f}s, acc {acc_p:.3f}")
    t0 = time.time()
    lst = LockingEngine(g, upd, syncs=syncs, max_pending=64,
                        max_supersteps=20000).run()
    t_l = time.time() - t0
    acc_l = lbp.label_accuracy(prob, lst.vertex_data)
    print(f"locking (claim pass, max_pending=64): "
          f"{int(lst.superstep)} supersteps, {int(lst.n_updates)} updates,"
          f" {t_l:.2f}s, acc {acc_l:.3f}")

    # the engines are adaptive; compare against the non-adaptive
    # full-sweep schedule each would otherwise execute
    sweeps_c = int(chrom.superstep) * nv
    print(f"adaptive savings vs full sweeps: chromatic "
          f"{1 - int(chrom.n_updates) / sweeps_c:.0%}, priority engine "
          f"processes the top-k residuals first (residual-BP order [27])")
    print("GMM centroids (sync):")
    print(np.asarray(prio.globals["gmm"]).round(2))


if __name__ == "__main__":
    main()
