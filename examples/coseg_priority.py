"""CoSeg (paper §5.2): residual-prioritized LBP + GMM sync — the workload
that needs the Locking Engine, run through the ``repro.api`` facade as
three scheduler strings over one identical program (DESIGN.md §9).

Shows the paper's claims on one problem:
  1. adaptive prioritized scheduling does far fewer updates than fixed
     sweeps for the same segmentation quality;
  2. the GMM parameters stay fresh through the sync operation while the
     asynchronous-style LBP iteration runs;
  3. the locking engine reaches the same segmentation with a bounded
     lock pipeline (max_pending) and no reliance on the coloring.

    PYTHONPATH=src python examples/coseg_priority.py
"""
import time

import numpy as np

from repro import api
from repro.apps import lbp

K = 4          # labels
FEAT = 3


def main() -> None:
    prob = lbp.synthetic_coseg(n_frames=6, h=6, w=12, n_labels=K,
                               n_feat=FEAT, noise=0.55, seed=0)
    g, upd, syncs = lbp.build(prob, beta=0.6, eps=5e-3, tau=2)
    nv = g.n_vertices
    base = float((np.asarray(g.vertex_data["unary"]).argmax(1)
                  == prob.true_labels).mean())
    print(f"CoSeg grid {prob.shape}: {nv} super-pixels, {g.n_edges} edges, "
          f"{g.n_colors} colors | unary-only accuracy {base:.3f}")

    t0 = time.time()
    chrom = api.run(g, upd, syncs=syncs, scheduler="chromatic",
                    max_supersteps=40)
    t_c = time.time() - t0
    acc_c = lbp.label_accuracy(prob, chrom.vertex_data)
    print(f"chromatic (fixed sweeps): {chrom.superstep} supersteps, "
          f"{chrom.n_updates} updates, {t_c:.2f}s, acc {acc_c:.3f}")

    t0 = time.time()
    prio = api.run(g, upd, syncs=syncs, scheduler="priority", k_select=64,
                   max_supersteps=20000)
    t_p = time.time() - t0
    acc_p = lbp.label_accuracy(prob, prio.vertex_data)
    print(f"priority (locking-engine analogue, k=64): "
          f"{prio.superstep} supersteps, {prio.n_updates} updates,"
          f" {t_p:.2f}s, acc {acc_p:.3f}")

    t0 = time.time()
    lst = api.run(g, upd, syncs=syncs, scheduler="locking", max_pending=64,
                  max_supersteps=20000)
    t_l = time.time() - t0
    acc_l = lbp.label_accuracy(prob, lst.vertex_data)
    print(f"locking (claim pass, max_pending=64): "
          f"{lst.superstep} supersteps, {lst.n_updates} updates,"
          f" {t_l:.2f}s, acc {acc_l:.3f}")

    # the engines are adaptive; compare against the non-adaptive
    # full-sweep schedule each would otherwise execute
    sweeps_c = chrom.superstep * nv
    print(f"adaptive savings vs full sweeps: chromatic "
          f"{1 - chrom.n_updates / sweeps_c:.0%}, priority engine "
          f"processes the top-k residuals first (residual-BP order [27])")
    print("GMM centroids (sync):")
    print(np.asarray(prio.globals["gmm"]).round(2))


if __name__ == "__main__":
    main()
