"""Paper Fig. 1: consistent (chromatic) vs inconsistent (BSP/Jacobi)
asynchronous ALS — prediction error after equal sweep budgets.

The paper's claim: "Consistent iterations converge rapidly to a lower
error while inconsistent iterations oscillate and converge slowly."
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro import api
from repro.apps import als


def run() -> None:
    sweeps = 12
    rmse = {}
    for mode, scheduler in (("consistent", "chromatic"),
                            ("inconsistent", "bsp")):
        prob = als.synthetic_netflix(60, 50, d=6, density=0.25,
                                     noise=0.05, seed=7)
        upd = als.make_update(6, lam=0.05, eps=0.0)
        eng = api.build_engine(prob.graph, upd, scheduler=scheduler,
                               max_supersteps=sweeps)
        us = time_fn(lambda: eng.run(num_supersteps=sweeps), iters=1)
        st = eng.run(num_supersteps=sweeps)
        err = als.dataset_rmse(prob, st.vertex_data)
        rmse[mode] = err
        emit(f"fig1_als_{mode}", us / sweeps, f"rmse={err:.4f}")
    emit("fig1_gap", 0.0,
         f"consistent_better={rmse['consistent'] <= rmse['inconsistent']}")
