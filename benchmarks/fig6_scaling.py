"""Paper Fig. 6(a,b): scaling + per-node communication for the three
applications (Netflix/ALS, CoSeg/LBP, NER/CoEM).

This container is one CPU, so wall-clock multi-node speedup cannot be
measured; we report what the paper's figures are made of:
  (a) engine update throughput (updates/us on this host) and
  (b) the per-shard ghost-exchange volume per superstep for shard counts
      4..64, computed exactly from the static ShardPlan communication
      schedule (what each EC2 node would put on the wire).
NER is the bandwidth-bound outlier in the paper (816-byte vertex data,
random cut); the same ordering falls out of the plan volumes here.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro import api
from repro.apps import als, coem, lbp
from repro.core import ShardPlan, random_partition, two_phase_partition


def _apps():
    als_prob = als.synthetic_netflix(150, 120, d=8, density=0.08, seed=0)
    coem_prob = coem.synthetic_ner(300, 200, 5, mean_deg=6, seed=0)
    coseg_prob = lbp.synthetic_coseg(6, 5, 10, n_labels=4, noise=0.5)
    return {
        "netflix": (als_prob.graph, als.make_update(8, eps=1e-3),
                    8 * 4, "random"),
        "ner": (coem_prob.graph, coem.make_update(1e-3),
                5 * 4, "random"),
        "coseg": (coseg_prob.graph, lbp.make_update(4, eps=1e-2),
                  4 * 4 * 2, "frames"),
    }


def run() -> None:
    apps = _apps()
    # (a) update throughput on this host
    for name, (g, upd, vbytes, _part) in apps.items():
        eng = api.build_engine(g, upd, max_supersteps=5)
        us = time_fn(lambda e=eng: e.run(num_supersteps=5), iters=2)
        st = eng.run(num_supersteps=5)
        n_upd = max(int(st.n_updates), 1)
        emit(f"fig6a_{name}_throughput", us / n_upd,
             f"updates={n_upd};verts={g.n_vertices}")
    # (b) ghost bytes per shard per superstep vs cluster size
    for name, (g, upd, vbytes, part) in apps.items():
        for m in (4, 8, 16, 32, 64):
            if part == "random":
                asg = random_partition(g.n_vertices, m, seed=1)
            else:
                asg = two_phase_partition(g.n_vertices, g.edges_np, m,
                                          seed=1)
            plan = ShardPlan.build(g, asg, m)
            ghost_rows = int(np.asarray(plan.send_mask).sum())
            per_node = ghost_rows * vbytes / m
            emit(f"fig6b_{name}_m{m}", 0.0,
                 f"ghost_bytes_per_node_per_step={per_node:.0f}")
