"""Render the §Roofline table from dry-run JSONL results (if present)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = [
    ("results/dryrun_16x16.jsonl", "16x16"),
    ("results/dryrun_2x16x16.jsonl", "2x16x16"),
]


def run() -> None:
    found = False
    for path, mesh in RESULTS:
        if not os.path.exists(path):
            continue
        found = True
        ok = err = 0
        with open(path) as f:
            for line in f:
                row = json.loads(line)
                if "error" in row:
                    err += 1
                    emit(f"roofline_{mesh}_{row['name']}", 0.0, "ERROR")
                    continue
                ok += 1
                emit(
                    f"roofline_{mesh}_{row['name']}", 0.0,
                    f"Tc={row['t_compute_s']:.3e};"
                    f"Tm={row['t_memory_s']:.3e};"
                    f"Tx={row['t_collective_s']:.3e};"
                    f"bottleneck={row['bottleneck']};"
                    f"useful={row['usefulness']:.2f}")
        emit(f"roofline_{mesh}_summary", 0.0, f"ok={ok};errors={err}")
    if not found:
        emit("roofline_table", 0.0,
             "no dry-run results yet (python -m repro.launch.dryrun --all)")
