"""Online serving: incremental dirty-scope recompute vs full rebuild.

The serving subsystem's headline number (DESIGN.md §13): after a small
batch of edge inserts on a Zipf graph, re-converging the connected-
components labels incrementally (slack-slot insert + dirty-closure
seeding on the live engine) vs the no-serving alternative — rebuild the
``DataGraph`` from scratch and converge a fresh engine.  CC's int32
min-label semilattice has one fixed point, so every batch is **gated
bitwise** before its timing is recorded: a speedup over a wrong answer
is not a speedup.

Appends ``results/BENCH_serve.json``; wired into ``benchmarks.run
--smoke`` (CI artifact job).
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro import api
from repro.apps import cc
from repro.core.graph import zipf_edges

_RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"

# acceptance floor: incremental recompute must beat rebuild+reconverge
# by this factor on every small-batch round
MIN_SPEEDUP = 5.0


def _fresh_edges(rng, nv, existing: set, k: int) -> np.ndarray:
    out = []
    while len(out) < k:
        u, v = int(rng.integers(0, nv)), int(rng.integers(0, nv))
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key in existing:
            continue
        existing.add(key)
        out.append(key)
    return np.asarray(out, np.int64)


def run() -> None:
    nv = 1_000 if common.SMOKE else 10_000
    n_batches = 3 if common.SMOKE else 5
    batch_k = 8
    run_kw = {"scheduler": "locking", "dispatch": "batch",
              "max_pending": 64, "max_supersteps": 20_000}

    rng = np.random.default_rng(0)
    edges = zipf_edges(nv, seed=0)
    existing = {(min(u, v), max(u, v)) for u, v in edges}

    graph, update, _ = cc.build(edges, nv, slack=4)
    serving = api.serve(graph, update, slack=4, **run_kw)
    t0 = time.perf_counter()
    r0 = serving.recompute()
    emit("serve_initial_converge", (time.perf_counter() - t0) * 1e6,
         f"nv={nv} supersteps={r0['supersteps']}")

    # warm the dirty-seeded recompute path (the first incremental
    # round traces the masked init + the k-shaped insert scatter once)
    # so the timed batches measure steady-state serving, like
    # time_fn's warmup
    warm = _fresh_edges(rng, nv, existing, batch_k)
    t0 = time.perf_counter()
    serving.add_edges(warm)
    r = serving.recompute()
    emit("serve_warmup_batch", (time.perf_counter() - t0) * 1e6,
         f"dirty={r['dirty']} supersteps={r['supersteps']}")

    record = {"n_vertices": nv, "n_edges_base": int(len(edges)),
              "batch_k": batch_k, "scheduler": "locking",
              "batches": []}
    all_edges = np.vstack([edges, warm])
    speedups = []
    for t in range(n_batches):
        batch = _fresh_edges(rng, nv, existing, batch_k)
        all_edges = np.vstack([all_edges, batch])

        t0 = time.perf_counter()
        serving.add_edges(batch)
        r = serving.recompute()
        incr_s = time.perf_counter() - t0
        inc = np.asarray(serving.graph.vertex_data["label"])

        # the alternative: rebuild storage + coloring + fresh engine,
        # converge from scratch (recompiles — that is the real cost)
        t0 = time.perf_counter()
        g2, u2, _ = cc.build(all_edges, nv)
        res = api.run(g2, u2, **run_kw)
        full_s = time.perf_counter() - t0
        ref = np.asarray(res.vertex_data["label"])

        # bitwise gate before the timing is recorded
        assert np.array_equal(inc, ref), \
            f"batch {t}: incremental labels diverged from rebuild"
        speedup = full_s / incr_s
        speedups.append(speedup)
        emit(f"serve_incr_batch{t}", incr_s * 1e6,
             f"dirty={r['dirty']} supersteps={r['supersteps']} "
             f"vs_full={speedup:.1f}x")
        emit(f"serve_full_batch{t}", full_s * 1e6,
             f"supersteps={res.superstep}")
        record["batches"].append(
            {"k": batch_k, "dirty": int(r["dirty"]),
             "supersteps_incr": int(r["supersteps"]),
             "supersteps_full": int(res.superstep),
             "incr_s": incr_s, "full_s": full_s,
             "speedup": speedup, "bitwise_equal": True})

    record["speedup_min"] = min(speedups)
    record["speedup_mean"] = float(np.mean(speedups))
    assert min(speedups) >= MIN_SPEEDUP, \
        f"incremental speedup {min(speedups):.1f}x below {MIN_SPEEDUP}x"

    _RESULTS.mkdir(exist_ok=True)
    out_path = _RESULTS / "BENCH_serve.json"
    hist = json.loads(out_path.read_text()) if out_path.exists() else []
    hist.append(record)
    out_path.write_text(json.dumps(hist, indent=1))
