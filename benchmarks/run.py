"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.
  fig1   consistent vs inconsistent ALS (paper Fig. 1)
  fig6ab scaling + per-node communication (Fig. 6a/6b)
  fig6cd IPB sweep + GraphLab/Hadoop/MPI comparison (Fig. 6c/6d, 7a)
  fig8   weak scaling + maxpending/k_select sweep (Fig. 8a/8b)
  kernels Pallas kernels vs jnp oracle
  roofline dry-run roofline table (per arch x shape x mesh)
"""
import sys


def main() -> None:
    from benchmarks import (fig1_consistency, fig6_scaling,
                            fig6cd_comparison, fig8_locking, kernels_bench,
                            roofline_table)
    only = sys.argv[1] if len(sys.argv) > 1 else None
    mods = {
        "fig1": fig1_consistency, "fig6ab": fig6_scaling,
        "fig6cd": fig6cd_comparison, "fig8": fig8_locking,
        "kernels": kernels_bench, "roofline": roofline_table,
    }
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if only and name != only:
            continue
        mod.run()


if __name__ == "__main__":
    main()
