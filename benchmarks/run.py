"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.
  fig1   consistent vs inconsistent ALS (paper Fig. 1)
  fig6ab scaling + per-node communication (Fig. 6a/6b)
  fig6cd IPB sweep + GraphLab/Hadoop/MPI comparison (Fig. 6c/6d, 7a)
  fig8   weak scaling + lock-pipeline sweep: real max_pending
         (LockingEngine) side by side with the old k_select proxy
         (Fig. 8a/8b); appends results/BENCH_locking.json
  kernels Pallas kernels vs jnp oracle; appends results/BENCH_engines.json
  graph  padded vs sliced-ELL storage: slot counts, build time,
         PageRank sweep; appends results/BENCH_graph.json
  dispatch window size k x {bucket-row, batch, adaptive} dispatch
         sweep (DESIGN.md §8); appends results/BENCH_dispatch.json
  ft     snapshot overhead (checkpoint_every sweep) + kill-recovery
         wall time with a bitwise gate (DESIGN.md §12); appends
         results/BENCH_ft.json
  serve  online serving: incremental dirty-scope recompute vs full
         rebuild, bitwise-gated (DESIGN.md §13); appends
         results/BENCH_serve.json
  roofline dry-run roofline table (per arch x shape x mesh)

``--smoke`` runs tiny sizes (CI artifact job); without an explicit
module it restricts to the BENCH_*.json producers (fig8, kernels).
``--w-cap=16,32,64`` overrides the hub-splitting caps swept by the
graph / dispatch benchmarks.
"""
import sys


def main() -> None:
    from benchmarks import (common, dispatch_window, fault_tolerance,
                            fig1_consistency, fig6_scaling,
                            fig6cd_comparison, fig8_locking, graph_storage,
                            kernels_bench, roofline_table, serve_online)
    args = sys.argv[1:]
    common.SMOKE = "--smoke" in args
    args = [a for a in args if a != "--smoke"]
    for a in list(args):
        if a.startswith("--w-cap"):
            val = a.split("=", 1)[1] if "=" in a else args[args.index(a) + 1]
            common.W_CAPS = [int(v) for v in val.split(",")]
            args.remove(a)
            if "=" not in a:
                args.remove(val)
    only = args[0] if args else None
    mods = {
        "fig1": fig1_consistency, "fig6ab": fig6_scaling,
        "fig6cd": fig6cd_comparison, "fig8": fig8_locking,
        "kernels": kernels_bench, "graph": graph_storage,
        "dispatch": dispatch_window, "ft": fault_tolerance,
        "serve": serve_online,
        "roofline": roofline_table,
    }
    if only is None and common.SMOKE:
        # the BENCH_*.json producers
        selected = ["fig8", "kernels", "graph", "dispatch", "ft", "serve"]
    else:
        selected = [only] if only else list(mods)
    print("name,us_per_call,derived")
    for name in selected:
        mods[name].run()


if __name__ == "__main__":
    main()
