"""Fault tolerance, measured (DESIGN.md §12): what does a snapshot
cost, and how fast does a killed run recover?

Two sweeps over a PageRank run on the Zipf graph (M=1 degenerate plan
so the sharded snapshot path is exercised on one device):

* **checkpoint_every sweep** — wall time of the checkpointed run at
  K ∈ {1, 2, 5, 10} vs the no-checkpoint baseline, reported as
  overhead per snapshot and as a fraction of the baseline.  The
  snapshot itself is also timed in isolation (``write_snapshot`` +
  ``validate_snapshot`` round).
* **recovery** — an injected kill mid-run under the supervisor:
  wall time of the recovered run vs the unfaulted one, with the
  bitwise-equality gate enforced at record time (a fast recovery that
  computes different numbers is a bug, not a result).

Appends ``results/BENCH_ft.json``; wired into ``benchmarks.run
--smoke`` for the CI artifact job (tiny sizes).
"""
from __future__ import annotations

import json
import pathlib
import tempfile
import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro import api
from repro.apps import pagerank
from repro.core.graph import zipf_edges
from repro.ft import (FaultEvent, FaultPlan, latest_valid_snapshot,
                      validate_snapshot, write_snapshot)

_RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def _problem():
    nv = 300 if common.SMOKE else 3000
    edges = zipf_edges(nv, seed=0)
    return pagerank.build(edges, nv)


def _wall_s(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run() -> None:
    from repro.ft import runner as ft_runner

    graph, update, syncs = _problem()
    assign = np.zeros(graph.n_vertices, np.int64)
    steps = 8 if common.SMOKE else 20
    # one engine for everything: its program cache persists, so the
    # sweep times snapshots, not recompilation
    eng = api.build_engine(graph, update, syncs=syncs, n_shards=1,
                           partition=assign, max_supersteps=steps)

    def drive(**kw):
        return ft_runner.run_distributed(eng, scheduler="chromatic", **kw)

    drive()                              # warm the chunked program
    base_s = _wall_s(drive)
    base, _ = drive()
    emit("ft_baseline", base_s * 1e6, f"steps={base['supersteps']}")

    record = {"n_vertices": graph.n_vertices, "supersteps": steps,
              "baseline_s": base_s, "checkpoint_sweep": [],
              "recovery": {}}

    # --- snapshot cost in isolation --------------------------------
    carry = eng.step_chunk(eng.init_carry(), 2)
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        p = write_snapshot(d, carry, scheduler="chromatic",
                           partition=eng.plan.partition_fingerprint,
                           assignment=eng.plan.assignment)
        write_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        validate_snapshot(p)
        validate_s = time.perf_counter() - t0
    emit("ft_snapshot_write", write_s * 1e6)
    emit("ft_snapshot_validate", validate_s * 1e6)
    record["snapshot_write_s"] = write_s
    record["snapshot_validate_s"] = validate_s

    # --- checkpoint_every sweep ------------------------------------
    for every in (1, 2, 5, 10):
        if every > steps:
            continue
        with tempfile.TemporaryDirectory() as d:
            wall = _wall_s(lambda: drive(checkpoint_every=every,
                                         checkpoint_dir=d))
            n_snaps = steps // every
        overhead = wall - base_s
        emit(f"ft_ckpt_every_{every}", wall * 1e6,
             f"overhead_frac={overhead / base_s:.3f} snaps={n_snaps}")
        record["checkpoint_sweep"].append(
            {"every": every, "wall_s": wall, "n_snapshots": n_snaps,
             "overhead_s": overhead,
             "overhead_frac": overhead / base_s})

    # --- recovery from an injected mid-run kill --------------------
    with tempfile.TemporaryDirectory() as d:     # fresh dir: no stale
        t0 = time.perf_counter()                 # snapshots to cheat with
        faults = FaultPlan([FaultEvent("kill", superstep=steps // 2)])
        out, restarts = drive(checkpoint_every=2, checkpoint_dir=d,
                              faults=faults)
        recover_s = time.perf_counter() - t0
        assert latest_valid_snapshot(d) is not None
    # the gate: recovery must be bitwise, or the timing is meaningless
    assert np.array_equal(np.asarray(base["vertex_data"]["rank"]),
                          np.asarray(out["vertex_data"]["rank"])), \
        "recovered run diverged from the unfaulted baseline"
    assert restarts and restarts[0].error_type == "InjectedKill"
    emit("ft_recovery", recover_s * 1e6,
         f"restored_at={restarts[0].restored_superstep} "
         f"vs_base={recover_s / base_s:.2f}x")
    record["recovery"] = {
        "wall_s": recover_s, "vs_baseline": recover_s / base_s,
        "kill_at": steps // 2,
        "restored_superstep": restarts[0].restored_superstep,
        "bitwise_equal": True}

    _RESULTS.mkdir(exist_ok=True)
    out_path = _RESULTS / "BENCH_ft.json"
    hist = json.loads(out_path.read_text()) if out_path.exists() else []
    hist.append(record)
    out_path.write_text(json.dumps(hist, indent=1))
