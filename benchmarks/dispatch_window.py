"""Window-shaped adaptive kernel dispatch (DESIGN.md §8), measured.

The tentpole claim of the batch-shaped dispatch path: a scheduler
window of k vertices should cost ``O(k * W)`` per dispatch, not the
bucket-row launches' fixed ``O(sum_b Nv_b * W_b)``.  This benchmark
sweeps window size k x dispatch path on the Zipf graph (the paper's
Netflix/NER degree regime) and times one full ``apply_batch`` — gather
or kernel launch, update, scatter, task bookkeeping — per combination:

* **bucket**  — the per-bucket row launches (PR 3's path),
* **batch**   — the window-shaped ``[B, W]`` launch pair,
* **adaptive** — ``choose_dispatch("auto", ...)``'s pick, recorded for
  both the static slot-count rule (``auto_static``) and the fitted
  trace cost model (``auto_calibrated``, DESIGN.md §11; loaded from
  ``results/COSTMODEL_<device>.json`` or bootstrapped inline).

Acceptance (enforced at record time, full sizes): static adaptive is
>= 5x faster than bucket-row for k <= 64 and within +-10% of it at
k = Nv, with dense-vs-kernel bitwise parity asserted on both paths;
the calibrated pick matches or beats the static pick at EVERY k (in
particular no regression at k = Nv, where mispicking batch costs
~10x).  The ``zipf_split`` section repeats the sweep with hub
splitting enabled (``--w-cap`` overrides the cap): the cost model
prices windows at ``B * W_cap`` and the same gates must hold with no
tail bucket.  A ``partition_scoring`` section then scores >= 8
partitions of the Zipf graph with the model's predicted step time
(shard-uniform bucket launches + ghost sync) against a measured step
at the same shapes, asserting Spearman >= 0.8.

Appends ``results/BENCH_dispatch.json``; wired into ``benchmarks.run
--smoke`` for the CI artifact job (tiny sizes).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.core.exec import apply_batch, choose_dispatch
from repro.core.graph import zipf_edges

_RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def _time_us(fn, *args, warmup: int = 2, iters: int = 7) -> float:
    """Best-of-N wall time per call in microseconds.

    The small-window dispatches sit at the ~100 us scale where OS
    scheduling noise swamps a 3-sample median; the minimum is the
    standard noise-robust statistic for micro-kernels (the true cost
    plus the least interference observed)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _window(g, k: int, seed: int = 0) -> jnp.ndarray:
    """A k-vertex scheduler window: the highest-priority active
    vertices under a random priority draw (what the priority/locking
    engines' top-k would select mid-run)."""
    rng = np.random.default_rng(seed)
    prio = rng.random(g.n_vertices)
    ids = np.argsort(-prio, kind="stable")[:k]
    return jnp.asarray(np.sort(ids), jnp.int32)


def _dispatch_fn(g, upd, ids, mode: str, use_kernel: bool):
    """One jitted conflict-free batch through the chosen path."""
    nv = g.n_vertices
    valid = jnp.ones(ids.shape, bool)

    def run(vdata):
        carry = (vdata, g.edge_data, jnp.ones((nv,), bool),
                 jnp.ones((nv,), jnp.float32), jnp.int32(0))
        out = apply_batch(g, upd, carry, ids, valid, {}, sentinel=nv,
                          use_kernel=use_kernel, interpret=True,
                          dispatch=mode)
        return out[0]
    return jax.jit(run)


def _get_model():
    """The device's fitted cost model: the persisted calibration when
    one exists (CI runs ``repro.profile.calibrate --smoke`` first),
    else a quick inline calibration, persisted for the next run."""
    from repro.profile import calibrate as cal
    from repro.profile.model import load_cost_model
    model = load_cost_model()
    if model is None:
        sizes = (dict(cal.SMOKE_SIZES) if common.SMOKE
                 else dict(nv=2000, cap=64, batch_sizes=(8, 64, 512),
                           iters=3))
        recorder, model = cal.calibrate(
            with_hlo=False, emit=lambda *_: None, **sizes)
        recorder.save()
        model.save()
    return model


def _bench_graph(name: str, nv: int, cap: int, ks, model,
                 w_cap: int | None = None) -> dict:
    from repro.apps import pagerank
    g = pagerank.make_graph(zipf_edges(nv, alpha=2.0, max_deg=cap, seed=0),
                            nv, w_cap=w_cap)
    upd = pagerank.make_update(1e-6)
    ell = g.ell
    entry = {
        "graph": name, "nv": nv, "n_edges": int(g.n_edges),
        "max_deg": int(g.max_deg), "sliced_slots": int(ell.padded_slots),
        "bucket_widths": list(ell.widths), "w_cap": ell.w_cap,
        "windows": [],
    }
    for k in ks:
        k = min(k, nv)
        ids = _window(g, k)
        # post-split the batch path's worst case is B * W_cap, so the
        # cost model prices the widest *stored* bucket, not max_deg
        auto = choose_dispatch("auto", k, ell.widths[-1], ell.padded_slots)
        auto_cal = choose_dispatch(
            "auto", k, ell.widths[-1], ell.padded_slots, cost_model=model,
            bucket_launches=ell.bucket_launches)
        row = {"k": int(k), "auto_picks": auto, "auto_static": auto,
               "auto_calibrated": auto_cal}
        outs = {}
        for mode in ("bucket", "batch"):
            fn = _dispatch_fn(g, upd, ids, mode, use_kernel=True)
            outs[mode] = np.asarray(fn(g.vertex_data)["rank"])
            row[f"{mode}_us"] = round(_time_us(fn, g.vertex_data), 1)
            # dense-vs-kernel bitwise parity on this path, this window
            dense = _dispatch_fn(g, upd, ids, mode, use_kernel=False)
            assert np.array_equal(outs[mode],
                                  np.asarray(dense(g.vertex_data)["rank"])), \
                f"dense/kernel parity broke: {name} k={k} {mode}"
        if ell.is_split:
            # split hub windows: the two paths chunk the same rows at
            # W_cap but sum stage-2 partials through differently-shaped
            # scatters; on CPU interpret they agree bitwise, on Mosaic
            # only to float tolerance — assert the portable contract
            np.testing.assert_allclose(outs["bucket"], outs["batch"],
                                       rtol=1e-6, atol=1e-7,
                                       err_msg=f"{name} k={k}")
        else:
            # the dispatcher is a pure performance knob (bitwise)
            assert np.array_equal(outs["bucket"], outs["batch"]), \
                f"batch/bucket parity broke: {name} k={k}"
        # "auto" resolves at *trace* time (choose_dispatch compares two
        # static numbers — slot counts or predicted microseconds), so
        # an adaptive program IS the picked path's program — its cost
        # is that path's measurement, exactly (re-timing the same
        # executable would only record CPU noise; at k = Nv this is
        # what makes adaptive match bucket-row)
        row["adaptive_us"] = row["adaptive_static_us"] = row[f"{auto}_us"]
        row["adaptive_calibrated_us"] = row[f"{auto_cal}_us"]
        row["speedup_vs_bucket"] = round(
            row["bucket_us"] / max(row["adaptive_us"], 1e-9), 2)
        entry["windows"].append(row)
        emit(f"dispatch_{name}_k{k}_bucket", row["bucket_us"],
             f"slots={ell.padded_slots}")
        emit(f"dispatch_{name}_k{k}_batch", row["batch_us"],
             f"W<=B*{ell.widths[-1]}={k * ell.widths[-1]}")
        emit(f"dispatch_{name}_k{k}_adaptive", row["adaptive_us"],
             f"picks={auto};x{row['speedup_vs_bucket']}")
        emit(f"dispatch_{name}_k{k}_calibrated",
             row["adaptive_calibrated_us"], f"picks={auto_cal}")
    return entry


def _spearman(x, y) -> float:
    """Spearman rank correlation, numpy-only (scipy is not assumed)."""
    rx = np.argsort(np.argsort(np.asarray(x))).astype(float)
    ry = np.argsort(np.argsort(np.asarray(y))).astype(float)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = np.sqrt((rx ** 2).sum() * (ry ** 2).sum())
    return float((rx * ry).sum() / denom) if denom else 0.0


def _measured_step_us(launches, n_ghosts: int, nv: int) -> float:
    """Wall-clock one distributed-superstep-shaped workload: a real
    bucketed SpMV at the shard-uniform ``(W, rows)`` launch shapes plus
    a ghost-row-sized scatter — the same two terms the model predicts,
    measured instead of priced."""
    from repro.kernels.ell_spmv import ell_spmv_bucketed
    rng = np.random.default_rng(0)
    nbrs = tuple(jnp.asarray(rng.integers(0, nv, size=(r, w)), jnp.int32)
                 for w, r in launches)
    w_blocks = tuple(jnp.ones((r, w), jnp.float32) for w, r in launches)
    x = jnp.ones((nv, 1), jnp.float32)
    fn = jax.jit(lambda xv: ell_spmv_bucketed(nbrs, w_blocks, xv,
                                              interpret=True))
    compute = _time_us(fn, x)
    h = max(int(n_ghosts), 1)
    arr = jnp.zeros((nv, 1), jnp.float32)
    idx = jnp.asarray(np.arange(h) % nv, jnp.int32)
    vals = jnp.ones((h, 1), jnp.float32)
    sfn = jax.jit(lambda a, i, v: a.at[i].set(v))
    return compute + _time_us(sfn, arr, idx, vals)


def _partition_scoring(model, nv: int, cap: int, n_machines: int = 4) -> dict:
    """Predicted vs measured step time over >= 8 partitions of the Zipf
    graph, spanning good (two-phase) to bad (skewed random) quality."""
    from repro.core.partition import (ghost_rows, predicted_step_time,
                                      random_partition,
                                      shard_bucket_launches,
                                      two_phase_partition)
    edges = zipf_edges(nv, alpha=2.0, max_deg=cap, seed=0)
    degrees = np.zeros(nv, dtype=np.int64)
    for col in (0, 1):
        np.add.at(degrees, edges[:, col], 1)
    rng = np.random.default_rng(7)
    candidates = [("two_phase_s0",
                   two_phase_partition(nv, edges, n_machines, seed=0)),
                  ("two_phase_s1",
                   two_phase_partition(nv, edges, n_machines, seed=1))]
    candidates += [(f"random_s{s}", random_partition(nv, n_machines, seed=s))
                   for s in (0, 1, 2)]
    # skewed draws: deliberately imbalanced machines -> inflated uniform
    # bucket shapes and ghost counts, the "bad partition" end of the axis
    for i, probs in enumerate([(0.4, 0.3, 0.2, 0.1),
                               (0.55, 0.25, 0.15, 0.05),
                               (0.7, 0.15, 0.1, 0.05)]):
        candidates.append(
            (f"skewed_{i}", rng.choice(n_machines, size=nv, p=probs)))
    out = {"n_machines": n_machines, "partitions": []}
    pred, meas = [], []
    for pname, assignment in candidates:
        launches = shard_bucket_launches(assignment, degrees, n_machines)
        ghosts = int(ghost_rows(assignment, edges, n_machines).max())
        p = predicted_step_time(assignment, degrees, edges, n_machines,
                                model)
        m = _measured_step_us(launches, ghosts, nv)
        pred.append(p)
        meas.append(m)
        out["partitions"].append(
            {"partition": pname, "predicted_us": round(p, 1),
             "measured_us": round(m, 1), "max_ghosts": ghosts})
        emit(f"partition_{pname}", m, f"predicted={p:.1f}")
    out["spearman"] = round(_spearman(pred, meas), 3)
    emit("partition_scoring_spearman", 0.0, f"rho={out['spearman']}")
    return out


def run() -> None:
    if common.SMOKE:
        nv, cap, w_cap = 400, 32, 8
    else:
        nv, cap, w_cap = 10_000, 192, 64
    if common.W_CAPS:
        w_cap = max(common.W_CAPS)
    model = _get_model()
    ks = sorted({min(k, nv) for k in (8, 64, 512, nv)})
    entry = {
        "bench": "dispatch_window",
        "smoke": common.SMOKE,
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "cost_model": {"device": model.device,
                       "n_records": model.n_records,
                       "widths": sorted(model.coef)},
        "zipf": _bench_graph("zipf", nv, cap, ks, model),
        "zipf_split": _bench_graph("zipf_split", nv, cap, ks, model,
                                   w_cap=w_cap),
        "partition_scoring": _partition_scoring(model, nv, cap),
    }
    assert entry["zipf_split"]["bucket_widths"][-1] == w_cap  # no tail
    if not common.SMOKE:
        # The PR's acceptance criteria, enforced at record time.  There
        # is no third "adaptive" executable to stopwatch — choose_dispatch
        # is a pure trace-time function, so adaptive == the resolved
        # path's program by construction (adaptive_us records it).  The
        # meaningful gates are the >=5x win where auto picks the batch
        # path and that auto actually resolves small windows to batch
        # and graph-sized windows to bucket (where it matches bucket-row
        # cost exactly, satisfying the +-10% criterion definitionally).
        # The split section holds to the same gates: capping the batch
        # worst case at B*W_cap must not cost the small-window win.
        for section in ("zipf", "zipf_split"):
            for row in entry[section]["windows"]:
                if row["k"] <= 64:
                    assert row["auto_picks"] == "batch", (section, row)
                    assert row["speedup_vs_bucket"] >= 5.0, (section, row)
                if row["k"] == nv:
                    assert row["auto_picks"] == "bucket", (section, row)
                # calibrated auto must match or beat the static pick at
                # every k (5% timing-noise allowance; when both resolve
                # to the same mode the two sides are the same number)
                assert (row["adaptive_calibrated_us"]
                        <= row["adaptive_static_us"] * 1.05), (section, row)
                if row["k"] == nv:
                    # the expensive mispick: batch at a graph-sized
                    # window costs ~10x — the calibrated pick must not
                    # regress the full-window case
                    assert (row["adaptive_calibrated_us"]
                            <= row["bucket_us"] * 1.10), (section, row)
        rho = entry["partition_scoring"]["spearman"]
        assert rho >= 0.8, f"partition scoring decorrelated: rho={rho}"
    _RESULTS.mkdir(exist_ok=True)
    path = _RESULTS / "BENCH_dispatch.json"
    history = json.loads(path.read_text()) if path.exists() else []
    history.append(entry)
    path.write_text(json.dumps(history, indent=2) + "\n")
