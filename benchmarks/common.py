"""Shared benchmark helpers: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (one per
configuration) so ``python -m benchmarks.run`` produces one CSV stream.
"""
from __future__ import annotations

import time

import jax

# Set by ``python -m benchmarks.run --smoke``: tiny problem sizes and
# short sweeps so CI can exercise every benchmark path and upload the
# BENCH_*.json artifacts in a few minutes.
SMOKE = False

# Set by ``--w-cap=16,32,64``: hub-splitting cap widths for the graph /
# dispatch sweeps (None -> each benchmark's default ladder).
W_CAPS: list[int] | None = None


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (blocks on device)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
