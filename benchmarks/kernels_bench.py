"""Pallas kernels vs pure-jnp oracle timings (interpret mode on CPU —
relative numbers are indicative only; the kernels target TPU Mosaic).

Also times the engine-level aggregator fast path (lite scopes +
``ell_spmv``) against the dense-scope path on a PageRank sweep, and
appends the result to ``results/BENCH_engines.json``.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref

_RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def _bench_engine_paths() -> None:
    """Dense-scope vs Pallas-aggregator dispatch through the executor."""
    from repro import api
    from repro.apps import pagerank

    rng = np.random.default_rng(0)
    nv, ne = 2000, 8000
    edges = set()
    while len(edges) < ne:
        u, v = rng.integers(0, nv, 2)
        if u != v:
            edges.add((min(int(u), int(v)), max(int(u), int(v))))
    edges = np.asarray(sorted(edges), dtype=np.int64)
    g = pagerank.make_graph(edges, nv)
    upd = pagerank.make_update(-1.0)      # full sweeps: no early drain
    entry = {"bench": "engine_dense_vs_aggregator", "app": "pagerank",
             "nv": nv, "n_edges": int(len(edges)),
             "max_deg": int(g.max_deg), "supersteps": 3,
             "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S")}
    for label, use_kernel in (("dense_scope", False), ("aggregator", True)):
        eng = api.build_engine(g, upd, max_supersteps=3,
                               use_kernel=use_kernel)
        us = time_fn(lambda e=eng: e.run(num_supersteps=3), iters=2)
        emit(f"engine_pagerank_{label}", us,
             f"nv={nv};use_kernel={use_kernel}")
        entry[f"{label}_us"] = round(us, 1)
    entry["aggregator_speedup_over_dense"] = round(
        entry["dense_scope_us"] / entry["aggregator_us"], 3)
    _RESULTS.mkdir(exist_ok=True)
    path = _RESULTS / "BENCH_engines.json"
    history = json.loads(path.read_text()) if path.exists() else []
    history.append(entry)
    path.write_text(json.dumps(history, indent=2) + "\n")


def run() -> None:
    rng = np.random.default_rng(0)
    nv, deg, rows, feat = 512, 8, 512, 64
    nbrs = jnp.asarray(rng.integers(0, rows, (nv, deg)), jnp.int32)
    w = jnp.asarray(rng.random((nv, deg)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(rows, feat)), jnp.float32)
    us = time_fn(lambda: ref.ell_spmv_ref(nbrs, w, x))
    emit("kernel_ell_spmv_ref", us, f"nv={nv};deg={deg};f={feat}")
    us = time_fn(lambda: ops.ell_spmv(nbrs, w, x))
    emit("kernel_ell_spmv_pallas_interp", us, "interpret=True")

    d = 16
    mask = jnp.asarray(rng.random((nv, deg)) < 0.7)
    r = jnp.asarray(rng.normal(size=(nv, deg)), jnp.float32)
    xf = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    us = time_fn(lambda: ref.als_normal_eq_ref(nbrs, mask, r, xf))
    emit("kernel_als_neq_ref", us, f"d={d}")
    us = time_fn(lambda: ops.als_normal_eq(nbrs, mask, r, xf))
    emit("kernel_als_neq_pallas_interp", us, "interpret=True")

    bh, wlen, dh = 8, 2048, 64
    q = jnp.asarray(rng.normal(size=(bh, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, wlen, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(bh, wlen, dh)), jnp.bfloat16)
    kvl = jnp.full((bh,), wlen, jnp.int32)
    us = time_fn(lambda: ref.decode_window_attention_ref(q, k, v, kvl))
    emit("kernel_window_attn_ref", us, f"w={wlen}")
    us = time_fn(lambda: ops.decode_window_attention(q, k, v, kvl))
    emit("kernel_window_attn_pallas_interp", us, "interpret=True")

    _bench_engine_paths()
