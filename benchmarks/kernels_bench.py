"""Pallas kernels vs pure-jnp oracle timings (interpret mode on CPU —
relative numbers are indicative only; the kernels target TPU Mosaic)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref


def run() -> None:
    rng = np.random.default_rng(0)
    nv, deg, rows, feat = 512, 8, 512, 64
    nbrs = jnp.asarray(rng.integers(0, rows, (nv, deg)), jnp.int32)
    w = jnp.asarray(rng.random((nv, deg)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(rows, feat)), jnp.float32)
    us = time_fn(lambda: ref.ell_spmv_ref(nbrs, w, x))
    emit("kernel_ell_spmv_ref", us, f"nv={nv};deg={deg};f={feat}")
    us = time_fn(lambda: ops.ell_spmv(nbrs, w, x))
    emit("kernel_ell_spmv_pallas_interp", us, "interpret=True")

    d = 16
    mask = jnp.asarray(rng.random((nv, deg)) < 0.7)
    r = jnp.asarray(rng.normal(size=(nv, deg)), jnp.float32)
    xf = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    us = time_fn(lambda: ref.als_normal_eq_ref(nbrs, mask, r, xf))
    emit("kernel_als_neq_ref", us, f"d={d}")
    us = time_fn(lambda: ops.als_normal_eq(nbrs, mask, r, xf))
    emit("kernel_als_neq_pallas_interp", us, "interpret=True")

    bh, wlen, dh = 8, 2048, 64
    q = jnp.asarray(rng.normal(size=(bh, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, wlen, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(bh, wlen, dh)), jnp.bfloat16)
    kvl = jnp.full((bh,), wlen, jnp.int32)
    us = time_fn(lambda: ref.decode_window_attention_ref(q, k, v, kvl))
    emit("kernel_window_attn_ref", us, f"w={wlen}")
    us = time_fn(lambda: ops.decode_window_attention(q, k, v, kvl))
    emit("kernel_window_attn_pallas_interp", us, "interpret=True")
