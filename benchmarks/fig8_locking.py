"""Paper Fig. 8(a): CoSeg weak scaling, and Fig. 8(b): the lock-pipeline
(maxpending) sweep under good vs worst-case partitioning.

8(a): runtime per superstep as the graph grows proportionally with the
shard count (per-shard work constant).  On one host we measure engine
time per superstep per vertex — flat means weak-scalable compute — plus
the plan's cut growth (the paper attributes its 11%-to-64-procs overhead
to linear cut growth; we report cut edges per shard directly).

8(b): ``k_select`` in the PriorityEngine is the in-flight-work knob that
replaces lock pipelining (DESIGN.md §2).  We sweep it on the paper's two
partitions of a small CoSeg problem — "optimal" (8-frame blocks) vs
"worst case" (frames striped) — and report supersteps-to-convergence and
the ghost traffic each partition implies.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.apps import lbp
from repro.core import ChromaticEngine, PriorityEngine, ShardPlan


def run() -> None:
    # ---- 8(a) weak scaling ----
    for m in (1, 2, 4, 8):
        prob = lbp.synthetic_coseg(2 * m, 4, 8, n_labels=3, noise=0.5,
                                   seed=m)
        g = prob.graph
        upd = lbp.make_update(3, eps=1e-3)
        eng = ChromaticEngine(g, upd, max_supersteps=3)
        us = time_fn(lambda e=eng: e.run(num_supersteps=3), iters=2)
        asg = lbp.frame_partition(prob, m)
        plan = ShardPlan.build(g, asg, m) if m > 1 else None
        cut = int(np.asarray(plan.send_mask).sum()) if plan else 0
        emit(f"fig8a_coseg_m{m}", us / 3 / g.n_vertices * m,
             f"verts={g.n_vertices};ghost_rows_per_shard={cut / m:.0f}")

    # ---- 8(b) maxpending (k_select) sweep ----
    prob = lbp.synthetic_coseg(8, 4, 6, n_labels=3, noise=0.5, seed=0)
    for part_name, asg_fn in (("optimal", lbp.frame_partition),
                              ("worst", lbp.striped_partition)):
        asg = asg_fn(prob, 4)
        plan = ShardPlan.build(prob.graph, asg, 4)
        ghost = int(np.asarray(plan.send_mask).sum())
        for k in (8, 32, 128):
            eng = PriorityEngine(prob.graph,
                                 lbp.make_update(3, eps=1e-2),
                                 k_select=k, max_supersteps=4000)
            st = eng.run()
            us = time_fn(lambda e=eng: e.run(), iters=1)
            emit(f"fig8b_{part_name}_k{k}", us,
                 f"supersteps={int(st.superstep)};"
                 f"updates={int(st.n_updates)};ghost_rows={ghost}")
