"""Paper Fig. 8(a): CoSeg weak scaling, and Fig. 8(b): the lock-pipeline
(maxpending) sweep under good vs worst-case partitioning.

8(a): runtime per superstep as the graph grows proportionally with the
shard count (per-shard work constant).  On one host we measure engine
time per superstep per vertex — flat means weak-scalable compute — plus
the plan's cut growth (the paper attributes its 11%-to-64-procs overhead
to linear cut growth; we report cut edges per shard directly).

8(b): two sweeps side by side, so the BENCH trajectory stays comparable
across PRs:

* ``max_pending`` — the *real* lock-pipeline knob of the
  ``LockingEngine`` (DESIGN.md §6): how many scope acquisitions are in
  flight per shard.  P=1 is strictly sequential; larger P admits bigger
  claim-winner batches per round but executes with staler neighbor
  data, so total work can grow — the paper's maxpending trade-off.
* ``k_select`` — the PriorityEngine's in-flight-work knob, the proxy
  this benchmark swept before the locking engine existed (kept for
  comparability; see DESIGN.md §6 for why it is *not* lock pipelining).

When >= 4 devices are available (CI runs this under
``xla_force_host_platform_device_count``), the sweep also runs the
``DistributedLockingEngine`` on 4 shards and records the versioned
ghost sync's filtered vs full traffic per partition.

Appends one entry (both sweeps + per-partition ghost traffic) to
``results/BENCH_locking.json``.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

import benchmarks.common as common
from benchmarks.common import emit, time_fn
from repro import api
from repro.apps import lbp
from repro.core import ShardPlan

_RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def run() -> None:
    import jax

    # ---- 8(a) weak scaling ----
    for m in (1, 2) if common.SMOKE else (1, 2, 4, 8):
        prob = lbp.synthetic_coseg(2 * m, 4, 8, n_labels=3, noise=0.5,
                                   seed=m)
        g = prob.graph
        upd = lbp.make_update(3, eps=1e-3)
        eng = api.build_engine(g, upd, max_supersteps=3)
        us = time_fn(lambda e=eng: e.run(num_supersteps=3), iters=2)
        asg = lbp.frame_partition(prob, m)
        plan = ShardPlan.build(g, asg, m) if m > 1 else None
        cut = int(np.asarray(plan.send_mask).sum()) if plan else 0
        emit(f"fig8a_coseg_m{m}", us / 3 / g.n_vertices * m,
             f"verts={g.n_vertices};ghost_rows_per_shard={cut / m:.0f}")

    # ---- 8(b) lock-pipeline sweep: max_pending (real) + k_select ----
    if common.SMOKE:
        prob = lbp.synthetic_coseg(4, 3, 4, n_labels=3, noise=0.5, seed=0)
        ks, ps, max_ss = (8, 32), (1, 8, 32), 5000
    else:
        prob = lbp.synthetic_coseg(8, 4, 6, n_labels=3, noise=0.5, seed=0)
        ks, ps, max_ss = (8, 32, 128), (1, 8, 32, 128), 20000
    n_shards = 4
    entry = {"bench": "fig8b_lock_pipeline",
             "nv": prob.graph.n_vertices, "n_shards": n_shards,
             "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
             "max_pending": {}, "k_select": {}, "partitions": {}}

    # single-device sweeps depend only on the schedule knob, not on the
    # partition — run each point once
    for p in ps:
        eng = api.build_engine(prob.graph, lbp.make_update(3, eps=1e-2),
                               scheduler="locking", max_pending=p,
                               max_supersteps=max_ss)
        st = eng.run()
        us = time_fn(lambda e=eng: e.run(), iters=1)
        emit(f"fig8b_maxpending{p}", us,
             f"supersteps={int(st.superstep)};updates={int(st.n_updates)}")
        entry["max_pending"][str(p)] = {
            "us": round(us, 1), "supersteps": int(st.superstep),
            "updates": int(st.n_updates)}

    for k in ks:
        eng = api.build_engine(prob.graph, lbp.make_update(3, eps=1e-2),
                               scheduler="priority", k_select=k,
                               max_supersteps=4000)
        st = eng.run()
        us = time_fn(lambda e=eng: e.run(), iters=1)
        emit(f"fig8b_k{k}", us,
             f"supersteps={int(st.superstep)};updates={int(st.n_updates)}")
        entry["k_select"][str(k)] = {
            "us": round(us, 1), "supersteps": int(st.superstep),
            "updates": int(st.n_updates)}

    # ghost traffic is what the partition decides: static schedule
    # width, and (given a mesh) the versioned sync's filtered traffic
    for part_name, asg_fn in (("optimal", lbp.frame_partition),
                              ("worst", lbp.striped_partition)):
        asg = asg_fn(prob, n_shards)
        plan = ShardPlan.build(prob.graph, asg, n_shards)
        ghost = int(np.asarray(plan.send_mask).sum())
        part = {"ghost_rows_static": ghost}
        if jax.device_count() >= n_shards:
            # pass the prebuilt plan: the facade accepts it verbatim,
            # so the host-side ShardPlan.build is not paid twice
            res = api.run(
                prob.graph, lbp.make_update(3, eps=1e-2),
                scheduler="locking", n_shards=n_shards, partition=plan,
                max_pending=ps[-1], max_supersteps=max_ss,
                exchange_edges=True)
            emit(f"fig8b_{part_name}_ghost_filtered", 0.0,
                 f"static={ghost};sent={res.stats['ghost_rows_sent']};"
                 f"full={res.stats['ghost_rows_full']}")
            part["ghost_rows_sent"] = res.stats["ghost_rows_sent"]
            part["ghost_rows_full"] = res.stats["ghost_rows_full"]
        else:
            emit(f"fig8b_{part_name}_ghost_static", 0.0, f"static={ghost}")
        entry["partitions"][part_name] = part

    _RESULTS.mkdir(exist_ok=True)
    path = _RESULTS / "BENCH_locking.json"
    history = json.loads(path.read_text()) if path.exists() else []
    history.append(entry)
    path.write_text(json.dumps(history, indent=2) + "\n")
