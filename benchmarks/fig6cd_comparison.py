"""Paper Fig. 6(c): IPB / computational-intensity sweep (ALS d sweep) and
Fig. 6(d) + Fig. 7(a): GraphLab vs Hadoop-style vs MPI-style runtimes.

6(c): the paper varies d in ALS to change instructions-per-byte and shows
scalability improves with intensity.  We sweep the same d and report both
time-per-update and the analytic flops/byte of the update (O(d^3 + deg)
work over O(d*deg) bytes).

6(d)/7(a): per-iteration wall time of the same computation under the
three programming models on identical hardware, plus the traffic each
would put on a network (message materialization vs ghost exchange).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro import api
from repro.apps import als, coem
from repro.baselines.mapreduce import als_mapreduce, coem_mapreduce
from repro.baselines.mpi_als import als_mpi
from repro.core import ShardPlan, random_partition


def run() -> None:
    # ---- Fig 6(c): intensity sweep ----
    for d in (4, 8, 16, 32):
        prob = als.synthetic_netflix(120, 100, d=4, density=0.1, seed=2,
                                     d_model=d)
        upd = als.make_update(d, eps=0.0)
        eng = api.build_engine(prob.graph, upd, max_supersteps=3)
        us = time_fn(lambda e=eng: e.run(num_supersteps=3), iters=2)
        st = eng.run(num_supersteps=3)
        n_upd = max(int(st.n_updates), 1)
        mean_deg = float(np.asarray(prob.graph.degree).mean())
        flops = d ** 3 / 3 + mean_deg * d * d * 2
        bytes_ = mean_deg * (d + 1) * 4
        emit(f"fig6c_als_d{d}", us / n_upd,
             f"ipb={flops / bytes_:.2f}")

    # ---- Fig 6(d): Netflix under three models ----
    prob = als.synthetic_netflix(200, 150, d=8, density=0.08, seed=3)
    iters = 4
    upd = als.make_update(8, eps=0.0)
    eng = api.build_engine(prob.graph, upd, max_supersteps=iters)
    us_gl = time_fn(lambda: eng.run(num_supersteps=iters), iters=2)
    emit("fig6d_netflix_graphlab", us_gl / iters, "")
    us_mr = time_fn(lambda: als_mapreduce(prob, iters), iters=2)
    _, stats = als_mapreduce(prob, 1)
    emit("fig6d_netflix_hadoop_style", us_mr / iters,
         f"shuffle_bytes={stats.bytes_shuffled_per_iter}")
    us_mpi = time_fn(lambda: als_mpi(prob, iters), iters=2)
    emit("fig6d_netflix_mpi_style", us_mpi / iters, "")

    # ---- Fig 7(a): NER under two models + traffic accounting ----
    nprob = coem.synthetic_ner(400, 300, 5, mean_deg=8, seed=1)
    updc = coem.make_update(0.0)
    engc = api.build_engine(nprob.graph, updc, max_supersteps=iters)
    us_gl = time_fn(lambda: engc.run(num_supersteps=iters), iters=2)
    us_mr = time_fn(lambda: coem_mapreduce(nprob, iters), iters=2)
    _, cstats = coem_mapreduce(nprob, 1)
    asg = random_partition(nprob.graph.n_vertices, 16, seed=0)
    plan = ShardPlan.build(nprob.graph, asg, 16)
    ghost = int(np.asarray(plan.send_mask).sum()) * 5 * 4
    emit("fig7a_ner_graphlab", us_gl / iters,
         f"ghost_bytes_per_iter={ghost}")
    emit("fig7a_ner_hadoop_style", us_mr / iters,
         f"shuffle_bytes_per_iter={cstats.bytes_shuffled_per_iter}")
    emit("fig7a_traffic_ratio", 0.0,
         f"hadoop_over_graphlab={cstats.bytes_shuffled_per_iter / max(ghost, 1):.1f}x")
