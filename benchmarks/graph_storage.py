"""Graph storage: monolithic padded ELL vs degree-bucketed sliced ELL.

The tentpole claim of the sliced-ELL refactor (DESIGN.md §7), measured:

* **slots** — stored (= kernel-computed) neighbor slots.  The monolithic
  layout pays ``Nv * max_deg``; sliced ELL pays ``sum_b Nv_b * W_b``.
  On a Zipf-degree graph the ratio is the whole point (paper §5: the
  Netflix/NER graphs are exactly this shape).
* **build time** — the vectorized lexsort/cumsum ``from_edges`` builder
  vs the original per-edge Python loop, raced on a ~1M-edge graph.
* **PageRank sweep** — one aggregation pass ``y = sum_j w*x[nbr]`` over
  every vertex: one padded-width ``ell_spmv`` launch vs the per-bucket
  ``ell_spmv_bucketed`` launches (interpret mode on CPU; the relative
  number is the point).

Appends ``results/BENCH_graph.json``; wired into ``benchmarks.run
--smoke`` for the CI artifact job (tiny sizes).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_fn
from repro.core.graph import (DataGraph, _build_ell_loop,
                              _build_ell_vectorized, zipf_edges)
from repro.kernels.ell_spmv import ell_spmv, ell_spmv_bucketed

_RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def _uniform_edges(nv: int, ne: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    pairs = rng.integers(0, nv, (int(ne * 1.2), 2))
    pairs = pairs[pairs[:, 0] != pairs[:, 1]][:ne]
    lo = np.minimum(pairs[:, 0], pairs[:, 1])
    hi = np.maximum(pairs[:, 0], pairs[:, 1])
    return np.unique(np.stack([lo, hi], axis=1), axis=0)


def _sweep_us(g: DataGraph, interpret: bool = True) -> tuple[float, float]:
    """One full-graph PageRank aggregation, monolithic vs bucketed."""
    ell = g.ell
    p = g.to_padded()
    x = g.vertex_data["rank"][:, None].astype(jnp.float32)
    w_full = jnp.where(p.nbr_mask, g.edge_data["w"][p.edge_ids],
                       0.0).astype(jnp.float32)
    w_blocks = [jnp.where(m, g.edge_data["w"][e], 0.0).astype(jnp.float32)
                for m, e in zip(ell.nbr_mask, ell.edge_ids)]
    mono = jax.jit(lambda x: ell_spmv(p.nbrs, w_full, x,
                                      interpret=interpret))
    sliced = jax.jit(lambda x: ell_spmv_bucketed(ell.nbrs, w_blocks, x,
                                                 interpret=interpret))
    # same function before timing (float tolerance: launch widths
    # compile with different excess precision; the engines' bitwise
    # parity is between their two same-shape dispatch paths, §7)
    y_m, y_s = mono(x), sliced(x)
    np.testing.assert_allclose(np.asarray(y_m),
                               np.asarray(y_s)[np.asarray(ell.inv_perm)],
                               rtol=1e-5, atol=1e-7)
    return time_fn(mono, x), time_fn(sliced, x)


def _bench_graph(name: str, nv: int, edges: np.ndarray) -> dict:
    g = pagerank_graph(nv, edges)
    deg = np.asarray(g.degree, dtype=np.float64)
    mono_slots = g.n_vertices * g.max_deg
    sliced_slots = g.ell.padded_slots
    mono_us, sliced_us = _sweep_us(g)
    entry = {
        "graph": name, "nv": nv, "n_edges": int(g.n_edges),
        "max_deg": int(g.max_deg), "mean_deg": round(float(deg.mean()), 3),
        "skew_max_over_mean": round(g.max_deg / max(deg.mean(), 1e-9), 2),
        "monolithic_slots": int(mono_slots),
        "sliced_slots": int(sliced_slots),
        "slot_reduction": round(mono_slots / max(sliced_slots, 1), 2),
        "bucket_widths": list(g.ell.widths),
        "sweep_monolithic_us": round(mono_us, 1),
        "sweep_sliced_us": round(sliced_us, 1),
        "sweep_speedup": round(mono_us / max(sliced_us, 1e-9), 3),
    }
    emit(f"graph_storage_{name}_sweep_mono", mono_us,
         f"nv={nv};slots={mono_slots}")
    emit(f"graph_storage_{name}_sweep_sliced", sliced_us,
         f"nv={nv};slots={sliced_slots};x{entry['slot_reduction']}")
    return entry


def pagerank_graph(nv: int, edges: np.ndarray) -> DataGraph:
    from repro.apps import pagerank
    return pagerank.make_graph(edges, nv)


def _bench_build(ne_target: int) -> dict:
    """Vectorized vs loop ELL build on a large uniform edge list."""
    nv = max(ne_target // 10, 16)
    edges = _uniform_edges(nv, ne_target, seed=1)
    deg = np.zeros(nv, dtype=np.int64)
    for col in (0, 1):
        np.add.at(deg, edges[:, col], 1)
    md = max(int(deg.max()), 1)
    t0 = time.perf_counter()
    vec = _build_ell_vectorized(nv, edges, md)
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    loop = _build_ell_loop(nv, edges, md)
    t_loop = time.perf_counter() - t0
    for a, b in zip(vec, loop):       # identical output, not just faster
        assert np.array_equal(a, b)
    emit("graph_build_loop", t_loop * 1e6, f"ne={len(edges)}")
    emit("graph_build_vectorized", t_vec * 1e6,
         f"ne={len(edges)};x{t_loop / max(t_vec, 1e-9):.1f}")
    return {
        "n_edges": int(len(edges)), "nv": nv,
        "build_loop_us": round(t_loop * 1e6, 1),
        "build_vectorized_us": round(t_vec * 1e6, 1),
        "build_speedup": round(t_loop / max(t_vec, 1e-9), 2),
    }


def run() -> None:
    if common.SMOKE:
        nv_zipf, cap, nv_uni, ne_uni, ne_build = 400, 32, 300, 900, 20_000
    else:
        nv_zipf, cap, nv_uni, ne_uni, ne_build = 10_000, 192, 5_000, \
            20_000, 1_000_000
    entry = {
        "bench": "graph_storage",
        "smoke": common.SMOKE,
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "graphs": [
            _bench_graph("uniform", nv_uni,
                         _uniform_edges(nv_uni, ne_uni, seed=2)),
            _bench_graph("zipf", nv_zipf,
                         zipf_edges(nv_zipf, alpha=2.0, max_deg=cap,
                                    seed=0)),
        ],
        "build": _bench_build(ne_build),
    }
    zipf = entry["graphs"][1]
    if not common.SMOKE:
        # the PR's acceptance criterion, enforced at record time
        assert zipf["skew_max_over_mean"] >= 32, zipf
        assert zipf["slot_reduction"] >= 4, zipf
    _RESULTS.mkdir(exist_ok=True)
    path = _RESULTS / "BENCH_graph.json"
    history = json.loads(path.read_text()) if path.exists() else []
    history.append(entry)
    path.write_text(json.dumps(history, indent=2) + "\n")
