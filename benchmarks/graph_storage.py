"""Graph storage: monolithic padded ELL vs degree-bucketed sliced ELL.

The tentpole claim of the sliced-ELL refactor (DESIGN.md §7), measured:

* **slots** — stored (= kernel-computed) neighbor slots.  The monolithic
  layout pays ``Nv * max_deg``; sliced ELL pays ``sum_b Nv_b * W_b``.
  On a Zipf-degree graph the ratio is the whole point (paper §5: the
  Netflix/NER graphs are exactly this shape).
* **build time** — the vectorized lexsort/cumsum ``from_edges`` builder
  vs the original per-edge Python loop, raced on a ~1M-edge graph.
* **PageRank sweep** — one aggregation pass ``y = sum_j w*x[nbr]`` over
  every vertex: one padded-width ``ell_spmv`` launch vs the per-bucket
  ``ell_spmv_bucketed`` launches (interpret mode on CPU; the relative
  number is the point).

Appends ``results/BENCH_graph.json``; wired into ``benchmarks.run
--smoke`` for the CI artifact job (tiny sizes).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_fn
from repro.core.graph import (DataGraph, _build_ell_loop,
                              _build_ell_vectorized, default_bucket_widths,
                              zipf_edges)
from repro.kernels.ell_spmv import (ell_spmv, ell_spmv_bucketed,
                                    segment_combine)

_RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def _uniform_edges(nv: int, ne: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    pairs = rng.integers(0, nv, (int(ne * 1.2), 2))
    pairs = pairs[pairs[:, 0] != pairs[:, 1]][:ne]
    lo = np.minimum(pairs[:, 0], pairs[:, 1])
    hi = np.maximum(pairs[:, 0], pairs[:, 1])
    return np.unique(np.stack([lo, hi], axis=1), axis=0)


def _sweep_us(g: DataGraph, interpret: bool = True) -> tuple[float, float]:
    """One full-graph PageRank aggregation, monolithic vs bucketed."""
    ell = g.ell
    p = g.to_padded()
    x = g.vertex_data["rank"][:, None].astype(jnp.float32)
    w_full = jnp.where(p.nbr_mask, g.edge_data["w"][p.edge_ids],
                       0.0).astype(jnp.float32)
    w_blocks = [jnp.where(m, g.edge_data["w"][e], 0.0).astype(jnp.float32)
                for m, e in zip(ell.nbr_mask, ell.edge_ids)]
    mono = jax.jit(lambda x: ell_spmv(p.nbrs, w_full, x,
                                      interpret=interpret))
    sliced = jax.jit(lambda x: ell_spmv_bucketed(ell.nbrs, w_blocks, x,
                                                 interpret=interpret))
    # same function before timing (float tolerance: launch widths
    # compile with different excess precision; the engines' bitwise
    # parity is between their two same-shape dispatch paths, §7)
    y_m, y_s = mono(x), sliced(x)
    np.testing.assert_allclose(np.asarray(y_m),
                               np.asarray(y_s)[np.asarray(ell.inv_perm)],
                               rtol=1e-5, atol=1e-7)
    return time_fn(mono, x), time_fn(sliced, x)


def _bench_graph(name: str, nv: int, edges: np.ndarray) -> dict:
    g = pagerank_graph(nv, edges)
    deg = np.asarray(g.degree, dtype=np.float64)
    mono_slots = g.n_vertices * g.max_deg
    sliced_slots = g.ell.padded_slots
    mono_us, sliced_us = _sweep_us(g)
    entry = {
        "graph": name, "nv": nv, "n_edges": int(g.n_edges),
        "max_deg": int(g.max_deg), "mean_deg": round(float(deg.mean()), 3),
        "skew_max_over_mean": round(g.max_deg / max(deg.mean(), 1e-9), 2),
        "monolithic_slots": int(mono_slots),
        "sliced_slots": int(sliced_slots),
        "slot_reduction": round(mono_slots / max(sliced_slots, 1), 2),
        "bucket_widths": list(g.ell.widths),
        "sweep_monolithic_us": round(mono_us, 1),
        "sweep_sliced_us": round(sliced_us, 1),
        "sweep_speedup": round(mono_us / max(sliced_us, 1e-9), 3),
    }
    emit(f"graph_storage_{name}_sweep_mono", mono_us,
         f"nv={nv};slots={mono_slots}")
    emit(f"graph_storage_{name}_sweep_sliced", sliced_us,
         f"nv={nv};slots={sliced_slots};x{entry['slot_reduction']}")
    return entry


def pagerank_graph(nv: int, edges: np.ndarray) -> DataGraph:
    from repro.apps import pagerank
    return pagerank.make_graph(edges, nv)


def _pagerank_weights(nv: int, edges: np.ndarray) -> np.ndarray:
    deg = np.zeros(nv, dtype=np.int64)
    for col in (0, 1):
        np.add.at(deg, edges[:, col], 1)
    d = np.maximum(deg, 1).astype(np.float64)
    return (1.0 / np.sqrt(d[edges[:, 0]] * d[edges[:, 1]])).astype(np.float32)


def _bucketed_sweep_fn(g: DataGraph):
    """Jitted bucketed PageRank aggregation, result in owner-row order
    (split layouts add the segmented stage-2 combine)."""
    ell = g.ell
    w_blocks = [jnp.where(m, g.edge_data["w"][e], 0.0).astype(jnp.float32)
                for m, e in zip(ell.nbr_mask, ell.edge_ids)]
    inv = ell.inv_perm
    if ell.is_split:
        owner = ell.owner_of_vrow
        nv = g.n_vertices

        def f(x):
            y = ell_spmv_bucketed(ell.nbrs, w_blocks, x, interpret=True)
            return segment_combine(y[inv], owner, nv)
    else:
        def f(x):
            return ell_spmv_bucketed(ell.nbrs, w_blocks, x,
                                     interpret=True)[inv]
    return jax.jit(f)


def _bench_split(name: str, nv: int, w_caps, sweep_cap: int) -> dict:
    """The ``--w-cap`` sweep (DESIGN.md §10): hub splitting vs the two
    bucketed baselines on an *unclipped* Zipf graph, where one hub sets
    ``max_deg`` and the tail bucket is the whole ballgame.

    * ``pow2_ladder`` — the PR-3/4 default storage: a full power-of-two
      ladder ending in a ``max_deg``-wide tail bucket (many compile
      shapes, tail launch dominated by one row's unroll).
    * ``tail_ladder`` — the equal-compile-shape-budget baseline
      ``(2, ..., W_cap, max_deg)``: what capping the ladder *without*
      splitting costs — every row wider than ``W_cap`` pays ``max_deg``
      slots.  This is the ``>= 2x`` acceptance comparison.
    * ``split`` — virtual rows at ``W_cap`` + segmented stage-2 combine:
      the widest compiled width becomes ``W_cap`` regardless of skew.

    Sweep timing runs at ``sweep_cap`` only and records cold (trace +
    compile) and warm times separately: the baselines' tail-bucket
    launch pays a ``max_deg``-slot trace (the launch this PR deletes —
    minutes of wall time at real skew), while warm sweeps at feature
    dim 1 are launch-overhead bound for every layout, so the win lives
    in the trace term.  Too slow to repeat per cap.
    """
    from repro.apps import pagerank
    edges = zipf_edges(nv, alpha=2.0, max_deg=None, seed=0)
    w = _pagerank_weights(nv, edges)
    g0 = pagerank.make_graph(edges, nv)          # PR-3/4 default storage
    entry = {
        "graph": name, "nv": nv, "n_edges": int(g0.n_edges),
        "max_deg": int(g0.max_deg),
        "pow2_ladder_widths": list(g0.ell.widths),
        "pow2_ladder_slots": int(g0.ell.padded_slots),
        "caps": [],
    }
    x = g0.vertex_data["rank"][:, None].astype(jnp.float32)
    for w_cap in w_caps:
        gs = pagerank.make_graph(edges, nv, w_cap=w_cap)
        assert gs.ell.is_split, (w_cap, g0.max_deg)
        tail = tuple(default_bucket_widths(w_cap)) + (g0.max_deg,)
        gb = DataGraph.from_edges(
            nv, edges, {"rank": np.ones(nv, np.float32)}, {"w": w},
            bucket_widths=tail)
        row = {
            "w_cap": int(w_cap),
            "split_widths": list(gs.ell.widths),
            "widest_compiled_width": int(gs.ell.widths[-1]),
            "n_virtual": int(gs.ell.n_virtual),
            "split_slots": int(gs.ell.padded_slots),
            "tail_ladder_slots": int(gb.ell.padded_slots),
            "slot_reduction_vs_tail_ladder": round(
                gb.ell.padded_slots / max(gs.ell.padded_slots, 1), 2),
            "slot_reduction_vs_pow2_ladder": round(
                g0.ell.padded_slots / max(gs.ell.padded_slots, 1), 2),
        }
        if w_cap == sweep_cap:
            fns = {"split": _bucketed_sweep_fn(gs),
                   "tail_ladder": _bucketed_sweep_fn(gb),
                   "pow2_ladder": _bucketed_sweep_fn(g0)}
            for key, f in fns.items():
                t0 = time.perf_counter()
                jax.block_until_ready(f(x))
                row[f"trace_{key}_s"] = round(time.perf_counter() - t0, 2)
            ys = fns["split"](x)
            # split-vs-unsplit reassociates the hub row's ~max_deg-term
            # float32 sum (chunk partials then combine), so the hub
            # element drifts a few ulp more than same-width launches —
            # rtol covers it; engine parity stays bitwise per path (§10)
            for key in ("tail_ladder", "pow2_ladder"):
                np.testing.assert_allclose(np.asarray(ys),
                                           np.asarray(fns[key](x)),
                                           rtol=1e-4, atol=1e-7)
            for key, f in fns.items():
                row[f"sweep_{key}_us"] = round(time_fn(f, x), 1)
            # warm sweeps at d=1 run the same number of launches per
            # layout (plus split's stage-2 scatter), so the slot win is
            # invisible warm; the tail bucket's cost is its max_deg-slot
            # trace, so compare wall time = trace + warm sweep.
            for key in ("tail_ladder", "pow2_ladder"):
                row[f"wall_speedup_vs_{key}"] = round(
                    (row[f"trace_{key}_s"] + 1e-6 * row[f"sweep_{key}_us"])
                    / max(row["trace_split_s"]
                          + 1e-6 * row["sweep_split_us"], 1e-9), 1)
            emit(f"graph_storage_{name}_wcap{w_cap}_sweep_split",
                 row["sweep_split_us"],
                 f"trace={row['trace_split_s']}s;"
                 f"wall_x{row['wall_speedup_vs_tail_ladder']}_vs_tail_ladder")
        entry["caps"].append(row)
        emit(f"graph_storage_{name}_wcap{w_cap}_slots",
             float(row["split_slots"]),
             f"x{row['slot_reduction_vs_tail_ladder']}_vs_tail_ladder;"
             f"widest={row['widest_compiled_width']}")
    return entry


def _bench_build(ne_target: int) -> dict:
    """Vectorized vs loop ELL build on a large uniform edge list."""
    nv = max(ne_target // 10, 16)
    edges = _uniform_edges(nv, ne_target, seed=1)
    deg = np.zeros(nv, dtype=np.int64)
    for col in (0, 1):
        np.add.at(deg, edges[:, col], 1)
    md = max(int(deg.max()), 1)
    t0 = time.perf_counter()
    vec = _build_ell_vectorized(nv, edges, md)
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    loop = _build_ell_loop(nv, edges, md)
    t_loop = time.perf_counter() - t0
    for a, b in zip(vec, loop):       # identical output, not just faster
        assert np.array_equal(a, b)
    emit("graph_build_loop", t_loop * 1e6, f"ne={len(edges)}")
    emit("graph_build_vectorized", t_vec * 1e6,
         f"ne={len(edges)};x{t_loop / max(t_vec, 1e-9):.1f}")
    return {
        "n_edges": int(len(edges)), "nv": nv,
        "build_loop_us": round(t_loop * 1e6, 1),
        "build_vectorized_us": round(t_vec * 1e6, 1),
        "build_speedup": round(t_loop / max(t_vec, 1e-9), 2),
    }


def run() -> None:
    if common.SMOKE:
        nv_zipf, cap, nv_uni, ne_uni, ne_build = 400, 32, 300, 900, 20_000
        w_caps, sweep_cap = (8, 16), 16
    else:
        nv_zipf, cap, nv_uni, ne_uni, ne_build = 10_000, 192, 5_000, \
            20_000, 1_000_000
        w_caps, sweep_cap = (16, 32, 64), 64
    if common.W_CAPS:
        w_caps, sweep_cap = tuple(common.W_CAPS), max(common.W_CAPS)
    entry = {
        "bench": "graph_storage",
        "smoke": common.SMOKE,
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "graphs": [
            _bench_graph("uniform", nv_uni,
                         _uniform_edges(nv_uni, ne_uni, seed=2)),
            _bench_graph("zipf", nv_zipf,
                         zipf_edges(nv_zipf, alpha=2.0, max_deg=cap,
                                    seed=0)),
        ],
        "build": _bench_build(ne_build),
        "hub_split": _bench_split("zipf_unclipped", nv_zipf, w_caps,
                                  sweep_cap),
    }
    zipf = entry["graphs"][1]
    head = [c for c in entry["hub_split"]["caps"]
            if c["w_cap"] == sweep_cap][0]
    # tail-bucket elimination holds at every cap, every size
    for c in entry["hub_split"]["caps"]:
        assert c["widest_compiled_width"] == c["w_cap"], c
    if not common.SMOKE:
        # the PR's acceptance criteria, enforced at record time
        assert zipf["skew_max_over_mean"] >= 32, zipf
        assert zipf["slot_reduction"] >= 4, zipf
        # hub splitting (ISSUE 6): >= 2x fewer slots than the bucketed
        # baseline with the same compile-shape budget, and the sweep no
        # longer pays the max_deg tail-bucket launch.  That launch costs
        # minutes of trace time at real skew, so the wall-time win is in
        # the trace term; warm sweeps at d=1 are launch-overhead bound,
        # so only bound the stage-2 scatter's warm regression.
        assert head["w_cap"] <= 64, head
        assert head["slot_reduction_vs_tail_ladder"] >= 2.0, head
        assert head["wall_speedup_vs_tail_ladder"] >= 10, head
        assert head["wall_speedup_vs_pow2_ladder"] >= 10, head
        assert head["sweep_split_us"] < 3 * head["sweep_tail_ladder_us"], head
    _RESULTS.mkdir(exist_ok=True)
    path = _RESULTS / "BENCH_graph.json"
    history = json.loads(path.read_text()) if path.exists() else []
    history.append(entry)
    path.write_text(json.dumps(history, indent=2) + "\n")
