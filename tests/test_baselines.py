"""Baseline equivalences (paper §6.2 comparisons are apples-to-apples)."""
import numpy as np
import jax

from repro.apps import als, coem
from repro.core import ChromaticEngine
from repro.baselines.mapreduce import als_mapreduce, coem_mapreduce
from repro.baselines.mpi_als import als_mpi


def test_mapreduce_als_matches_chromatic_trajectory():
    """Non-adaptive chromatic ALS (eps=0 -> full sweeps) computes exactly
    the Mahout-style alternating MR jobs.  With the color order aligned to
    the MR job order (movies first), the *trajectories* coincide to float
    precision — the two programming models run the same algorithm, the
    paper's apples-to-apples premise."""
    prob = als.synthetic_netflix(25, 20, d=3, density=0.4, noise=0.05,
                                 seed=4)
    colors = 1 - np.asarray(prob.graph.colors)   # movies = color 0
    g = prob.graph.with_colors(colors)
    eng = ChromaticEngine(g, als.make_update(3, lam=0.02, eps=0.0),
                          max_supersteps=6)
    st = eng.run(num_supersteps=6)
    out, stats = als_mapreduce(prob, 6, lam=0.02)
    w_eng = np.asarray(st.vertex_data["w"])
    w_mr = np.concatenate([np.asarray(out["w_users"]),
                           np.asarray(out["w_movies"])])
    np.testing.assert_allclose(w_eng, w_mr, atol=1e-4)
    assert stats.bytes_shuffled_per_iter > 0


def test_mapreduce_message_volume_exceeds_graphlab_ghost_volume():
    """The paper's core traffic argument: MR materializes a message per
    edge per iteration; GraphLab moves only boundary (ghost) vertices."""
    from repro.core import ShardPlan, two_phase_partition
    prob = als.synthetic_netflix(40, 30, d=4, density=0.3, seed=1)
    g = prob.graph
    _, stats = als_mapreduce(prob, 1)
    asg = two_phase_partition(g.n_vertices, g.edges_np, 4, seed=0)
    plan = ShardPlan.build(g, asg, 4)
    # ghost traffic per superstep: one (d,)-vector per ghosted vertex
    ghost_rows = int(np.asarray(plan.send_mask).sum())
    ghost_bytes = ghost_rows * prob.d * 4
    assert ghost_bytes < stats.bytes_shuffled_per_iter


def test_mpi_als_matches_mapreduce():
    prob = als.synthetic_netflix(25, 20, d=3, density=0.4, seed=5)
    out, _ = als_mapreduce(prob, 10, lam=0.02)
    wU, wV, info = als_mpi(prob, 10, lam=0.02)
    np.testing.assert_allclose(np.asarray(out["w_users"]), wU,
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(out["w_movies"]), wV,
                               rtol=1e-3, atol=1e-3)


def test_mapreduce_coem_reaches_same_accuracy():
    prob = coem.synthetic_ner(120, 80, 3, mean_deg=8, seed_frac=0.15,
                              seed=1)
    eng = ChromaticEngine(prob.graph, coem.make_update(0.0),
                          max_supersteps=30)
    st = eng.run(num_supersteps=30)
    out, _ = coem_mapreduce(prob, 30)
    acc_eng = coem.label_accuracy(prob, st.vertex_data)
    acc_mr = coem.label_accuracy(prob, {"p": out["p"]})
    assert abs(acc_eng - acc_mr) < 0.05
