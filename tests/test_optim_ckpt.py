"""Optimizer + checkpoint round-trip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.train import checkpoint as ck


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray(2.0)}
    cfg = adamw.AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, grad_clip=10.0)
    state = adamw.init(params)
    def loss_fn(p):
        return (p["w"] ** 2).sum() + p["b"] ** 2
    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        params, state, _ = adamw.update(cfg, g, state, params)
    assert float(loss_fn(params)) < 1e-2


def test_adamw_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(adamw.schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(adamw.schedule(cfg, jnp.asarray(100))) <= 0.1 + 1e-6


def test_grad_clip_applied():
    params = {"w": jnp.asarray([1.0])}
    cfg = adamw.AdamWConfig(lr=0.1, grad_clip=1.0, weight_decay=0.0,
                            warmup_steps=0)
    state = adamw.init(params)
    _, _, mets = adamw.update(cfg, {"w": jnp.asarray([1e6])}, state, params)
    assert float(mets["grad_norm"]) > 1e5   # reported pre-clip


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.asarray([1.5, 2.5])}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        ck.save(path, tree, step=7)
        restored, step = ck.restore(path, tree)
        assert step == 7
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))


def test_engine_state_snapshot():
    """The paper §8 sketch: consistent snapshots via the sync barrier."""
    import numpy as np
    from repro.apps import pagerank
    from repro.core import ChromaticEngine
    from conftest import random_graph
    edges = random_graph(20, 40, seed=2)
    g = pagerank.make_graph(edges, 20)
    eng = ChromaticEngine(g, pagerank.make_update(1e-5), max_supersteps=5)
    st = eng.run(num_supersteps=5)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "snap.npz")
        ck.snapshot_engine_state(path, st)
        restored, step = ck.restore(path, {
            "vertex_data": st.vertex_data, "edge_data": st.edge_data,
            "active": st.active, "priority": st.priority})
        assert step == 5
        np.testing.assert_array_equal(
            np.asarray(restored["vertex_data"]["rank"]),
            np.asarray(st.vertex_data["rank"]))
