"""Optimizer + checkpoint round-trip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.train import checkpoint as ck


@pytest.mark.parametrize("engine", ["chromatic", "locking"])
def test_snapshot_engine_state_resume_bit_identical(tmp_path, engine):
    """§8 consistent snapshot: snapshot mid-run, restore, and the
    resumed run must be bit-identical to the uninterrupted one —
    including the task set, priorities, sync results, and counters."""
    from repro.apps import pagerank
    from repro.core import ChromaticEngine, LockingEngine
    from conftest import random_graph

    edges = random_graph(40, 90, seed=7)
    g = pagerank.make_graph(edges, 40)
    upd = pagerank.make_update(1e-5)
    syncs = [pagerank.total_rank_sync()]
    if engine == "chromatic":
        eng = ChromaticEngine(g, upd, syncs=syncs, max_supersteps=100)
    else:
        eng = LockingEngine(g, upd, syncs=syncs, max_pending=8,
                            max_supersteps=5000)

    full = eng.run(num_supersteps=10)                    # uninterrupted

    half = eng.run(num_supersteps=5)
    path = str(tmp_path / "mid.npz")
    ck.snapshot_engine_state(path, half)
    restored = ck.restore_engine_state(path, eng.init_state())
    assert int(restored.superstep) == 5
    resumed = eng.resume(restored, num_supersteps=5)

    assert int(resumed.superstep) == int(full.superstep)
    assert int(resumed.n_updates) == int(full.n_updates)
    for key in full.vertex_data:
        assert np.array_equal(np.asarray(resumed.vertex_data[key]),
                              np.asarray(full.vertex_data[key])), key
    assert np.array_equal(np.asarray(resumed.active),
                          np.asarray(full.active))
    assert np.array_equal(np.asarray(resumed.priority),
                          np.asarray(full.priority))
    for key in full.globals:
        assert np.array_equal(np.asarray(jax.tree.leaves(full.globals[key])),
                              np.asarray(jax.tree.leaves(resumed.globals[key]))), key


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray(2.0)}
    cfg = adamw.AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, grad_clip=10.0)
    state = adamw.init(params)
    def loss_fn(p):
        return (p["w"] ** 2).sum() + p["b"] ** 2
    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        params, state, _ = adamw.update(cfg, g, state, params)
    assert float(loss_fn(params)) < 1e-2


def test_adamw_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(adamw.schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(adamw.schedule(cfg, jnp.asarray(100))) <= 0.1 + 1e-6


def test_grad_clip_applied():
    params = {"w": jnp.asarray([1.0])}
    cfg = adamw.AdamWConfig(lr=0.1, grad_clip=1.0, weight_decay=0.0,
                            warmup_steps=0)
    state = adamw.init(params)
    _, _, mets = adamw.update(cfg, {"w": jnp.asarray([1e6])}, state, params)
    assert float(mets["grad_norm"]) > 1e5   # reported pre-clip


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.asarray([1.5, 2.5])}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        ck.save(path, tree, step=7)
        restored, step = ck.restore(path, tree)
        assert step == 7
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))


def test_engine_state_snapshot():
    """The paper §8 sketch: consistent snapshots via the sync barrier."""
    import numpy as np
    from repro.apps import pagerank
    from repro.core import ChromaticEngine
    from conftest import random_graph
    edges = random_graph(20, 40, seed=2)
    g = pagerank.make_graph(edges, 20)
    eng = ChromaticEngine(g, pagerank.make_update(1e-5), max_supersteps=5)
    st = eng.run(num_supersteps=5)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "snap.npz")
        ck.snapshot_engine_state(path, st)
        restored, step = ck.restore(path, {
            "vertex_data": st.vertex_data, "edge_data": st.edge_data,
            "active": st.active, "priority": st.priority})
        assert step == 5
        np.testing.assert_array_equal(
            np.asarray(restored["vertex_data"]["rank"]),
            np.asarray(st.vertex_data["rank"]))
