"""Locking engine (paper §4.2.2): claim-algebra conflict resolution,
the max_pending lock pipeline, versioned ghost sync, and single-shard /
multi-shard equivalence.

The in-process tests run on one CPU device (the M=1 plan is the
degenerate case: every collective is an identity).  The 8-virtual-device
equivalence runs in a subprocess because XLA_FLAGS device-count must be
set before jax initializes; it is marked ``distributed`` so the CI
matrix can give it a real multi-device job.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import pagerank
from repro.core import (ChromaticEngine, Consistency, DistributedLockingEngine,
                        LockingEngine, ShardPlan, UpdateFn, UpdateResult,
                        run_sequential)
from repro.core.graph import DataGraph
from conftest import random_graph


def _graph(nv=40, ne=90, seed=1):
    return pagerank.make_graph(random_graph(nv, ne, seed=seed), nv)


def test_locking_engine_needs_no_coloring():
    """§4.2.2: the locking engine generalizes to graphs where coloring
    is unavailable — same fixed point as the chromatic engine."""
    edges = random_graph(40, 90, seed=5)
    g_colored = pagerank.make_graph(edges, 40)
    # recycle the colored graph's edge data: its rows follow the
    # bucket-major renumbering, so pair them with edges_np (same order)
    g_plain = DataGraph.from_edges(
        40, g_colored.edges_np,
        {"rank": np.asarray(g_colored.vertex_data["rank"])},
        {"w": np.asarray(g_colored.edge_data["w"])[:-1]})
    upd = pagerank.make_update(1e-6)
    chrom = ChromaticEngine(g_colored, upd, max_supersteps=300).run()
    lock = LockingEngine(g_plain, upd, max_pending=16,
                         max_supersteps=20000).run()
    assert not bool(lock.active.any()), "locking engine must drain"
    np.testing.assert_allclose(np.asarray(lock.vertex_data["rank"]),
                               np.asarray(chrom.vertex_data["rank"]),
                               atol=2e-5)


def test_max_pending_one_is_strictly_sequential():
    """P=1: one scope in flight — exactly one update per superstep."""
    g = _graph()
    st = LockingEngine(g, pagerank.make_update(1e-4), max_pending=1,
                       max_supersteps=20000).run()
    assert not bool(st.active.any())
    assert int(st.n_updates) == int(st.superstep)


def test_winner_batches_are_conflict_free():
    """EDGE winners are an independent set; FULL winners have disjoint
    scopes — checked directly on the claim primitives."""
    from repro.core import claim_winners, scope_claims
    from repro.core.exec import adjacent_claim_winners, self_claims
    g = _graph(30, 70, seed=2)
    adj = g.adjacency_lists
    ids = jnp.arange(30, dtype=jnp.int32)
    sel = jnp.ones(30, bool)
    win_edge = np.asarray(adjacent_claim_winners(
        g, ids, sel, self_claims(g, ids, sel)))
    winners = np.nonzero(win_edge)[0]
    assert len(winners) > 1
    wset = set(winners.tolist())
    for v in winners:
        assert not (set(adj[v]) & wset), "EDGE winners must be independent"
    win_full = np.asarray(claim_winners(g, ids, sel,
                                        scope_claims(g, ids, sel)))
    scopes = [set(adj[v]) | {int(v)} for v in np.nonzero(win_full)[0]]
    for i in range(len(scopes)):
        for j in range(i + 1, len(scopes)):
            assert not (scopes[i] & scopes[j]), "FULL scopes must be disjoint"
    # FULL is strictly more exclusive than EDGE
    assert win_full.sum() <= win_edge.sum()
    assert win_full.sum() >= 1, "min-id candidate must always win"


def _neighbor_writer():
    """FULL-consistency update: pushes value onto neighbors."""
    def update(scope):
        push = scope.v_data["x"][:, None] * 0.5
        new_nbr = jnp.where(scope.nbr_mask, scope.nbr_data["x"] + push,
                            scope.nbr_data["x"])
        return UpdateResult(v_data={"x": scope.v_data["x"] + 1.0},
                            nbr_data={"x": new_nbr})
    return UpdateFn(update, Consistency.FULL, name="pusher")


def test_locking_full_consistency_matches_oracle():
    """Scope-disjoint winners make neighbor-writing updates safe without
    a distance-2 coloring (the chromatic engine needs one)."""
    edges = random_graph(20, 40, seed=1)
    x0 = np.arange(20, dtype=np.float32)
    g = DataGraph.from_edges(20, edges, {"x": x0})
    upd = _neighbor_writer()
    eng = LockingEngine(g, upd, max_pending=20, max_supersteps=50)
    st = eng.run(num_supersteps=8)
    vd, *_rest, n_seq = run_sequential(g, upd, max_supersteps=8,
                                       locking_pending=20)
    np.testing.assert_allclose(np.asarray(st.vertex_data["x"]),
                               np.asarray(vd["x"]), rtol=1e-6)
    assert int(st.n_updates) == n_seq


def test_lbp_residual_locking_wiring():
    """CoSeg under the locking engine (the paper's §5.2 adaptive
    schedule): residual priorities drive the window, GMM sync included."""
    from repro.apps import lbp
    prob = lbp.synthetic_coseg(2, 3, 4, n_labels=3, noise=0.3, seed=0)
    eng = lbp.residual_locking_engine(prob, eps=1e-2, max_pending=8,
                                      max_supersteps=5000)
    st = eng.run()
    assert not bool(st.active.any())
    assert "gmm" in st.globals
    assert lbp.label_accuracy(prob, st.vertex_data) > 0.8


def test_distributed_full_consistency_rejected_across_shards():
    """FULL neighbor writes land on ghost rows with no backflow channel;
    the distributed engine must refuse rather than silently diverge."""
    g = _graph(20, 40, seed=3)
    plan2 = ShardPlan.build(g, np.arange(20, dtype=np.int64) % 2, 2)
    with pytest.raises(ValueError, match="FULL"):
        DistributedLockingEngine(g, plan2, _neighbor_writer())
    # the single-shard degenerate case stays allowed
    plan1 = ShardPlan.build(g, np.zeros(20, np.int64), 1)
    DistributedLockingEngine(g, plan1, _neighbor_writer())


def test_single_shard_plan_is_bitwise_degenerate():
    """DistributedLockingEngine on an M=1 plan == LockingEngine
    bit-for-bit (every collective is an identity), including with a
    *binding* pipeline window."""
    g = _graph(40, 90, seed=1)
    upd = pagerank.make_update(1e-5)
    single = LockingEngine(g, upd, max_pending=8, max_supersteps=5000).run()
    plan = ShardPlan.build(g, np.zeros(40, np.int64), 1)
    dist = DistributedLockingEngine(g, plan, upd, max_pending=8,
                                    max_supersteps=5000).run()
    assert dist["supersteps"] == int(single.superstep)
    assert dist["n_updates"] == int(single.n_updates)
    assert np.array_equal(np.asarray(single.vertex_data["rank"]),
                          np.asarray(dist["vertex_data"]["rank"]))
    # no ghosts on one shard: the versioned sync moves nothing
    assert dist["ghost_rows_sent"] == 0
    assert dist["ghost_rows_full"] == 0


# ----------------------------------------------------------------------
# 8-virtual-device equivalence (subprocess: XLA_FLAGS before jax import)
# ----------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.apps import lbp, pagerank
    from repro.core import (DistributedLockingEngine, LockingEngine,
                            ShardPlan, two_phase_partition)

    out = {}

    # --- PageRank, 8 shards: saturating window -> bit-identical ---
    rng = np.random.default_rng(1)
    nv = 80
    edges = set()
    while len(edges) < 200:
        u, v = rng.integers(0, nv, 2)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    edges = np.array(sorted(edges))
    g = pagerank.make_graph(edges, nv)
    upd = pagerank.make_update(1e-4)
    syncs = [pagerank.total_rank_sync()]
    single = LockingEngine(g, upd, syncs=syncs, max_pending=nv,
                           max_supersteps=3000).run()
    plan = ShardPlan.build(g, two_phase_partition(nv, edges, 8, seed=0), 8)
    dist = DistributedLockingEngine(g, plan, upd, syncs=syncs,
                                    max_pending=plan.R,
                                    max_supersteps=3000).run()
    out["pr_equal"] = bool(np.array_equal(
        np.asarray(single.vertex_data["rank"]),
        np.asarray(dist["vertex_data"]["rank"])))
    out["pr_updates"] = [int(single.n_updates), dist["n_updates"]]
    out["pr_supersteps"] = [int(single.superstep), dist["supersteps"]]
    out["pr_ghost_sent"] = dist["ghost_rows_sent"]
    out["pr_ghost_full"] = dist["ghost_rows_full"]

    # --- LBP with cut-edge writes (CoSeg), versioned edge sync ---
    pl = lbp.synthetic_coseg(4, 3, 4, n_labels=3, noise=0.5)
    updl = lbp.make_update(3, eps=1e-2, use_gmm_sync=False)
    stl = LockingEngine(pl.graph, updl, max_pending=pl.graph.n_vertices,
                        max_supersteps=3000).run()
    planl = ShardPlan.build(pl.graph, lbp.frame_partition(pl, 8), 8)
    resl = DistributedLockingEngine(pl.graph, planl, updl,
                                    max_pending=planl.R,
                                    max_supersteps=3000,
                                    exchange_edges=True).run()
    out["lbp_maxdiff"] = float(np.abs(
        np.asarray(stl.vertex_data["belief"])
        - np.asarray(resl["vertex_data"]["belief"])).max())
    out["lbp_updates"] = [int(stl.n_updates), resl["n_updates"]]
    out["lbp_supersteps"] = [int(stl.superstep), resl["supersteps"]]

    # --- binding per-shard window: still converges to the fixed point ---
    from repro.core import ChromaticEngine
    chrom = ChromaticEngine(g, pagerank.make_update(1e-6),
                            max_supersteps=300).run()
    dist_small = DistributedLockingEngine(
        g, plan, pagerank.make_update(1e-6), max_pending=4,
        max_supersteps=20000).run()
    out["pipeline_drained"] = not dist_small["active_any"]
    out["pipeline_maxdiff"] = float(np.abs(
        np.asarray(chrom.vertex_data["rank"])
        - np.asarray(dist_small["vertex_data"]["rank"])).max())

    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def lock_dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.distributed
def test_distributed_locking_pagerank_bitwise_equal(lock_dist_results):
    r = lock_dist_results
    assert r["pr_equal"]
    assert r["pr_updates"][0] == r["pr_updates"][1]
    assert r["pr_supersteps"][0] == r["pr_supersteps"][1]


@pytest.mark.distributed
def test_versioned_ghost_sync_filters_traffic(lock_dist_results):
    """The paper's "only transmit modified data": the version filter
    must ship strictly less than the static every-round schedule."""
    r = lock_dist_results
    assert r["pr_ghost_full"] > 0
    assert 0 < r["pr_ghost_sent"] < r["pr_ghost_full"]


@pytest.mark.distributed
def test_distributed_locking_lbp_edge_exchange(lock_dist_results):
    r = lock_dist_results
    assert r["lbp_maxdiff"] < 1e-4
    assert r["lbp_updates"][0] == r["lbp_updates"][1]
    assert r["lbp_supersteps"][0] == r["lbp_supersteps"][1]


@pytest.mark.distributed
def test_distributed_locking_pipelined_window_converges(lock_dist_results):
    r = lock_dist_results
    assert r["pipeline_drained"]
    assert r["pipeline_maxdiff"] < 2e-5
