"""Trace-driven cost model (DESIGN.md §11).

Contracts asserted here:

1. **Fit correctness + monotonicity** — ``fit_cost_model`` recovers a
   planted linear law exactly, and every fitted curve is monotone
   non-decreasing in the padded slot count ``B * W`` (the clamp that
   keeps a noisy trace from inverting the dispatch crossover).  The
   hypothesis sweep of the same property lives in
   ``test_profile_properties.py`` (optional dep, skips cleanly).
2. **Zero-trace fallback is bitwise** — an empty model predicts
   ``None`` everywhere and ``choose_dispatch`` with it reproduces the
   static slot-count choices exactly, over a pinned grid.
3. **The dispatcher stays invisible** — ``dispatch="auto"`` under ANY
   cost model (including ones that force each pick) is bit-identical
   to the forced modes; the model moves the crossover, never results.
4. **One error surface** — ``choose_dispatch`` and ``validate_dispatch``
   raise the same text for an unknown mode (satellite: engines and the
   facade funnel through one validator).
5. **Persistence + resolution** — COSTMODEL save/load roundtrip,
   ``REPRO_RESULTS_DIR`` redirection, and every ``cost_model=`` spec
   form ``resolve_cost_model`` accepts.
6. **Calibration smoke** — a tiny ``repro.profile.calibrate`` run fits
   real widths and carries HLO op counts in the shared trace schema.
7. **Measured width policy** — ``width_policy="measured"`` picks a
   hub-split ladder when the model prices wide launches out, and falls
   back to the pow2 default (structurally unchanged) with no model.
8. **Partition objective** — ``predicted_step_time`` ranks a balanced
   partition ahead of degenerate ones, and candidate selection in
   ``two_phase_partition`` never returns a worse-scoring assignment.
9. **Plugin discovery** — ``repro.schedulers`` / ``repro.cost_models``
   entry points resolve through the registry (monkeypatched iterator,
   no package installation).
"""
import json
import types

import numpy as np
import pytest

from repro import api
from repro.apps import pagerank
from repro.core import ChromaticEngine, PriorityEngine
from repro.core.exec import choose_dispatch, validate_dispatch
from repro.core.graph import (DataGraph, candidate_width_plans,
                              choose_width_plan, zipf_edges)
from repro.core import registry
from repro.core.partition import (ghost_rows, predicted_step_time,
                                  random_partition, shard_bucket_launches,
                                  two_phase_partition)
from repro.profile import (CostModel, TraceRecorder, fit_cost_model,
                           load_cost_model, load_trace, resolve_cost_model)
from conftest import random_graph


def _launch(width, rows, wall_us, **kw):
    return {"kind": "launch", "mode": "batch", "width": width,
            "rows": rows, "wall_us": wall_us, **kw}


def _linear_records(coef, batch_sizes=(4, 16, 64, 256)):
    """Noise-free records obeying ``t = a_W + b_W * B * W`` exactly."""
    return [_launch(w, b, a + bb * b * w)
            for w, (a, bb) in coef.items() for b in batch_sizes]


# ----------------------------------------------------------------------
# 1. fit correctness + monotonicity
# ----------------------------------------------------------------------

def test_fit_recovers_planted_linear_law():
    planted = {4: (120.0, 0.02), 16: (150.0, 0.005), 64: (200.0, 0.001)}
    model = fit_cost_model(_linear_records(planted), device="testdev")
    assert sorted(model.coef) == [4, 16, 64]
    for w, (a, b) in planted.items():
        fa, fb = model.coef[w]
        np.testing.assert_allclose([fa, fb], [a, b], rtol=1e-8)
        np.testing.assert_allclose(model.predict(w, 32), a + b * 32 * w)
    assert model.pooled is not None
    # unmeasured width falls back to the pooled line, never None
    assert model.predict(8, 10) is not None
    assert model.n_records == len(_linear_records(planted))


def test_fit_is_monotone_in_slots_even_under_noise():
    """For ANY trace, fixed W: predict is non-decreasing in rows (the
    clamp collapses negative slopes to flat means)."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        records = []
        for w in (2, 8, 32):
            for b in (4, 16, 64, 256):
                # adversarial: pure noise, no signal at all
                records.append(_launch(w, b, float(rng.uniform(1, 1000))))
        model = fit_cost_model(records)
        for w in (2, 8, 32, 128):   # 128 exercises the pooled fallback
            ts = [model.predict(w, b) for b in (1, 4, 16, 64, 256, 4096)]
            assert all(t is not None and t >= 0 for t in ts), (seed, w)
            assert all(t1 - t0 >= -1e-9 for t0, t1 in zip(ts, ts[1:])), \
                (seed, w, ts)


def test_fit_ignores_cold_records_and_fits_sync_slope():
    records = _linear_records({8: (100.0, 0.01)})
    records.append(_launch(8, 4, 1e9, cold=True))   # compile-time outlier
    records += [{"kind": "sync", "rows": 100, "wall_us": 50.0 + 0.5 * 100},
                {"kind": "sync", "rows": 400, "wall_us": 50.0 + 0.5 * 400}]
    model = fit_cost_model(records)
    np.testing.assert_allclose(model.coef[8], (100.0, 0.01), rtol=1e-8)
    np.testing.assert_allclose(model.sync_cost_us, 0.5, rtol=1e-8)


# ----------------------------------------------------------------------
# 2. zero-trace fallback is bitwise
# ----------------------------------------------------------------------

def test_empty_model_predicts_none_and_keeps_static_choices():
    empty = CostModel()
    assert empty.predict(8, 4) is None
    assert empty.predict_launches([(8, 4)]) is None
    launches = ((2, 100), (8, 30), (32, 5))
    for b in (1, 8, 64, 512, 4096):
        for w in (2, 8, 32, 128):
            for slots in (64, 1024, 65536):
                static = choose_dispatch("auto", b, w, slots)
                assert choose_dispatch("auto", b, w, slots,
                                       cost_model=empty) == static
                assert choose_dispatch(
                    "auto", b, w, slots, cost_model=empty,
                    bucket_launches=launches) == static
                # forced modes ignore the model entirely
                for forced in ("bucket", "batch"):
                    assert choose_dispatch(forced, b, w, slots,
                                           cost_model=empty) == forced


def test_partial_model_falls_back_when_bucket_side_unknown():
    model = fit_cost_model(_linear_records({8: (10.0, 0.01)}))
    # no bucket_launches handed over -> bucket side unpredictable ->
    # static rule, even though the batch side has a fit
    assert (choose_dispatch("auto", 4, 8, 10_000, cost_model=model)
            == "batch")
    assert (choose_dispatch("auto", 4000, 8, 10_000, cost_model=model)
            == "bucket")


# ----------------------------------------------------------------------
# 3. any cost model is dispatcher-invisible (bitwise)
# ----------------------------------------------------------------------

class _Force:
    """A cost model that always prices one path cheaper."""

    def __init__(self, pick):
        self._batch_t = 1.0 if pick == "batch" else 2.0

    def predict(self, width, rows):
        return self._batch_t

    def predict_launches(self, launches):
        return 1.5


@pytest.mark.parametrize("pick", ["batch", "bucket"])
def test_forced_cost_model_picks_that_path(pick):
    assert choose_dispatch("auto", 7, 8, 100, cost_model=_Force(pick),
                           bucket_launches=((8, 10),)) == pick


@pytest.mark.parametrize("pick", ["batch", "bucket"])
def test_cost_model_is_bitwise_invisible(pick):
    """auto + a model that forces either pick == the forced mode's run,
    bit for bit — for a sweep engine and a windowed engine."""
    edges = zipf_edges(120, alpha=2.0, max_deg=32, seed=3)
    g = pagerank.make_graph(edges, 120)
    upd = pagerank.make_update(1e-6)
    ref_c = ChromaticEngine(g, upd, dispatch=pick, max_supersteps=200).run()
    got_c = ChromaticEngine(g, upd, dispatch="auto", cost_model=_Force(pick),
                            max_supersteps=200).run()
    ref_p = PriorityEngine(g, upd, dispatch=pick, k_select=16,
                           max_supersteps=4000).run()
    got_p = PriorityEngine(g, upd, dispatch="auto", cost_model=_Force(pick),
                           k_select=16, max_supersteps=4000).run()
    for ref, got in ((ref_c, got_c), (ref_p, got_p)):
        assert np.array_equal(np.asarray(got.vertex_data["rank"]),
                              np.asarray(ref.vertex_data["rank"]))
        assert int(got.n_updates) == int(ref.n_updates)
        assert int(got.superstep) == int(ref.superstep)


# ----------------------------------------------------------------------
# 4. one error surface for dispatch validation
# ----------------------------------------------------------------------

def test_choose_and_validate_dispatch_share_error_text():
    with pytest.raises(ValueError) as e1:
        validate_dispatch("bogus")
    with pytest.raises(ValueError) as e2:
        choose_dispatch("bogus", 8, 8, 100)
    assert str(e1.value) == str(e2.value)
    assert "expected one of" in str(e1.value)


# ----------------------------------------------------------------------
# 5. persistence + spec resolution
# ----------------------------------------------------------------------

def test_save_load_roundtrip_and_results_dir_env(tmp_path, monkeypatch):
    model = fit_cost_model(_linear_records({4: (10.0, 0.5)}),
                           device="testdev")
    model.sync_cost_us = 0.25
    path = model.save(tmp_path / "m.json")
    back = CostModel.load(path)
    assert back.coef == model.coef
    assert back.pooled == model.pooled
    assert back.sync_cost_us == model.sync_cost_us
    assert back.device == "testdev"
    # REPRO_RESULTS_DIR redirects the default artifact location
    monkeypatch.setenv("REPRO_RESULTS_DIR", str(tmp_path / "alt"))
    p2 = model.save()
    assert p2 == tmp_path / "alt" / "COSTMODEL_testdev.json"
    assert load_cost_model(device="testdev").coef == model.coef
    rec = TraceRecorder(device="testdev")
    rec.record_launch(mode="batch", width=4, rows=8, wall_us=12.0)
    tp = rec.save()
    assert tp == tmp_path / "alt" / "TRACE_testdev.json"
    back_rec = load_trace(tp)
    assert back_rec.device == "testdev"
    assert back_rec.records == rec.records


def test_resolve_cost_model_spec_forms(tmp_path, monkeypatch):
    model = fit_cost_model(_linear_records({4: (10.0, 0.5)}), device="t")
    assert resolve_cost_model(None) is None
    assert resolve_cost_model("static") is None
    assert resolve_cost_model(model) is model
    path = model.save(tmp_path / "COSTMODEL_t.json")
    assert resolve_cost_model(str(path)).coef == model.coef
    monkeypatch.setenv("REPRO_RESULTS_DIR", str(tmp_path / "nothing"))
    with pytest.raises(ValueError, match="calibrate"):
        resolve_cost_model("measured")
    with pytest.raises(ValueError, match="entry point"):
        resolve_cost_model("no-such-plugin")
    with pytest.raises(ValueError, match="cost_model must be"):
        resolve_cost_model(42)


# ----------------------------------------------------------------------
# 6. calibration smoke: real fits, shared HLO schema
# ----------------------------------------------------------------------

def test_calibrate_smoke_fits_widths_and_carries_hlo():
    from repro.profile.calibrate import calibrate
    recorder, model = calibrate(nv=120, cap=8, batch_sizes=(4, 8),
                                iters=1, with_hlo=True,
                                emit=lambda *_: None)
    assert model.coef, "no widths fitted"
    assert model.n_records > 0
    t = model.predict(max(model.coef), 8)
    assert t is not None and t > 0
    launches = [r for r in recorder.records if r["kind"] == "launch"]
    assert launches
    hlos = [r["hlo"] for r in launches if r.get("hlo")]
    assert hlos, "no launch carried HLO op counts"
    for h in hlos:
        assert set(h) >= {"flops", "hbm_bytes", "coll_bytes"}
        assert h["flops"] > 0
    # the recorded trace refits to the same model
    refit = fit_cost_model(recorder.records, device=model.device)
    assert refit.coef == model.coef
    assert refit.sync_cost_us == model.sync_cost_us


# ----------------------------------------------------------------------
# 7. measured width policy
# ----------------------------------------------------------------------

def _slot_counts(nv, edges):
    deg = np.zeros(nv, dtype=np.int64)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    return deg


def test_candidate_width_plans_conserve_rows():
    nv = 150
    edges = zipf_edges(nv, alpha=2.0, max_deg=48, seed=9)
    cnt = _slot_counts(nv, edges)
    plans = candidate_width_plans(cnt, int(cnt.max()))
    assert plans[0]["hub_split"] is False
    assert sum(r for _, r in plans[0]["launches"]) == nv
    for plan in plans[1:]:
        cap = plan["w_cap"]
        assert plan["hub_split"] and plan["widths"][-1] == cap
        # every row contributes ceil(slots / cap) chunks (min 1)
        expect = int(np.maximum(1, -(-cnt // cap)).sum())
        assert sum(r for _, r in plan["launches"]) == expect


def test_measured_width_policy_splits_when_wide_is_priced_out():
    nv = 150
    edges = zipf_edges(nv, alpha=2.0, max_deg=48, seed=9)
    vdata = {"x": np.zeros(nv, np.float32)}
    edata = {"w": np.ones(len(edges), np.float32)}
    # wide launches cost 1e9, narrow ones ~their slot count
    wide_hostile = CostModel(coef={w: ((1e9, 0.0) if w > 8 else (0.0, 1.0))
                                   for w in (2, 4, 8, 16, 32, 64)},
                             pooled=(1e9, 0.0))
    g = DataGraph.from_edges(nv, edges, vdata, edata,
                             width_policy="measured",
                             cost_model=wide_hostile)
    assert g.ell.is_split and g.ell.widths[-1] <= 8
    cnt = _slot_counts(nv, edges)
    plan = choose_width_plan(cnt, int(cnt.max()), wide_hostile)
    assert plan["hub_split"] and plan["w_cap"] == g.ell.w_cap
    # the split build still computes the same answers
    upd = pagerank.make_update(1e-6)
    gp = pagerank.make_graph(edges, nv)
    gm = pagerank.make_graph(edges, nv, w_cap=g.ell.w_cap)
    a = ChromaticEngine(gp, upd, max_supersteps=200).run()
    b = ChromaticEngine(gm, upd, max_supersteps=200).run()
    np.testing.assert_allclose(np.asarray(a.vertex_data["rank"]),
                               np.asarray(b.vertex_data["rank"]),
                               rtol=1e-6, atol=1e-7)


def test_measured_width_policy_without_model_is_pow2_default(
        tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RESULTS_DIR", str(tmp_path))  # no model file
    nv = 80
    edges = zipf_edges(nv, alpha=2.0, max_deg=16, seed=2)
    vdata = {"x": np.zeros(nv, np.float32)}
    edata = {"w": np.ones(len(edges), np.float32)}
    g_meas = DataGraph.from_edges(nv, edges, vdata, edata,
                                  width_policy="measured")
    g_def = DataGraph.from_edges(nv, edges, vdata, edata)
    assert g_meas.ell.widths == g_def.ell.widths
    assert g_meas.ell.is_split == g_def.ell.is_split
    # an empty model also falls back (choose_width_plan -> None)
    assert choose_width_plan(_slot_counts(nv, edges), 16,
                             CostModel()) is None


def test_width_policy_validation_errors():
    nv, edges = 20, np.array([[0, 1], [1, 2]])
    vdata = {"x": np.zeros(nv, np.float32)}
    edata = {"w": np.ones(2, np.float32)}
    with pytest.raises(ValueError, match="width_policy"):
        DataGraph.from_edges(nv, edges, vdata, edata, width_policy="bogus")
    with pytest.raises(ValueError):
        DataGraph.from_edges(nv, edges, vdata, edata,
                             cost_model=CostModel())
    with pytest.raises(ValueError):
        DataGraph.from_edges(nv, edges, vdata, edata,
                             width_policy="measured", w_cap=8)


# ----------------------------------------------------------------------
# 8. partition objective
# ----------------------------------------------------------------------

def _zipf_partition_setup(nv=600, cap=48, n_machines=4):
    edges = zipf_edges(nv, alpha=2.0, max_deg=cap, seed=0)
    return edges, _slot_counts(nv, edges), n_machines


def test_predicted_step_time_prefers_balanced_partitions():
    edges, degrees, m = _zipf_partition_setup()
    nv = len(degrees)
    model = CostModel(pooled=(1.0, 0.1), sync_cost_us=0.01)
    balanced = random_partition(nv, m, seed=0)
    one_machine = np.zeros(nv, dtype=np.int64)
    rng = np.random.default_rng(1)
    skewed = rng.choice(m, size=nv, p=[0.85, 0.05, 0.05, 0.05])
    t_bal = predicted_step_time(balanced, degrees, edges, m, model)
    t_one = predicted_step_time(one_machine, degrees, edges, m, model)
    t_skew = predicted_step_time(skewed, degrees, edges, m, model)
    assert t_bal is not None
    # shard-uniform launches make imbalance a straight compute tax
    assert t_bal < t_skew < t_one
    # empty model -> unpredictable, callers keep the cut-edge objective
    assert predicted_step_time(balanced, degrees, edges, m,
                               CostModel()) is None


def test_shard_launches_and_ghosts_shapes():
    edges, degrees, m = _zipf_partition_setup(nv=200, cap=16)
    asg = random_partition(len(degrees), m, seed=3)
    launches = shard_bucket_launches(asg, degrees, m)
    assert launches and all(w > 0 and r > 0 for w, r in launches)
    widths = [w for w, _ in launches]
    assert widths == sorted(widths)
    ghosts = ghost_rows(asg, edges, m)
    assert ghosts.shape == (m,)
    assert ghosts.max() > 0        # a random cut always crosses machines


def test_two_phase_candidate_selection_never_worse():
    edges, degrees, m = _zipf_partition_setup(nv=300, cap=32)
    nv = len(degrees)
    model = CostModel(pooled=(5.0, 0.05), sync_cost_us=0.2)
    base = two_phase_partition(nv, edges, m, seed=0)
    picked = two_phase_partition(nv, edges, m, seed=0, cost_model=model,
                                 n_candidates=4)
    assert picked.shape == (nv,) and picked.max() < m
    t_base = predicted_step_time(base, degrees, edges, m, model)
    t_pick = predicted_step_time(picked, degrees, edges, m, model)
    assert t_pick <= t_base
    # n_candidates=1 short-circuits to the plain seed-0 build, bitwise
    same = two_phase_partition(nv, edges, m, seed=0, cost_model=model,
                               n_candidates=1)
    np.testing.assert_array_equal(same, base)


# ----------------------------------------------------------------------
# 9. plugin discovery through entry points (monkeypatched)
# ----------------------------------------------------------------------

def _fake_eps(monkeypatch, group, name, obj):
    real = registry._iter_entry_points

    def fake(g):
        if g == group:
            return (types.SimpleNamespace(name=name, load=lambda: obj),)
        return real(g)
    monkeypatch.setattr(registry, "_iter_entry_points", fake)


def test_scheduler_plugin_resolves_on_registry_miss(monkeypatch):
    def plugin_factory():
        return lambda graph, update_fn, syncs=None, **kw: ChromaticEngine(
            graph, update_fn, syncs=syncs or (), **kw)
    _fake_eps(monkeypatch, registry.SCHEDULER_PLUGIN_GROUP,
              "extplugin", plugin_factory)
    try:
        entry = registry.get_scheduler("extplugin")
        assert entry.name == "extplugin"
        assert "plugin" in entry.description
        g, upd = (pagerank.make_graph(random_graph(30, 60, seed=1), 30),
                  pagerank.make_update(1e-5))
        res = api.run(g, upd, scheduler="extplugin", max_supersteps=100)
        ref = api.run(g, upd, scheduler="chromatic", max_supersteps=100)
        assert np.array_equal(np.asarray(res.vertex_data["rank"]),
                              np.asarray(ref.vertex_data["rank"]))
    finally:
        registry._SCHEDULERS.pop("extplugin", None)


def test_unknown_scheduler_error_unchanged_by_plugins(monkeypatch):
    monkeypatch.setattr(registry, "_iter_entry_points", lambda g: ())
    with pytest.raises(ValueError, match="registered schedulers"):
        registry.get_scheduler("no-such-engine")


def test_cost_model_plugin_resolves_by_name(monkeypatch):
    from repro.profile.model import COST_MODEL_PLUGIN_GROUP
    planted = fit_cost_model(_linear_records({4: (3.0, 0.25)}), device="pl")
    _fake_eps(monkeypatch, COST_MODEL_PLUGIN_GROUP, "labmodel",
              lambda: planted)
    got = resolve_cost_model("labmodel")
    assert got.coef == planted.coef


# ----------------------------------------------------------------------
# profile=True recording through the facade
# ----------------------------------------------------------------------

def test_api_profile_records_steps_and_fits():
    g = pagerank.make_graph(random_graph(40, 90, seed=3), 40)
    upd = pagerank.make_update(1e-5)
    res = api.run(g, upd, scheduler="chromatic", max_supersteps=50,
                  profile=True)
    rec = res.profile
    assert rec is not None and rec.records
    steps = [r for r in rec.records if r["kind"] == "step"]
    assert len(steps) == res.superstep
    for r in steps:
        assert r["mode"] in ("batch", "bucket")
        assert r["wall_us"] > 0
    assert steps[0]["cold"] is True        # first shape always cold
    # the profiled run is still the plain run, bit for bit
    ref = api.run(g, upd, scheduler="chromatic", max_supersteps=50)
    assert np.array_equal(np.asarray(res.vertex_data["rank"]),
                          np.asarray(ref.vertex_data["rank"]))
    assert res.superstep == ref.superstep
    # and its trace is fittable (chromatic sweeps are batch-mode
    # single-phase only on some graphs; empty fits are legal too)
    model = fit_cost_model(rec.records, device=rec.device)
    assert isinstance(model, CostModel)


def test_api_run_accepts_cost_model_and_stays_bitwise():
    g = pagerank.make_graph(random_graph(40, 90, seed=3), 40)
    upd = pagerank.make_update(1e-5)
    model = fit_cost_model(_linear_records({2: (1.0, 0.01),
                                            4: (1.0, 0.01),
                                            8: (1.0, 0.01)}))
    ref = api.run(g, upd, scheduler="chromatic", max_supersteps=50)
    got = api.run(g, upd, scheduler="chromatic", max_supersteps=50,
                  dispatch="auto", cost_model=model)
    assert np.array_equal(np.asarray(got.vertex_data["rank"]),
                          np.asarray(ref.vertex_data["rank"]))
    assert got.superstep == ref.superstep
    with pytest.raises(ValueError, match="cost_model must be"):
        api.run(g, upd, scheduler="chromatic", cost_model=43)
