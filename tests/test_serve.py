"""Online graph serving (DESIGN.md §13): slack storage, live mutations,
dirty-scope incremental recompute, snapshot-isolated queries.

The equivalence workload is connected components (``repro.apps.cc``):
int32 min-label over a confluent semilattice has exactly one fixed
point, so incremental-vs-rebuild checks are **bitwise** on any
scheduler.  Float workloads (PageRank) are covered in
examples/dynamic_pagerank.py with the eps-scaled tolerance contract.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import random_graph
from repro import api
from repro.apps import cc, pagerank
from repro.core.graph import (DataGraph, input_order_edges, insert_edges,
                              rebuild_compacted, zipf_edges)
from repro.data.pipeline import edge_stream


def _serve_cc(edges, nv, scheduler="locking", **kw):
    graph, update, _ = cc.build(edges, nv, slack=4)
    if scheduler == "locking":
        kw.setdefault("dispatch", "batch")
        kw.setdefault("max_pending", 32)
        kw.setdefault("max_supersteps", 20_000)
    return api.serve(graph, update, scheduler=scheduler, slack=4, **kw)


def _rebuild_labels(edges, nv, scheduler="locking"):
    g, u, _ = cc.build(edges, nv)
    kw = ({"dispatch": "batch", "max_pending": 32,
           "max_supersteps": 20_000} if scheduler == "locking" else {})
    res = api.run(g, u, scheduler=scheduler, **kw)
    return np.asarray(res.vertex_data["label"])


# ----------------------------------------------------------------------
# storage: slack slots are bitwise-inert until used
# ----------------------------------------------------------------------

def test_slack_storage_is_bitwise_inert():
    nv = 60
    edges = random_graph(nv, 120, seed=3)
    g0, u0, _ = cc.build(edges, nv)
    g1, u1, _ = cc.build(edges, nv, slack=4)
    assert g1.slack == 4 and g1.edge_capacity > g0.n_edges
    r0 = api.run(g0, u0, scheduler="chromatic")
    r1 = api.run(g1, u1, scheduler="chromatic")
    assert np.array_equal(np.asarray(r0.vertex_data["label"]),
                          np.asarray(r1.vertex_data["label"]))


def test_insert_edges_matches_from_scratch_build():
    nv = 50
    edges = random_graph(nv, 90, seed=1)
    new = np.asarray([[0, 17], [5, 33], [2, 48]], np.int64)
    g = pagerank.make_graph(edges, nv, slack=4)
    w_new = {"w": np.asarray([0.5, 0.25, 0.125], np.float32)}
    g2 = insert_edges(g, new, w_new)
    assert g2 is not None and g2.n_edges == len(edges) + 3
    # original untouched (snapshot isolation depends on this)
    assert g.n_edges == len(edges)
    ein, edata = input_order_edges(g2)
    assert np.array_equal(ein, np.vstack([edges, new]))
    assert np.allclose(edata["w"][-3:], w_new["w"])
    # per-vertex adjacency matches a from-scratch build
    ref = DataGraph.from_edges(
        nv, np.vstack([edges, new]),
        vertex_data={"x": np.zeros(nv, np.float32)})
    import jax.numpy as jnp
    ids = jnp.arange(nv, dtype=jnp.int32)
    got, want = g2.struct_rows(ids), ref.struct_rows(ids)
    for v in range(nv):
        gs = set(np.asarray(got.nbrs[v])[np.asarray(got.nbr_mask[v])])
        ws = set(np.asarray(want.nbrs[v])[np.asarray(want.nbr_mask[v])])
        assert gs == ws


def test_insert_validation():
    nv = 20
    edges = random_graph(nv, 30, seed=0)
    g_noslack, _, _ = cc.build(edges, nv)
    with pytest.raises(ValueError, match="slack"):
        insert_edges(g_noslack, np.asarray([[0, 5]]))
    g, _, _ = cc.build(edges, nv, slack=2)
    with pytest.raises(ValueError):
        insert_edges(g, np.asarray([[3, 3]]))      # self-loop
    with pytest.raises(ValueError):
        insert_edges(g, np.asarray([[0, nv]]))     # out of range


def test_compaction_rebuild_preserves_edge_perm_contract():
    nv = 40
    edges = random_graph(nv, 70, seed=5)
    g, _, _ = cc.build(edges, nv, slack=2)
    extra = np.asarray([[1, 30], [2, 29]], np.int64)
    g2 = rebuild_compacted(g, extra_edges=extra)
    ein, _ = input_order_edges(g2)
    assert np.array_equal(ein, np.vstack([edges, extra]))
    assert g2.slack == g.slack and g2.n_edges == len(edges) + 2
    # stored order maps back through edge_perm for every edge
    assert np.array_equal(ein[g2.edge_perm], g2.edges_np)


# ----------------------------------------------------------------------
# serving engine: incremental == rebuild, bitwise (CC)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["locking", "chromatic"])
def test_incremental_recompute_matches_rebuild_bitwise(scheduler):
    nv = 80
    edges = zipf_edges(nv, seed=7)
    serving = _serve_cc(edges, nv, scheduler)
    serving.recompute()
    new = np.asarray([[0, 61], [7, 44], [3, 71]], np.int64)
    new = np.asarray([e for e in new
                      if serving.find_edge(*e) is None]).reshape(-1, 2)
    serving.add_edges(new)
    r = serving.recompute()
    assert r["dirty"] > 0
    inc = np.asarray(serving.graph.vertex_data["label"])
    ref = _rebuild_labels(np.vstack([edges, new]), nv, scheduler)
    assert np.array_equal(inc, ref)


def test_locking_dirty_window_launch_trace():
    nv = 100
    edges = zipf_edges(nv, seed=3)
    serving = _serve_cc(edges, nv, "locking", max_pending=32)
    serving.recompute()
    serving.add_edge(0, 55)
    r = serving.recompute(track_launches=True)
    assert r["launches"], "track_launches must record the trace"
    for launch in r["launches"]:
        # dirty-window shaped: batched [B, W] launches, never a
        # full bucket sweep, never more rows than the window
        assert launch["mode"] == "batch"
        assert launch["rows"] <= 32
    inc = np.asarray(serving.graph.vertex_data["label"])
    ref = _rebuild_labels(np.vstack([edges, [[0, 55]]]), nv, "locking")
    assert np.array_equal(inc, ref)


def test_vertex_data_update_dirties_and_converges():
    nv = 60
    edges = random_graph(nv, 100, seed=2)
    serving = _serve_cc(edges, nv, "chromatic")
    serving.recompute()
    # inject a smaller label: the whole component must adopt it
    serving.update_vertex_data([10], {"label": np.asarray([-5, ], np.int32)})
    r = serving.recompute()
    assert r["dirty"] > 0
    inc = np.asarray(serving.graph.vertex_data["label"])
    labels = np.arange(nv, dtype=np.int32)
    labels[10] = -5
    ref = cc.reference_components(edges, nv, labels=labels)
    assert np.array_equal(inc, ref)


def test_update_field_validation_and_edge_updates():
    nv = 30
    edges = random_graph(nv, 50, seed=4)
    graph, update, syncs = pagerank.build(edges, nv, slack=4)
    serving = api.serve(graph, update, syncs=syncs, scheduler="chromatic",
                        slack=4)
    serving.recompute()
    with pytest.raises(KeyError, match="rank"):
        serving.update_vertex_data([0], {"nope": np.zeros(1)})
    u, v = int(edges[0][0]), int(edges[0][1])
    serving.update_edge(u, v, w=0.0)
    assert float(serving.snapshot().read_edge(u, v, "w")) != 0.0  # isolated
    serving.recompute()
    assert float(serving.snapshot().read_edge(u, v, "w")) == 0.0


def test_compaction_under_serving_stays_correct():
    nv = 40
    edges = random_graph(nv, 60, seed=6)
    graph, update, _ = cc.build(edges, nv, slack=1,
                                edge_capacity=len(edges) + 4)
    serving = api.serve(graph, update, scheduler="chromatic")
    serving.recompute()
    rng = np.random.default_rng(0)
    added = []
    while serving.stats["compactions"] == 0:
        u, v = int(rng.integers(0, nv)), int(rng.integers(0, nv))
        if u == v or serving.find_edge(u, v) is not None:
            continue
        serving.add_edge(u, v)
        added.append((u, v))
    serving.recompute()
    inc = np.asarray(serving.graph.vertex_data["label"])
    ref = _rebuild_labels(np.vstack([edges, np.asarray(added)]), nv,
                          "chromatic")
    assert np.array_equal(inc, ref)
    assert serving.n_edges == len(edges) + len(added)


def test_online_als_new_rating_reconverges():
    """The paper's online-CF flow: a user rates a movie, the rating
    lands as a live edge insert, and only the dirty scope (the user,
    the movie, their neighborhoods) re-solves its least squares."""
    from repro.apps import als
    prob = als.synthetic_netflix(12, 10, 3, density=0.3, seed=0, slack=4)
    graph, update, syncs = als.build(prob)
    serving = api.serve(graph, update, syncs=syncs, scheduler="chromatic",
                        slack=4)
    serving.recompute()
    w_before = np.asarray(serving.graph.vertex_data["w"]).copy()
    rated = {tuple(p) for p in prob.pairs}
    u, m = next((u, m) for u in range(prob.n_users)
                for m in range(prob.n_movies) if (u, m) not in rated)
    mv = prob.n_users + m                       # movie vertex id
    serving.add_edge(u, mv, rating=1.5)
    r = serving.recompute()
    assert r["dirty"] > 0
    w_after = np.asarray(serving.graph.vertex_data["w"])
    pred_before = float(w_before[u] @ w_before[mv])
    pred_after = float(w_after[u] @ w_after[mv])
    # the new rating pulls the pair's prediction toward it
    assert abs(pred_after - 1.5) < abs(pred_before - 1.5)
    assert float(serving.snapshot().read_edge(u, mv, "rating")) == 1.5


# ----------------------------------------------------------------------
# snapshot isolation
# ----------------------------------------------------------------------

def test_snapshot_isolation_pinned_reads():
    nv = 50
    edges = random_graph(nv, 80, seed=8)
    serving = _serve_cc(edges, nv, "chromatic")
    serving.recompute()
    pinned = serving.snapshot()
    before = np.asarray(pinned.read_vertex(np.arange(nv), "label")).copy()
    assert pinned.find_edge(*edges[0]) is not None
    serving.update_vertex_data([0], {"label": np.asarray([-9], np.int32)})
    serving.add_edge(*[e for e in [(0, 33), (1, 44)]
                       if serving.find_edge(*e) is None][0])
    serving.recompute()
    # the pinned snapshot still serves the pre-mutation state
    assert np.array_equal(
        np.asarray(pinned.read_vertex(np.arange(nv), "label")), before)
    assert pinned.n_edges == len(edges)
    # the fresh snapshot sees the new fixed point
    new = serving.snapshot()
    assert new.n_edges == len(edges) + 1
    assert int(new.read_vertex([0], "label")[0]) == -9 or \
        int(new.read_vertex([0], "label")[0]) < 0


def test_top_k_and_round_metadata():
    nv = 30
    edges = random_graph(nv, 40, seed=9)
    graph, update, syncs = pagerank.build(edges, nv, slack=4)
    serving = api.serve(graph, update, syncs=syncs, scheduler="chromatic",
                        slack=4)
    serving.recompute()
    snap = serving.snapshot()
    ids, vals = snap.top_k("rank", 5)
    ranks = np.asarray(snap.read_vertex(np.arange(nv), "rank"))
    assert np.array_equal(np.sort(vals)[::-1], vals)
    assert vals[0] == ranks.max()
    assert snap.round == 1


# ----------------------------------------------------------------------
# facade kwarg hygiene, both directions
# ----------------------------------------------------------------------

def test_serve_rejects_inapplicable_knobs_naming_allowed_set():
    nv = 20
    edges = random_graph(nv, 30, seed=0)
    graph, update, _ = cc.build(edges, nv, slack=4)
    with pytest.raises(ValueError) as ei:
        api.serve(graph, update, scheduler="chromatic", k_select=4)
    assert "allowed options" in str(ei.value)
    assert "chromatic" in str(ei.value)
    with pytest.raises(ValueError, match="sequential"):
        api.serve(graph, update, scheduler="sequential")


def test_run_redirects_serve_only_kwargs():
    nv = 20
    edges = random_graph(nv, 30, seed=0)
    graph, update, _ = cc.build(edges, nv)
    for kw in ({"slack": 4}, {"publish_every": 2}, {"edge_capacity": 64}):
        with pytest.raises(ValueError, match="api.serve"):
            api.run(graph, update, scheduler="chromatic", **kw)


def test_serving_engine_requires_slack_storage():
    from repro.serve import ServingEngine  # facade re-export
    nv = 20
    edges = random_graph(nv, 30, seed=0)
    graph, update, _ = cc.build(edges, nv)  # no slack
    spec = api.EngineSpec(scheduler="chromatic")
    with pytest.raises(ValueError, match="slack"):
        ServingEngine(graph, update, spec=spec)
    # api.serve transparently re-stores with slack instead
    serving = api.serve(graph, update, scheduler="chromatic")
    assert serving.graph.slack > 0
    serving.recompute()
    assert np.array_equal(
        np.asarray(serving.graph.vertex_data["label"]),
        _rebuild_labels(edges, nv, "chromatic"))


# ----------------------------------------------------------------------
# edge_stream trace generator
# ----------------------------------------------------------------------

def test_edge_stream_deterministic_and_wellformed():
    a = list(edge_stream(200, rate=6, seed=11, n_batches=5))
    b = list(edge_stream(200, rate=6, seed=11, n_batches=5))
    assert len(a) == 5
    for x, y in zip(a, b):
        assert (np.array_equal(x.edges, y.edges)
                and np.array_equal(x.touch, y.touch)
                and np.array_equal(x.queries, y.queries))
        assert x.edges.shape[1] == 2
        assert (x.edges[:, 0] != x.edges[:, 1]).all()
        keys = {tuple(sorted(e)) for e in x.edges}
        assert len(keys) == len(x.edges)          # deduped within batch


# ----------------------------------------------------------------------
# distributed serving: 8 virtual devices (subprocess — XLA_FLAGS must
# be set before jax initializes; same harness shape as test_api.py)
# ----------------------------------------------------------------------

_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro import api
    from repro.apps import cc
    from repro.core import two_phase_partition
    from repro.core.graph import zipf_edges

    nv = 64
    edges = zipf_edges(nv, alpha=2.0, max_deg=24, seed=7)
    graph, update, _ = cc.build(edges, nv, slack=4)
    asg = two_phase_partition(nv, edges, 8, seed=0)
    serving = api.serve(graph, update, scheduler="chromatic", n_shards=8,
                        partition=asg, slack=4)
    serving.recompute()
    new = np.asarray([e for e in [[0, 41], [5, 60], [2, 33]]
                      if serving.find_edge(*e) is None],
                     np.int64).reshape(-1, 2)
    serving.add_edges(new)
    r = serving.recompute()
    inc = np.asarray(serving.graph.vertex_data["label"])

    g2, u2, _ = cc.build(np.vstack([edges, new]), nv)
    asg2 = two_phase_partition(nv, np.vstack([edges, new]), 8, seed=0)
    res = api.run(g2, u2, scheduler="chromatic", n_shards=8,
                  partition=asg2)
    out = {
        "dirty": int(r["dirty"]),
        "equal": bool(np.array_equal(
            inc, np.asarray(res.vertex_data["label"]))),
    }
    print("RESULT:" + json.dumps(out))
""")


@pytest.mark.distributed
def test_distributed_serving_incremental_matches_rebuild():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["equal"]
    assert out["dirty"] > 0
