"""Sliced-ELL storage (DESIGN.md §7): bucketing round-trips the
adjacency, the vectorized builder matches the loop builder bit-for-bit,
and the degree buckets actually shrink storage on skewed graphs."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.graph import (DataGraph, _build_ell_loop,
                              _build_ell_vectorized, build_sliced_ell,
                              default_bucket_widths, zipf_edges)
from conftest import random_graph


def _degrees(nv, edges):
    deg = np.zeros(nv, dtype=np.int64)
    for col in (0, 1):
        np.add.at(deg, edges[:, col], 1)
    return deg


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_vectorized_builder_identical_to_loop(seed):
    """The lexsort/cumsum build is the old per-edge loop, bit-for-bit —
    including self-loop and duplicate-edge handling."""
    rng = np.random.default_rng(seed)
    nv = int(rng.integers(5, 50))
    ne = int(rng.integers(1, 120))
    # raw random edges: self loops and duplicates included on purpose
    edges = rng.integers(0, nv, (ne, 2)).astype(np.int64)
    md = max(int(_degrees(nv, edges).max()), 1)
    for a, b in zip(_build_ell_loop(nv, edges, md),
                    _build_ell_vectorized(nv, edges, md)):
        np.testing.assert_array_equal(a, b)


def test_sliced_ell_roundtrips_adjacency():
    """to_padded() == the old monolithic from_edges output (edge ids
    modulo the bucket-major renumbering, exactly when locality is on)."""
    edges = random_graph(80, 240, seed=7)
    g0 = DataGraph.from_edges(80, edges, {"x": np.zeros(80, np.float32)},
                              edge_locality=False)
    want = _build_ell_loop(80, edges, g0.max_deg)
    for a, b in zip(g0.to_padded(), want):
        np.testing.assert_array_equal(np.asarray(a), b)
    # with locality on, only the edge *ids* change — mapped through the
    # stored permutation they are the unordered layout's ids
    g = DataGraph.from_edges(80, edges, {"x": np.zeros(80, np.float32)})
    got = g.to_padded()
    np.testing.assert_array_equal(np.asarray(got.nbrs), want[0])
    np.testing.assert_array_equal(np.asarray(got.nbr_mask), want[1])
    to_input = np.append(g.edge_perm, g.n_edges)     # pad id fixed
    np.testing.assert_array_equal(to_input[np.asarray(got.edge_ids)],
                                  want[2])
    np.testing.assert_array_equal(np.asarray(got.is_src), want[3])
    # every vertex is in exactly one bucket; the permutation is exact
    perm = np.asarray(g.ell.perm)
    assert sorted(perm[perm < 80].tolist()) == list(range(80))
    inv = np.asarray(g.ell.inv_perm)
    np.testing.assert_array_equal(perm[inv], np.arange(80))


def test_bucket_widths_cover_and_cap():
    assert default_bucket_widths(1) == (1,)
    assert default_bucket_widths(2) == (2,)
    assert default_bucket_widths(5) == (2, 4, 5)
    assert default_bucket_widths(32) == (2, 4, 8, 16, 32)


def test_bucket_assignment_minimal_width():
    """Each row sits in the smallest bucket covering its degree."""
    edges = zipf_edges(300, alpha=2.0, max_deg=40, seed=3)
    g = DataGraph.from_edges(300, edges, {"x": np.zeros(300, np.float32)})
    ell = g.ell
    deg = np.asarray(g.degree)
    inv = np.asarray(ell.inv_perm)
    for b in range(ell.n_buckets):
        lo = 0 if b == 0 else ell.widths[b - 1]
        rows = np.nonzero((inv >= ell.starts[b])
                          & (inv < ell.starts[b + 1]))[0]
        assert np.all(deg[rows] <= ell.widths[b])
        assert np.all(deg[rows] > lo) or b == 0


def test_sliced_storage_shrinks_on_zipf():
    """The acceptance-criterion inequality, in miniature: >= 4x fewer
    stored+computed slots than [Nv, max_deg] on a power-law graph."""
    edges = zipf_edges(2000, alpha=2.0, max_deg=64, seed=1)
    g = DataGraph.from_edges(2000, edges, {"x": np.zeros(2000, np.float32)})
    monolithic = g.n_vertices * g.max_deg
    assert g.ell.padded_slots * 4 <= monolithic
    # and it degrades gracefully on uniform graphs (never worse than 2x)
    eu = random_graph(500, 1500, seed=2)
    gu = DataGraph.from_edges(500, eu, {"x": np.zeros(500, np.float32)})
    assert gu.ell.padded_slots <= 2 * gu.n_vertices * gu.max_deg


def test_bucket_major_edge_order_is_first_visit():
    """Edge-data locality (DESIGN.md §8): walking buckets in width
    order, rows top to bottom and slots left to right, the stored edge
    ids appear in first-visit order 0, 1, 2, ... — so per-bucket edge
    gathers walk edge data in ascending, nearly-contiguous runs."""
    edges = zipf_edges(400, alpha=2.0, max_deg=48, seed=6)
    g = DataGraph.from_edges(400, edges, {"x": np.zeros(400, np.float32)})
    ell = g.ell
    visits = np.concatenate([
        np.asarray(ell.edge_ids[b])[np.asarray(ell.nbr_mask[b])]
        for b in range(ell.n_buckets)])
    first = visits[np.sort(np.unique(visits, return_index=True)[1])]
    np.testing.assert_array_equal(first, np.arange(g.n_edges))
    # the permutation round-trips, and edges_np rows follow the new ids
    np.testing.assert_array_equal(g.edge_perm[g.edge_inv_perm],
                                  np.arange(g.n_edges))
    np.testing.assert_array_equal(g.edges_np, edges[g.edge_perm])


def test_row_activation_routes_oob():
    edges = random_graph(30, 60, seed=4)
    g = DataGraph.from_edges(30, edges, {"x": np.zeros(30, np.float32)})
    ids = jnp.asarray([5, 0, 7, 0], jnp.int32)    # padded slots alias 0
    sel = jnp.asarray([True, False, True, False])
    act = np.asarray(g.ell.row_activation(ids, sel))
    inv = np.asarray(g.ell.inv_perm)
    want = np.zeros(g.ell.total_rows, bool)
    want[inv[5]] = want[inv[7]] = True
    np.testing.assert_array_equal(act, want)


def test_forced_bucket_sizes_pad_rows():
    """ShardPlan-style forced sizes produce inert padding rows."""
    edges = random_graph(20, 40, seed=5)
    g = DataGraph.from_edges(20, edges, {"x": np.zeros(20, np.float32)})
    p = g.to_padded()
    widths = default_bucket_widths(g.max_deg)
    ell = build_sliced_ell(np.asarray(p.nbrs), np.asarray(p.nbr_mask),
                           np.asarray(p.edge_ids), np.asarray(p.is_src),
                           pad_edge=g.n_edges, widths=widths,
                           bucket_sizes=[12] * len(widths))
    assert ell.total_rows == 12 * len(widths)
    perm = np.asarray(ell.perm)
    pad_rows = perm == 20
    for b in range(ell.n_buckets):
        blk_mask = np.asarray(ell.nbr_mask[b])
        pads_b = pad_rows[ell.starts[b]: ell.starts[b + 1]]
        assert not blk_mask[pads_b].any()       # padding rows have no slots
    got = ell.to_padded()
    for a, b in zip(got, p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_width_specialized_rows_and_window_bucket():
    """The batch dispatch path's gather contract (DESIGN.md §8):
    ``rows(ids, width=W)`` equals the full materialization truncated to
    W for rows in buckets <= W and reads as padding for wider rows;
    ``window_bucket`` reports the widest selected bucket."""
    edges = zipf_edges(300, alpha=2.0, max_deg=40, seed=3)
    g = DataGraph.from_edges(300, edges, {"x": np.zeros(300, np.float32)})
    ell = g.ell
    assert ell.n_buckets >= 3
    assert ell.snap_width(3) == 4 and ell.snap_width(2) == 2
    assert ell.snap_width(ell.max_deg + 7) == ell.widths[-1]
    ids = jnp.arange(300, dtype=jnp.int32)
    full = ell.rows(ids)
    w = ell.widths[1]
    part = ell.rows(ids, width=w)
    assert part.nbrs.shape == (300, w)
    deg = np.asarray(g.degree)
    fits = deg <= w
    for f_arr, p_arr in [(full.nbrs, part.nbrs), (full.nbr_mask, part.nbr_mask),
                         (full.edge_ids, part.edge_ids), (full.is_src, part.is_src)]:
        np.testing.assert_array_equal(np.asarray(f_arr)[fits, :w],
                                      np.asarray(p_arr)[fits])
    assert not np.asarray(part.nbr_mask)[~fits].any()   # wider rows: empty
    # window_bucket: a selection inside bucket 0 reports 0; including a
    # widest-bucket row reports n_buckets - 1; empty selection -> 0
    inv = np.asarray(ell.inv_perm)
    narrow = np.nonzero((inv >= ell.starts[0]) & (inv < ell.starts[1]))[0][:4]
    wide = np.nonzero(inv >= ell.starts[ell.n_buckets - 1])[0][:1]
    sel_ids = jnp.asarray(np.concatenate([narrow, wide]), jnp.int32)
    sel = jnp.ones(sel_ids.shape, bool)
    assert int(ell.window_bucket(sel_ids, sel)) == ell.n_buckets - 1
    assert int(ell.window_bucket(sel_ids, sel.at[-1].set(False))) == 0
    assert int(ell.window_bucket(sel_ids, jnp.zeros_like(sel))) == 0


@pytest.mark.split
def test_split_storage_roundtrip_and_metadata():
    """Hub splitting (DESIGN.md §10): rows wider than W_cap decompose
    into virtual rows; the adjacency round-trips bit-identically to the
    unsplit layout and the owner map is exact."""
    edges = zipf_edges(400, alpha=2.0, max_deg=48, seed=6)
    vd = {"x": np.zeros(400, np.float32)}
    g0 = DataGraph.from_edges(400, edges, vd, edge_locality=False)
    gs = DataGraph.from_edges(400, edges, vd, w_cap=8, edge_locality=False)
    ell = gs.ell
    assert ell.is_split and ell.w_cap == 8
    assert ell.widths[-1] == 8 < ell.max_deg == g0.max_deg
    # w_cap implies hub_split; hub_split alone picks the p99 default
    assert DataGraph.from_edges(400, edges, vd, hub_split=True).ell.w_cap
    with pytest.raises(ValueError, match="power of two"):
        DataGraph.from_edges(400, edges, vd, w_cap=6)
    with pytest.raises(ValueError, match="bucket_widths"):
        DataGraph.from_edges(400, edges, vd, w_cap=8, bucket_widths=(2, 8))
    # owner map: vrow_offset[r]:vrow_offset[r+1] all owned by r, chunk
    # count is ceil(deg / W_cap) (empty rows still get one vrow)
    off = np.asarray(ell.vrow_offset)
    owner = np.asarray(ell.owner_of_vrow)
    deg = np.asarray(gs.degree)
    np.testing.assert_array_equal(off[1:] - off[:-1],
                                  np.maximum(1, -(-deg // 8)))
    for r in (0, 1, 399):
        assert np.all(owner[off[r]:off[r + 1]] == r)
    assert ell.n_virtual == off[-1]
    # adjacency round-trip is bit-identical to the unsplit layout
    for a, b in zip(gs.to_padded(), g0.to_padded()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # scope widths: stored buckets then 2*W_cap, 4*W_cap, ... >= max_deg
    sw = ell.scope_widths
    assert sw[:ell.n_buckets] == ell.widths
    assert all(w % 8 == 0 for w in sw[ell.n_buckets:])
    assert sw[-1] >= ell.max_deg and sw[-2] < ell.max_deg


@pytest.mark.split
def test_split_rows_row_activation_and_window_bucket():
    """The dispatch contracts survive splitting: width-specialized
    gathers truncate/blank exactly as unsplit (hubs materialize through
    chunk concatenation at wide widths), ``row_activation`` lights every
    virtual row of a selected owner, and ``window_bucket`` reports wide
    classes for hub selections."""
    edges = zipf_edges(400, alpha=2.0, max_deg=48, seed=6)
    gs = DataGraph.from_edges(400, edges, {"x": np.zeros(400, np.float32)},
                              w_cap=8, edge_locality=False)
    ell = gs.ell
    deg = np.asarray(gs.degree)
    ids = jnp.arange(400, dtype=jnp.int32)
    full = ell.rows(ids)
    assert full.nbrs.shape == (400, ell.max_deg)
    for w in ell.scope_widths:
        part = ell.rows(ids, width=w)
        assert part.nbrs.shape == (400, w)
        fits = deg <= w
        wc = min(w, ell.max_deg)     # widest class may exceed max_deg
        for f_arr, p_arr in [(full.nbrs, part.nbrs),
                             (full.nbr_mask, part.nbr_mask),
                             (full.edge_ids, part.edge_ids),
                             (full.is_src, part.is_src)]:
            np.testing.assert_array_equal(
                np.asarray(f_arr)[fits, :wc],
                np.asarray(p_arr)[fits, :wc])
        assert not np.asarray(part.nbr_mask)[:, wc:].any()
        assert not np.asarray(part.nbr_mask)[~fits].any()
    # row_activation: all the owner's vrows, nothing else
    hub = int(np.argmax(deg))
    low = int(np.argmin(np.where(deg <= 8, deg, deg.max() + 1)))
    sel_ids = jnp.asarray([hub, low], jnp.int32)
    act = np.asarray(ell.row_activation(sel_ids, jnp.ones(2, bool)))
    off = np.asarray(ell.vrow_offset)
    inv = np.asarray(ell.inv_perm)
    want = np.zeros(ell.total_rows, bool)
    for r in (hub, low):
        want[inv[off[r]:off[r + 1]]] = True
    np.testing.assert_array_equal(act, want)
    # window_bucket: hub selection lands in a wide class whose width
    # covers the hub; a low-degree-only selection stays in the buckets
    wb = int(ell.window_bucket(sel_ids, jnp.ones(2, bool)))
    assert wb >= ell.n_buckets and ell.scope_widths[wb] >= deg[hub]
    wb_low = int(ell.window_bucket(sel_ids, jnp.asarray([False, True])))
    assert wb_low < ell.n_buckets and ell.scope_widths[wb_low] >= deg[low]
    assert int(ell.window_bucket(sel_ids, jnp.zeros(2, bool))) == 0


@pytest.mark.split
def test_split_eliminates_tail_bucket():
    """The acceptance shape bound: with splitting on, the widest stored
    (= compiled) bucket is W_cap regardless of skew, and the slot count
    never exceeds the unsplit bucketed layout's."""
    edges = zipf_edges(2000, alpha=2.0, max_deg=64, seed=1)
    vd = {"x": np.zeros(2000, np.float32)}
    g0 = DataGraph.from_edges(2000, edges, vd)
    gs = DataGraph.from_edges(2000, edges, vd, w_cap=16)
    assert g0.ell.widths[-1] > 16          # unsplit ladder has a tail
    assert gs.ell.widths[-1] == 16         # split ladder is capped
    assert gs.ell.padded_slots <= g0.ell.padded_slots


@pytest.mark.split
def test_split_slot_weight_is_post_split_cost():
    """Partitioner vertex weights under splitting: full chunks cost
    W_cap, the remainder its covering power-of-two bucket."""
    from repro.core.partition import split_slot_weight
    deg = np.asarray([0, 1, 3, 8, 9, 16, 20, 100])
    np.testing.assert_array_equal(
        split_slot_weight(deg, 8),
        #          0/1 deg pay the min bucket; 9 = 8 + pad(1)->2;
        #          20 = 2 full chunks + pad(4)->4; 100 = 12*8 + 4
        np.asarray([2, 2, 4, 8, 10, 16, 20, 100]))
    with pytest.raises(ValueError, match="power of two"):
        split_slot_weight(deg, 6)


# Engine-level split parity (4 schedulers x {batch,bucket} x
# {kernel,dense}, bitwise) lives with the dispatch invariants in
# tests/test_dispatch.py::test_split_dispatch_paths_bitwise_identical.


def test_zipf_edges_are_skewed_and_simple():
    edges = zipf_edges(3000, alpha=2.0, max_deg=128, seed=0)
    assert len(edges)
    lo, hi = edges[:, 0], edges[:, 1]
    assert np.all(lo < hi)                       # no self loops, canonical
    assert len(np.unique(lo * 3000 + hi)) == len(edges)   # no duplicates
    deg = _degrees(3000, edges)
    assert deg.max() / max(deg.mean(), 1e-9) >= 8.0       # heavy tail
