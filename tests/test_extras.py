"""BPTF app, FIFO scheduling, and HLO-walker unit tests."""
import numpy as np
import pytest

from repro.core import ChromaticEngine, PriorityEngine


def test_bptf_tripartite_converges():
    """Paper §5.4: BPTF as a (tri-partite) data graph with a time-factor
    sync; converges to the noise floor."""
    from repro.apps import bptf
    prob = bptf.synthetic_bptf(30, 25, 5, d=4, density=0.3, noise=0.05)
    eng = ChromaticEngine(prob.graph, bptf.make_update(4, lam=0.02),
                          syncs=[bptf.time_table_sync(5, 4)],
                          max_supersteps=30)
    st = eng.run(num_supersteps=30)
    rmse = bptf.dataset_rmse(prob, st.vertex_data, st.globals)
    base = float(np.sqrt(np.mean(prob.ratings ** 2)))
    assert rmse < 0.25 * base, (rmse, base)


def test_fifo_scheduling_drains_and_converges():
    """Paper §3.4/§4.2.2: FIFO ordering is a legal RemoveNext — the
    engine still converges to the same fixed point."""
    from repro.apps import pagerank
    from conftest import random_graph
    edges = random_graph(40, 90, seed=11)
    g = pagerank.make_graph(edges, 40)
    upd = pagerank.make_update(1e-6)
    chrom = ChromaticEngine(g, upd, max_supersteps=300).run()
    fifo = PriorityEngine(g, upd, k_select=16, fifo=True,
                          max_supersteps=8000).run()
    assert not bool(fifo.active.any())
    np.testing.assert_allclose(np.asarray(fifo.vertex_data["rank"]),
                               np.asarray(chrom.vertex_data["rank"]),
                               atol=3e-5)


_HLO = """\
HloModule test

%cond (p: (s32[], f32[8,4])) -> pred[] {
  %p = (s32[], f32[8,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p2: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %p2 = (s32[], f32[8,4]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %x = f32[8,4] get-tuple-element(%p2), index=1
  %one = s32[] constant(1)
  %ip = s32[] add(%i2, %one)
  %w = f32[4,4] constant({...})
  %y = f32[8,4] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,8] all-gather(%y), replica_groups={}, dimensions={1}
  %z = f32[8,4] slice(%ag), slice={[0:8], [0:4]}
  ROOT %t = (s32[], f32[8,4]) tuple(%ip, %z)
}

ENTRY %main (a: f32[8,4]) -> f32[8,4] {
  %a = f32[8,4] parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,4]) tuple(%zero, %a)
  %wh = (s32[], f32[8,4]) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[8,4] get-tuple-element(%wh), index=1
}
"""


def test_hlo_walker_multiplies_loop_trips():
    from repro.roofline import hlo_parse as HP
    cost = HP.analyze(_HLO)
    # dot: 2 * 8*4 out * 4 contract = 256 flops, x7 trips
    assert cost.flops == pytest.approx(256 * 7 + 7, rel=0.2)  # +adds
    # all-gather: f32[8,8] = 256 B x 7 trips
    assert cost.coll_bytes == pytest.approx(256 * 7)
    assert cost.coll_breakdown["all-gather"] == pytest.approx(256 * 7)


def test_hlo_walker_inplace_accounting():
    from repro.roofline import hlo_parse as HP
    hlo = """\
HloModule t2

ENTRY %main (a: f32[100,4], u: f32[1,4]) -> f32[100,4] {
  %a = f32[100,4] parameter(0)
  %u = f32[1,4] parameter(1)
  %z = s32[] constant(3)
  ROOT %d = f32[100,4] dynamic-update-slice(%a, %u, %z, %z)
}
"""
    cost = HP.analyze(hlo)
    # charged 2 x update (16 B) + indices, NOT the 1600 B buffer
    assert cost.bytes < 200
