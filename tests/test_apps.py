"""Application-level correctness (paper §5): each app converges to the
right answer on planted synthetic data."""
import numpy as np
import pytest

from repro.apps import als, coem, gibbs, lbp, pagerank
from repro.core import ChromaticEngine, PriorityEngine
from conftest import random_graph


def test_pagerank_matches_power_iteration_oracle():
    edges = random_graph(60, 150, seed=0)
    g = pagerank.make_graph(edges, 60)
    eng = ChromaticEngine(g, pagerank.make_update(1e-6),
                          max_supersteps=300)
    st = eng.run()
    assert not bool(st.active.any()), "should converge"
    ref = pagerank.reference_pagerank(edges, 60)
    np.testing.assert_allclose(np.asarray(st.vertex_data["rank"]), ref,
                               atol=5e-5)


def test_pagerank_adaptive_scheduling_saves_updates():
    """Adaptive rescheduling (Alg. 1) does less work than fixed sweeps."""
    edges = random_graph(60, 150, seed=0)
    g = pagerank.make_graph(edges, 60)
    eng = ChromaticEngine(g, pagerank.make_update(1e-4),
                          max_supersteps=300)
    st = eng.run()
    sweeps_equiv = int(st.superstep) * 60
    assert int(st.n_updates) < sweeps_equiv


def test_als_converges_to_noise_floor():
    prob = als.synthetic_netflix(40, 30, d=4, density=0.4, noise=0.05)
    eng = ChromaticEngine(prob.graph, als.make_update(4, lam=0.01,
                                                      eps=1e-4),
                          syncs=[als.rmse_sync()], max_supersteps=60)
    st = eng.run(num_supersteps=60)
    rmse = als.dataset_rmse(prob, st.vertex_data)
    assert rmse < 0.09, f"ALS should reach noise floor, got {rmse}"
    # the sync-op RMSE equals the exact dataset RMSE (paper §5.1 sync)
    np.testing.assert_allclose(float(st.globals["rmse"]), rmse, rtol=1e-3)


def test_als_rank_sweep_improves_fit():
    """Fig 5(a): larger d fits better (down to the noise floor)."""
    errs = []
    for d in (1, 4):
        prob = als.synthetic_netflix(40, 30, d=4, density=0.4,
                                     noise=0.05, d_model=d)
        eng = ChromaticEngine(prob.graph, als.make_update(d, lam=0.02),
                              max_supersteps=25)
        st = eng.run(num_supersteps=25)
        errs.append(als.dataset_rmse(prob, st.vertex_data))
    assert errs[1] < errs[0]


def test_coem_recovers_planted_types():
    prob = coem.synthetic_ner(120, 80, 3, mean_deg=8, seed_frac=0.15,
                              seed=1)
    eng = ChromaticEngine(prob.graph, coem.make_update(1e-4),
                          max_supersteps=50)
    st = eng.run()
    acc = coem.label_accuracy(prob, st.vertex_data)
    assert acc > 0.8, f"CoEM should recover planted types, got {acc}"


def test_lbp_on_tree_matches_exact_marginals():
    """Sum-product BP is exact on trees: chain of 4 vertices."""
    import jax
    import jax.numpy as jnp
    from repro.core.graph import DataGraph
    from repro.core.coloring import greedy_coloring
    k = 3
    edges = np.asarray([[0, 1], [1, 2], [2, 3]])
    rng = np.random.default_rng(0)
    unary = rng.normal(size=(4, k)).astype(np.float32)
    g = DataGraph.from_edges(
        4, edges,
        vertex_data={"feat": np.zeros((4, 1), np.float32),
                     "unary": unary, "belief": unary.copy()},
        edge_data={"msg01": np.zeros((3, k), np.float32),
                   "msg10": np.zeros((3, k), np.float32)})
    g = g.with_colors(greedy_coloring(4, edges))
    beta = 0.7
    upd = lbp.make_update(k, beta=beta, eps=1e-7, use_gmm_sync=False)
    eng = ChromaticEngine(g, upd, max_supersteps=50)
    st = eng.run()
    beliefs = jax.nn.softmax(jnp.asarray(st.vertex_data["belief"]), -1)
    # exact marginals by enumeration
    psi = np.exp(-beta * (1 - np.eye(k)))
    pot = np.exp(unary)
    joint = np.zeros((k,) * 4)
    for a in range(k):
        for b in range(k):
            for c in range(k):
                for d in range(k):
                    joint[a, b, c, d] = (pot[0, a] * pot[1, b] * pot[2, c]
                                         * pot[3, d] * psi[a, b] * psi[b, c]
                                         * psi[c, d])
    joint /= joint.sum()
    for v, axes in enumerate([(1, 2, 3), (0, 2, 3), (0, 1, 3), (0, 1, 2)]):
        np.testing.assert_allclose(np.asarray(beliefs[v]),
                                   joint.sum(axis=axes), atol=1e-3)


def test_coseg_priority_engine_improves_over_unary():
    prob = lbp.synthetic_coseg(3, 4, 8, n_labels=3, noise=0.6)
    base = float((np.asarray(prob.graph.vertex_data["unary"]).argmax(1)
                  == prob.true_labels).mean())
    eng = PriorityEngine(prob.graph, lbp.make_update(3, beta=0.5, eps=1e-3),
                         k_select=32, max_supersteps=3000)
    st = eng.run()
    acc = lbp.label_accuracy(prob, st.vertex_data)
    assert acc >= base, f"LBP smoothing should not hurt: {acc} vs {base}"


def test_gibbs_matches_exact_ising_marginals():
    """Chromatic Gibbs (the [22] sampler) is statistically correct."""
    edges = np.asarray([[0, 1], [1, 2], [2, 3], [3, 0]])
    prob = gibbs.ising_problem(edges, 4, beta=0.35, field=0.2, seed=1)
    eng = ChromaticEngine(prob.graph, gibbs.make_update(0.35, field=0.2,
                                                        burn_in=100),
                          max_supersteps=4000)
    st = eng.run()
    emp = gibbs.marginals(st.vertex_data)
    exact = gibbs.exact_marginals(edges, 4, 0.35, field=0.2)
    np.testing.assert_allclose(emp, exact, atol=0.05)
