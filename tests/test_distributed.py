"""Distributed engine: multi-device (subprocess, 8 host devices) equality
with the single-shard engine — the sharded runtime is semantics-preserving.

Run in a subprocess because XLA_FLAGS device-count must be set before jax
initializes (and the main test process must keep 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

pytestmark = pytest.mark.distributed

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.apps import als, coem, lbp, pagerank
    from repro.core import (ChromaticEngine, ShardPlan,
                            DistributedChromaticEngine,
                            two_phase_partition, random_partition)

    out = {}

    # --- PageRank on 8 shards, two-phase partition ---
    rng = np.random.default_rng(1)
    nv = 80
    edges = set()
    while len(edges) < 200:
        u, v = rng.integers(0, nv, 2)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    edges = np.array(sorted(edges))
    g = pagerank.make_graph(edges, nv)
    upd = pagerank.make_update(1e-5)
    syncs = [pagerank.total_rank_sync()]
    st = ChromaticEngine(g, upd, syncs=syncs, max_supersteps=80).run()
    asg = two_phase_partition(nv, edges, 8, seed=0)
    plan = ShardPlan.build(g, asg, 8)
    res = DistributedChromaticEngine(g, plan, upd, syncs=syncs,
                                     max_supersteps=80).run()
    out["pr_equal"] = bool(np.array_equal(
        np.asarray(st.vertex_data["rank"]),
        np.asarray(res["vertex_data"]["rank"])))
    out["pr_updates"] = [int(st.n_updates), res["n_updates"]]
    out["pr_supersteps"] = [int(st.superstep), res["supersteps"]]

    # --- CoEM (bipartite, random partition like the paper's NER) ---
    prob = coem.synthetic_ner(60, 40, 3, seed=2)
    updc = coem.make_update(1e-4)
    stc = ChromaticEngine(prob.graph, updc, max_supersteps=40).run()
    asgc = random_partition(prob.graph.n_vertices, 8, seed=3)
    planc = ShardPlan.build(prob.graph, asgc, 8)
    resc = DistributedChromaticEngine(prob.graph, planc, updc,
                                      max_supersteps=40).run()
    diff = np.abs(np.asarray(stc.vertex_data["p"])
                  - np.asarray(resc["vertex_data"]["p"])).max()
    out["coem_maxdiff"] = float(diff)

    # --- LBP with edge-data writes across cut edges (CoSeg-style) ---
    pl = lbp.synthetic_coseg(4, 3, 4, n_labels=3, noise=0.5)
    updl = lbp.make_update(3, eps=1e-3, use_gmm_sync=False)
    stl = ChromaticEngine(pl.graph, updl, max_supersteps=25).run()
    asgl = lbp.frame_partition(pl, 8)
    planl = ShardPlan.build(pl.graph, asgl, 8)
    resl = DistributedChromaticEngine(pl.graph, planl, updl,
                                      max_supersteps=25,
                                      exchange_edges=True).run()
    diffl = np.abs(np.asarray(stl.vertex_data["belief"])
                   - np.asarray(resl["vertex_data"]["belief"])).max()
    out["lbp_maxdiff"] = float(diffl)
    out["lbp_updates"] = [int(stl.n_updates), resl["n_updates"]]

    print("RESULT:" + json.dumps(out))
""")


_SPLIT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.apps import pagerank
    from repro.core import (ChromaticEngine, ShardPlan,
                            DistributedChromaticEngine, two_phase_partition)
    from repro.core.engine_locking import (LockingEngine,
                                           DistributedLockingEngine)
    from repro.core.graph import zipf_edges

    out = {}
    nv = 80
    edges = zipf_edges(nv, alpha=2.0, max_deg=32, seed=7)
    g = pagerank.make_graph(edges, nv, w_cap=8)
    assert g.ell.is_split
    upd = pagerank.make_update(1e-4)

    asg = two_phase_partition(nv, edges, 8, seed=0)
    plan = ShardPlan.build(g, asg, 8)
    out["plan_split"] = plan.ell_w_cap == 8 and plan.ell_max_deg is not None

    # chromatic: the per-shard virtual rows are invisible — bitwise
    st = ChromaticEngine(g, upd, max_supersteps=80).run()
    res = DistributedChromaticEngine(g, plan, upd, max_supersteps=80).run()
    out["chrom_equal"] = bool(np.array_equal(
        np.asarray(st.vertex_data["rank"]),
        np.asarray(res["vertex_data"]["rank"])))
    out["chrom_updates"] = [int(st.n_updates), res["n_updates"]]

    # locking: bitwise under the saturating-window contract
    # (tests/test_locking.py) — single max_pending=nv vs distributed
    # max_pending=plan.R schedule every runnable vertex each superstep
    sl = LockingEngine(g, upd, max_pending=nv, max_supersteps=3000).run()
    dl = DistributedLockingEngine(g, plan, upd, max_pending=plan.R,
                                  max_supersteps=3000).run()
    out["lock_equal"] = bool(np.array_equal(
        np.asarray(sl.vertex_data["rank"]),
        np.asarray(dl["vertex_data"]["rank"])))
    out["lock_updates"] = [int(sl.n_updates), dl["n_updates"]]

    print("RESULT:" + json.dumps(out))
""")


def _run_subprocess(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


@pytest.fixture(scope="module")
def dist_results():
    return _run_subprocess(_SCRIPT)


@pytest.fixture(scope="module")
def dist_split_results():
    return _run_subprocess(_SPLIT_SCRIPT)


def test_distributed_pagerank_bitwise_equal(dist_results):
    assert dist_results["pr_equal"]
    assert dist_results["pr_updates"][0] == dist_results["pr_updates"][1]
    assert (dist_results["pr_supersteps"][0]
            == dist_results["pr_supersteps"][1])


def test_distributed_coem_equal(dist_results):
    assert dist_results["coem_maxdiff"] < 1e-6


def test_distributed_lbp_with_edge_exchange(dist_results):
    assert dist_results["lbp_maxdiff"] < 1e-4
    assert dist_results["lbp_updates"][0] == dist_results["lbp_updates"][1]


@pytest.mark.split
def test_distributed_split_chromatic_bitwise(dist_split_results):
    """8 shards over a split Zipf graph: each shard rebuilds its hub
    chunks locally (ghost rows are one empty vrow), so the chromatic
    run is bitwise equal to single-device, update counts included."""
    assert dist_split_results["plan_split"]
    assert dist_split_results["chrom_equal"]
    assert (dist_split_results["chrom_updates"][0]
            == dist_split_results["chrom_updates"][1])


@pytest.mark.split
def test_distributed_split_locking_bitwise(dist_split_results):
    """Locking on the split plan under the saturating-window contract
    (single max_pending=nv vs distributed max_pending=plan.R — the
    bitwise regime test_locking.py pins): the claim pass runs in owner
    space, untouched by virtual rows."""
    assert dist_split_results["lock_equal"]
    assert (dist_split_results["lock_updates"][0]
            == dist_split_results["lock_updates"][1])
