"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; Mosaic on a real TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("nv,deg,rows,feat", [
    (1, 1, 1, 1),
    (7, 3, 11, 5),
    (128, 8, 128, 32),
    (200, 7, 300, 20),
    (513, 16, 300, 129),       # non-aligned padding paths
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ell_spmv_sweep(nv, deg, rows, feat, dtype):
    rng = np.random.default_rng(nv * 7 + deg)
    nbrs = jnp.asarray(rng.integers(0, rows, (nv, deg)), jnp.int32)
    w = jnp.asarray(
        rng.random((nv, deg)) * (rng.random((nv, deg)) < 0.7), dtype)
    x = jnp.asarray(rng.normal(size=(rows, feat)), dtype)
    got = ops.ell_spmv(nbrs, w, x)
    want = ref.ell_spmv_ref(nbrs, w, x)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("nv,deg,rows,d", [
    (1, 1, 2, 2),
    (50, 5, 60, 4),
    (130, 9, 100, 8),
    (257, 6, 300, 16),
])
def test_als_normal_eq_sweep(nv, deg, rows, d):
    rng = np.random.default_rng(nv + d)
    nbrs = jnp.asarray(rng.integers(0, rows, (nv, deg)), jnp.int32)
    mask = jnp.asarray(rng.random((nv, deg)) < 0.6)
    r = jnp.asarray(rng.normal(size=(nv, deg)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    a, b = ops.als_normal_eq(nbrs, mask, r, x)
    ar, br = ref.als_normal_eq_ref(nbrs, mask, r, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ar),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(b), np.asarray(br),
                               rtol=1e-4, atol=1e-4)
    # symmetric PSD-ish structure
    np.testing.assert_allclose(np.asarray(a),
                               np.asarray(a).transpose(0, 2, 1),
                               rtol=1e-5, atol=1e-5)


def _bucketize(nv, deg_counts, widths):
    """Host-side helper: rows -> (bucket row lists, starts) like SlicedEll."""
    bidx = np.searchsorted(np.asarray(widths), np.maximum(deg_counts, 1))
    return [np.nonzero(bidx == b)[0] for b in range(len(widths))]


def test_ell_spmv_bucketed_sweep():
    """Per-bucket width-specialized launches vs the monolithic oracle."""
    rng = np.random.default_rng(3)
    nv, rows, feat = 90, 120, 7
    widths = (2, 4, 9)
    deg = np.minimum(rng.zipf(2.0, nv), widths[-1])
    groups = _bucketize(nv, deg, widths)
    nbrs_b, w_b, order = [], [], []
    for g, wd in zip(groups, widths):
        nb = rng.integers(0, rows, (len(g), wd)).astype(np.int32)
        mk = np.arange(wd)[None, :] < deg[g, None]
        w = (rng.random((len(g), wd)) * mk).astype(np.float32)
        nbrs_b.append(jnp.asarray(nb))
        w_b.append(jnp.asarray(w))
        order.append(g)
    x = jnp.asarray(rng.normal(size=(rows, feat)), jnp.float32)
    got = np.asarray(ops.ell_spmv_bucketed(nbrs_b, w_b, x))
    ofs = 0
    for nb, w, g in zip(nbrs_b, w_b, order):
        want = np.asarray(ref.ell_spmv_ref(nb, w, x))
        np.testing.assert_allclose(got[ofs: ofs + len(g)], want,
                                   rtol=1e-5, atol=1e-6)
        ofs += len(g)
    assert ofs == got.shape[0] == nv


def test_als_normal_eq_bucketed_sweep():
    rng = np.random.default_rng(5)
    rows, d = 80, 6
    widths = (2, 5)
    sizes = (11, 7)
    nbrs_b, m_b, r_b = [], [], []
    for n, wd in zip(sizes, widths):
        nbrs_b.append(jnp.asarray(
            rng.integers(0, rows, (n, wd)), jnp.int32))
        m_b.append(jnp.asarray(rng.random((n, wd)) < 0.6))
        r_b.append(jnp.asarray(rng.normal(size=(n, wd)), jnp.float32))
    x = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    a, b = ops.als_normal_eq_bucketed(nbrs_b, m_b, r_b, x)
    assert a.shape == (sum(sizes), d, d) and b.shape == (sum(sizes), d)
    ofs = 0
    for nb, mk, rt, n in zip(nbrs_b, m_b, r_b, sizes):
        ar, br = ref.als_normal_eq_ref(nb, mk, rt, x)
        np.testing.assert_allclose(np.asarray(a)[ofs: ofs + n],
                                   np.asarray(ar), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(b)[ofs: ofs + n],
                                   np.asarray(br), rtol=1e-4, atol=1e-4)
        ofs += n


@pytest.mark.split
def test_als_normal_eq_split_vrows_segment_combine():
    """Hub splitting at the kernel layer (DESIGN.md §10): accumulate
    normal equations over W_cap-wide virtual-row chunks, then
    ``segment_combine`` the [n_virtual, d, d] / [n_virtual, d] partials
    per owner — equals the whole-row accumulation, since A/b are linear
    in the occupied slots.  Dummy virtual rows carry the ``n_rows``
    owner sentinel and are dropped."""
    rng = np.random.default_rng(11)
    nv, deg, rows, d, wc = 9, 13, 40, 4, 4
    nbrs = rng.integers(0, rows, (nv, deg)).astype(np.int32)
    mask = rng.random((nv, deg)) < 0.7
    rat = rng.normal(size=(nv, deg)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    ar, br = ref.als_normal_eq_ref(jnp.asarray(nbrs), jnp.asarray(mask),
                                   jnp.asarray(rat), x)
    s = -(-deg // wc)                      # chunks per row
    pad = s * wc - deg

    def chunk(a, fill):
        a = np.concatenate([a, np.full((nv, pad), fill, a.dtype)], axis=1)
        return a.reshape(nv * s, wc)

    vn, vm, vr = chunk(nbrs, 0), chunk(mask, False), chunk(rat, 0.0)
    # one dummy vrow with live-looking slots: the sentinel must drop it
    vn = np.concatenate([vn, np.ones((1, wc), np.int32)])
    vm = np.concatenate([vm, np.ones((1, wc), bool)])
    vr = np.concatenate([vr, np.ones((1, wc), np.float32)])
    owner = jnp.asarray(np.append(np.repeat(np.arange(nv), s), nv),
                        jnp.int32)
    a_v, b_v = ops.als_normal_eq(jnp.asarray(vn), jnp.asarray(vm),
                                 jnp.asarray(vr), x)
    a_c = ops.segment_combine(a_v, owner, nv)
    b_c = ops.segment_combine(b_v, owner, nv)
    assert a_c.shape == (nv, d, d) and b_c.shape == (nv, d)
    np.testing.assert_allclose(np.asarray(a_c), np.asarray(ar),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(b_c), np.asarray(br),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bh,w,dh", [
    (1, 8, 16),
    (4, 100, 32),
    (6, 1000, 64),
    (3, 513, 128),
    (2, 2048, 64),
])
def test_window_attention_sweep(bh, w, dh):
    rng = np.random.default_rng(bh * 31 + w)
    q = jnp.asarray(rng.normal(size=(bh, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, w, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, w, dh)), jnp.float32)
    kvl = jnp.asarray(rng.integers(1, w + 1, bh), jnp.int32)
    got = ops.decode_window_attention(q, k, v, kvl)
    want = ref.decode_window_attention_ref(q, k, v, kvl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_window_attention_bf16_cache():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(4, 700, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(4, 700, 64)), jnp.bfloat16)
    kvl = jnp.asarray([1, 10, 300, 700], jnp.int32)
    got = ops.decode_window_attention(q, k, v, kvl)
    want = ref.decode_window_attention_ref(q, k, v, kvl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_flash_attention_matches_dense():
    """The jnp flash path (training 32k shapes) vs the dense softmax."""
    from repro.models.attention import flash_attention, _sdpa, causal_mask
    rng = np.random.default_rng(1)
    b, s, h, dh = 2, 2048, 4, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    for window in (None, 256):
        got = flash_attention(q, k, v, causal=True, window=window, n_rep=1)
        want = _sdpa(q, k, v, causal_mask(s, window), 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


def test_ell_spmv_batched_window_launch():
    """The window-shaped [B, W] entry (DESIGN.md §8): matches the
    reference on a gathered scope, and is bit-identical to ``ell_fold``
    over the pre-gathered values at the same shape — the dense-vs-kernel
    parity anchor of the batch dispatch path."""
    from repro.kernels.ell_spmv import ell_fold, ell_spmv_batched
    rng = np.random.default_rng(7)
    b, w, rows, feat = 24, 6, 200, 5
    nbrs = jnp.asarray(rng.integers(0, rows, (b, w)), jnp.int32)
    wts = jnp.asarray(rng.random((b, w)) * (rng.random((b, w)) < 0.7),
                      jnp.float32)
    x = jnp.asarray(rng.normal(size=(rows, feat)), jnp.float32)
    sel = jnp.asarray(rng.random(b) < 0.8)
    got = np.asarray(ell_spmv_batched(nbrs, wts, x, row_mask=sel,
                                      interpret=True))
    want = np.asarray(ref.ell_spmv_ref(nbrs, wts, x, row_mask=sel))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    fold = np.asarray(ell_fold(wts, x[nbrs], row_mask=sel, interpret=True))
    assert np.array_equal(got, fold)


def test_als_normal_eq_batched_window_launch():
    """Window-shaped ALS accumulation equals the reference on [B, W]."""
    from repro.kernels.als_normal_eq import als_normal_eq_batched
    rng = np.random.default_rng(9)
    b, w, rows, d = 17, 5, 60, 4
    nbrs = jnp.asarray(rng.integers(0, rows, (b, w)), jnp.int32)
    mask = jnp.asarray(rng.random((b, w)) < 0.6)
    rat = jnp.asarray(rng.normal(size=(b, w)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    a, bb = als_normal_eq_batched(nbrs, mask, rat, x, interpret=True)
    ar, br = ref.als_normal_eq_ref(nbrs, mask, rat, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ar),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(bb), np.asarray(br),
                               rtol=1e-4, atol=1e-4)
