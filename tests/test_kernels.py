"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; Mosaic on a real TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("nv,deg,rows,feat", [
    (1, 1, 1, 1),
    (7, 3, 11, 5),
    (128, 8, 128, 32),
    (200, 7, 300, 20),
    (513, 16, 300, 129),       # non-aligned padding paths
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ell_spmv_sweep(nv, deg, rows, feat, dtype):
    rng = np.random.default_rng(nv * 7 + deg)
    nbrs = jnp.asarray(rng.integers(0, rows, (nv, deg)), jnp.int32)
    w = jnp.asarray(
        rng.random((nv, deg)) * (rng.random((nv, deg)) < 0.7), dtype)
    x = jnp.asarray(rng.normal(size=(rows, feat)), dtype)
    got = ops.ell_spmv(nbrs, w, x)
    want = ref.ell_spmv_ref(nbrs, w, x)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("nv,deg,rows,d", [
    (1, 1, 2, 2),
    (50, 5, 60, 4),
    (130, 9, 100, 8),
    (257, 6, 300, 16),
])
def test_als_normal_eq_sweep(nv, deg, rows, d):
    rng = np.random.default_rng(nv + d)
    nbrs = jnp.asarray(rng.integers(0, rows, (nv, deg)), jnp.int32)
    mask = jnp.asarray(rng.random((nv, deg)) < 0.6)
    r = jnp.asarray(rng.normal(size=(nv, deg)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    a, b = ops.als_normal_eq(nbrs, mask, r, x)
    ar, br = ref.als_normal_eq_ref(nbrs, mask, r, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ar),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(b), np.asarray(br),
                               rtol=1e-4, atol=1e-4)
    # symmetric PSD-ish structure
    np.testing.assert_allclose(np.asarray(a),
                               np.asarray(a).transpose(0, 2, 1),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bh,w,dh", [
    (1, 8, 16),
    (4, 100, 32),
    (6, 1000, 64),
    (3, 513, 128),
    (2, 2048, 64),
])
def test_window_attention_sweep(bh, w, dh):
    rng = np.random.default_rng(bh * 31 + w)
    q = jnp.asarray(rng.normal(size=(bh, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, w, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, w, dh)), jnp.float32)
    kvl = jnp.asarray(rng.integers(1, w + 1, bh), jnp.int32)
    got = ops.decode_window_attention(q, k, v, kvl)
    want = ref.decode_window_attention_ref(q, k, v, kvl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_window_attention_bf16_cache():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(4, 700, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(4, 700, 64)), jnp.bfloat16)
    kvl = jnp.asarray([1, 10, 300, 700], jnp.int32)
    got = ops.decode_window_attention(q, k, v, kvl)
    want = ref.decode_window_attention_ref(q, k, v, kvl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_flash_attention_matches_dense():
    """The jnp flash path (training 32k shapes) vs the dense softmax."""
    from repro.models.attention import flash_attention, _sdpa, causal_mask
    rng = np.random.default_rng(1)
    b, s, h, dh = 2, 2048, 4, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    for window in (None, 256):
        got = flash_attention(q, k, v, causal=True, window=window, n_rep=1)
        want = _sdpa(q, k, v, causal_mask(s, window), 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
