"""Sync operation (paper §3.3): Fold/Merge/Finalize semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import SyncOp, sum_sync, top_two_sync


@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_sum_sync_matches_numpy(values):
    vdata = {"x": jnp.asarray(np.asarray(values, np.float32))}
    s = sum_sync("total", lambda row: row["x"])
    np.testing.assert_allclose(float(s.run(vdata)),
                               np.asarray(values, np.float32).sum(),
                               rtol=1e-4, atol=1e-4)


@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=2, max_size=50, unique=True))
@settings(max_examples=50, deadline=None)
def test_top_two_sync_finds_second_best(values):
    """The paper's running example: second most popular page."""
    vdata = {"rank": jnp.asarray(np.asarray(values, np.float32))}
    s = top_two_sync("top2", lambda row: row["rank"])
    second, _ = s.run(vdata)
    want = np.sort(np.asarray(values, np.float32))[-2]
    np.testing.assert_allclose(float(second), want, rtol=1e-5)


def test_sequential_fold_equals_parallel_for_commutative():
    vdata = {"x": jnp.arange(37, dtype=jnp.float32)}
    fold = lambda acc, row: acc + row["x"] * 2.0
    merge = lambda a, b: a + b
    par = SyncOp("k", fold, merge, lambda a: a, jnp.float32(0.0))
    seq = SyncOp("k", fold, merge, lambda a: a, jnp.float32(0.0),
                 sequential=True)
    np.testing.assert_allclose(float(par.run(vdata)), float(seq.run(vdata)),
                               rtol=1e-5)


def test_sync_valid_mask():
    vdata = {"x": jnp.asarray([1.0, 2.0, 4.0, 8.0])}
    s = sum_sync("total", lambda row: row["x"])
    valid = jnp.asarray([True, False, True, False])
    np.testing.assert_allclose(float(s.local_reduce(vdata, valid)), 5.0)


def test_sync_interval_tau():
    """tau > 1: globals refresh only every tau supersteps."""
    import numpy as np
    from repro.apps import pagerank
    from repro.core import ChromaticEngine
    edges = np.asarray([[0, 1], [1, 2], [2, 0]])
    g = pagerank.make_graph(edges, 3)
    upd = pagerank.make_update(0.0)   # always reschedules
    s = pagerank.total_rank_sync(tau=2)
    eng = ChromaticEngine(g, upd, syncs=[s], max_supersteps=3)
    st1 = eng.run(num_supersteps=1)   # step 1: 1 % 2 != 0 -> stale
    init_total = float(s.run(g.vertex_data))
    assert float(st1.globals["total_rank"]) == init_total
    st2 = eng.run(num_supersteps=2)   # step 2: refreshed
    fresh = float(s.run(st2.vertex_data))
    np.testing.assert_allclose(float(st2.globals["total_rank"]), fresh,
                               rtol=1e-5)
