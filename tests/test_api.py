"""The ``repro.api`` facade (DESIGN.md §9): one paper-shaped entry point.

Three contracts are asserted here:

1. **Facade == direct construction, bit for bit.**  ``api.run(...,
   scheduler=s)`` must produce byte-identical ``EngineState`` contents
   to constructing the engine class by hand — for every registered
   scheduler single-device, and for chromatic + locking on an 8-virtual-
   device mesh (subprocess, like ``test_locking.py``, because XLA's
   device count must be set before jax initializes).  The facade is a
   *router*, never a different execution path.
2. **Registry round-trip**: every paper scheduler is registered,
   unknown names raise ``ValueError`` naming the menu, and the shared
   kwarg validator rejects knobs a strategy would silently ignore
   (``max_pending`` on chromatic, a typo'd ``dispatch=``).
3. **Termination-by-sync**: ``until=`` stops the stepping loop exactly
   where an explicit ``num_supersteps=`` run of the same length lands —
   superstep boundaries are consistent cuts (§8), so the two are
   bit-identical.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import api
from repro.apps import pagerank
from repro.core import (ChromaticEngine, LockingEngine, PriorityEngine,
                        bsp_engine, run_sequential)
from conftest import random_graph


def _setup(nv=40, ne=90, seed=3, eps=1e-5):
    g = pagerank.make_graph(random_graph(nv, ne, seed=seed), nv)
    return g, pagerank.make_update(eps), [pagerank.total_rank_sync()]


def _assert_same(res, st):
    assert np.array_equal(np.asarray(res.vertex_data["rank"]),
                          np.asarray(st.vertex_data["rank"]))
    assert res.n_updates == int(st.n_updates)
    assert res.superstep == int(st.superstep)
    assert np.array_equal(np.asarray(res.globals["total_rank"]),
                          np.asarray(st.globals["total_rank"]))


# ----------------------------------------------------------------------
# 1. facade == direct construction (single device)
# ----------------------------------------------------------------------

def test_facade_chromatic_bitwise_equals_direct():
    g, upd, syncs = _setup()
    res = api.run(g, upd, syncs=syncs, scheduler="chromatic",
                  max_supersteps=200)
    st = ChromaticEngine(g, upd, syncs=syncs, max_supersteps=200).run()
    _assert_same(res, st)


def test_facade_priority_bitwise_equals_direct():
    g, upd, syncs = _setup(eps=1e-6)
    res = api.run(g, upd, syncs=syncs, scheduler="priority", k_select=8,
                  max_supersteps=5000)
    st = PriorityEngine(g, upd, syncs=syncs, k_select=8,
                        max_supersteps=5000).run()
    _assert_same(res, st)


def test_facade_bsp_bitwise_equals_direct():
    g, upd, syncs = _setup(eps=-1.0)     # always-reschedule: fixed sweeps
    res = api.run(g, upd, syncs=syncs, scheduler="bsp", num_supersteps=6)
    st = bsp_engine(g, upd, syncs=syncs).run(num_supersteps=6)
    _assert_same(res, st)


def test_facade_locking_bitwise_equals_direct():
    g, upd, syncs = _setup(eps=1e-6)
    res = api.run(g, upd, syncs=syncs, scheduler="locking", max_pending=8,
                  max_supersteps=5000)
    st = LockingEngine(g, upd, syncs=syncs, max_pending=8,
                       max_supersteps=5000).run()
    _assert_same(res, st)


def test_facade_sequential_equals_oracle_function():
    g, upd, syncs = _setup()
    res = api.run(g, upd, syncs=syncs, scheduler="sequential",
                  max_supersteps=60)
    vd, ed, gl, n = run_sequential(g, upd, syncs=syncs, max_supersteps=60)
    assert np.array_equal(np.asarray(res.vertex_data["rank"]),
                          np.asarray(vd["rank"]))
    assert res.n_updates == n
    assert res.superstep is None       # the oracle does not count steps
    assert res.active_any is False     # drained, like the engines report
    np.testing.assert_array_equal(np.asarray(res.globals["total_rank"]),
                                  np.asarray(gl["total_rank"]))
    # an unconverged budget reports a live task set, not a vacuous None
    res1 = api.run(g, upd, syncs=syncs, scheduler="sequential",
                   max_supersteps=1)
    assert res1.active_any is True


def test_facade_sequential_replays_locking_window():
    """scheduler="sequential" + max_pending replays the locking engine's
    RemoveNext with the *same* kwarg name the engine uses."""
    g, upd, syncs = _setup()
    res = api.run(g, upd, syncs=syncs, scheduler="sequential",
                  max_pending=8, max_supersteps=200)
    vd, *_rest, n = run_sequential(g, upd, syncs=syncs, max_supersteps=200,
                                   locking_pending=8)
    assert np.array_equal(np.asarray(res.vertex_data["rank"]),
                          np.asarray(vd["rank"]))
    assert res.n_updates == n


def test_engine_spec_build_matches_run():
    """EngineSpec is the resolved configuration object behind run()."""
    g, upd, syncs = _setup()
    spec = api.EngineSpec(scheduler="priority", max_supersteps=5000,
                          options={"k_select": 8})
    eng = spec.build(g, upd, syncs)
    assert isinstance(eng, PriorityEngine)
    st = eng.run()
    res = api.run(g, upd, syncs=syncs, scheduler="priority", k_select=8,
                  max_supersteps=5000)
    _assert_same(res, st)


# ----------------------------------------------------------------------
# 2. registry round-trip + the shared kwarg validator
# ----------------------------------------------------------------------

def test_registry_lists_all_paper_schedulers():
    names = api.list_schedulers()
    assert names == sorted(names)
    for s in ("chromatic", "priority", "bsp", "locking", "sequential"):
        assert s in names
    desc = api.describe_schedulers()
    assert all(desc[n] for n in names), "every entry documents itself"


def test_unknown_scheduler_raises_with_menu():
    g, upd, syncs = _setup()
    with pytest.raises(ValueError, match="chromatic"):
        api.run(g, upd, scheduler="chromatik")


def test_undistributable_scheduler_raises():
    g, upd, syncs = _setup()
    with pytest.raises(ValueError, match="no distributed"):
        api.run(g, upd, scheduler="priority", n_shards=2, k_select=8)


@pytest.mark.parametrize("kwargs,match", [
    (dict(scheduler="chromatic", max_pending=8), "max_pending"),
    (dict(scheduler="priority", max_pending=8, k_select=8), "max_pending"),
    (dict(scheduler="bsp", k_select=8), "k_select"),
    (dict(scheduler="locking", k_select=8), "k_select"),
    (dict(scheduler="sequential", use_kernel=False), "use_kernel"),
    (dict(scheduler="chromatic", bogus_knob=1), "bogus_knob"),
    (dict(scheduler="chromatic", exchange_edges=True), "exchange_edges"),
])
def test_inapplicable_kwargs_raise(kwargs, match):
    """Knobs an engine would silently ignore must fail loudly (the
    kwarg-drift class the normalization surfaced)."""
    g, upd, syncs = _setup()
    with pytest.raises(ValueError, match=match):
        api.run(g, upd, syncs=syncs, **kwargs)


@pytest.mark.split
def test_storage_kwargs_redirect_to_from_edges():
    """``w_cap=``/``hub_split=`` are graph-storage choices: the facade
    rejects them with a pointer to ``from_edges`` (where the legal-set
    validation lives), and a split graph runs through ``api.run``
    bitwise-equal to its direct-engine construction."""
    g, upd, syncs = _setup()
    for kw in (dict(w_cap=8), dict(hub_split=True)):
        with pytest.raises(ValueError, match="from_edges"):
            api.run(g, upd, **kw)
    with pytest.raises(ValueError, match="power of two"):
        pagerank.make_graph(g.edges_np, g.n_vertices, w_cap=12)
    from repro.core.graph import zipf_edges
    edges = zipf_edges(120, alpha=2.0, max_deg=32, seed=3)
    gs = pagerank.make_graph(edges, 120, w_cap=8)
    assert gs.ell.is_split
    res = api.run(gs, upd, scheduler="chromatic", max_supersteps=60)
    direct = ChromaticEngine(gs, upd, max_supersteps=60).run()
    assert np.array_equal(np.asarray(res.vertex_data["rank"]),
                          np.asarray(direct.vertex_data["rank"]))
    assert res.n_updates == int(direct.n_updates)


def test_invalid_dispatch_rejected_everywhere():
    g, upd, syncs = _setup()
    with pytest.raises(ValueError, match="dispatch"):
        api.run(g, upd, dispatch="wide")
    # ... and at direct engine construction (shared validator)
    with pytest.raises(ValueError, match="dispatch"):
        ChromaticEngine(g, upd, dispatch="wide")
    with pytest.raises(ValueError, match="dispatch"):
        LockingEngine(g, upd, dispatch="wide")


def test_invalid_scalar_knobs_rejected():
    g, upd, syncs = _setup()
    with pytest.raises(ValueError, match="max_pending"):
        api.run(g, upd, scheduler="locking", max_pending=0)
    with pytest.raises(ValueError, match="k_select"):
        api.run(g, upd, scheduler="priority", k_select=-1)
    with pytest.raises(ValueError, match="n_shards"):
        api.run(g, upd, n_shards=0)
    # bool is an int subclass: a flag must not become a window of 1
    with pytest.raises(ValueError, match="k_select"):
        api.run(g, upd, scheduler="priority", k_select=True)


def test_registry_rejects_hijacking_a_taken_name():
    """Re-registering the same strategy is idempotent and keeps the
    existing entry's metadata; a different factory under a taken name
    would be a silent engine swap."""
    from repro.core import ChromaticEngine, register_scheduler
    # idempotent: what a module reload does — sparse metadata must NOT
    # clobber the existing entry (description etc. survive)
    entry = register_scheduler("chromatic", ChromaticEngine)
    assert entry.description, "prior entry returned untouched"
    assert api.describe_schedulers()["chromatic"]
    with pytest.raises(ValueError, match="already registered"):
        register_scheduler("chromatic", lambda *a, **k: None)
    # two distinct lambdas share a qualname — identity only, no
    # silent swap through the reload-idempotency hole
    register_scheduler("_lambda_probe", lambda *a, **k: "A")
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_scheduler("_lambda_probe", lambda *a, **k: "B")
    finally:
        from repro.core.registry import _SCHEDULERS
        _SCHEDULERS.pop("_lambda_probe", None)


def test_explicit_partition_builds_degenerate_distributed_engine():
    """partition= at n_shards=1 selects the shard_map variant on the
    M=1 plan (bit-identical to the single-device strategy, asserted in
    test_locking.py) — how graph_dryrun reaches the distributed code
    path on one device."""
    from repro.core import DistributedLockingEngine
    g, upd, syncs = _setup()
    eng = api.build_engine(g, upd, scheduler="locking", max_pending=8,
                           partition=np.zeros(g.n_vertices, np.int64))
    assert isinstance(eng, DistributedLockingEngine)
    assert eng.plan.M == 1
    # a prebuilt plan passes through verbatim (no second ShardPlan.build)
    eng2 = api.build_engine(g, upd, scheduler="locking", max_pending=8,
                            partition=eng.plan)
    assert eng2.plan is eng.plan
    # ... but a plan whose M contradicts n_shards is rejected
    with pytest.raises(ValueError, match="n_shards"):
        api.build_engine(g, upd, scheduler="locking", n_shards=4,
                         partition=eng.plan)


def test_colorless_graph_rejected_early_for_color_schedulers():
    """needs_colors registry metadata gives the uniform early error."""
    from repro.core.graph import DataGraph
    from repro.core import Consistency, UpdateFn, UpdateResult
    edges = random_graph(20, 40, seed=2)
    g = DataGraph.from_edges(20, edges, {"x": np.zeros(20, np.float32)})
    upd = UpdateFn(lambda s: UpdateResult(v_data=s.v_data),
                   Consistency.VERTEX)
    for sched in ("chromatic", "priority"):
        with pytest.raises(ValueError, match="colors"):
            api.build_engine(g, upd, scheduler=sched)
    # the sequential oracle's default mode replays color order, so it
    # too must fail loudly without colors ...
    with pytest.raises(ValueError, match="color"):
        api.run(g, upd, scheduler="sequential", max_supersteps=2)
    # ... while its colorless locking replay works, like the engine
    api.run(g, upd, scheduler="sequential", max_pending=4,
            max_supersteps=2)
    # the locking engine is the documented colorless path
    api.build_engine(g, upd, scheduler="locking", max_pending=4)


def test_facade_dispatch_override_still_bitwise():
    """Forcing a launch shape through the facade routes to the same
    dispatch= the engine accepts (cross-path parity, DESIGN.md §8)."""
    g, upd, syncs = _setup(eps=-1.0)
    res_bucket = api.run(g, upd, scheduler="bsp", dispatch="bucket",
                         num_supersteps=4)
    res_batch = api.run(g, upd, scheduler="bsp", dispatch="batch",
                        num_supersteps=4)
    assert np.array_equal(np.asarray(res_bucket.vertex_data["rank"]),
                          np.asarray(res_batch.vertex_data["rank"]))


def test_consistency_override():
    """consistency= is the paper's set_scope_type: it rewrites the
    update's declared scope model before the engine sees it."""
    g, upd, syncs = _setup()
    res = api.run(g, upd, scheduler="locking", consistency="vertex",
                  max_pending=4, max_supersteps=10, num_supersteps=1)
    from repro.core import Consistency
    assert res.engine.update_fn.consistency == Consistency.VERTEX
    with pytest.raises(ValueError, match="consistency"):
        api.run(g, upd, consistency="sorta-safe")


# ----------------------------------------------------------------------
# 3. until= / trace=: the uniform run loop
# ----------------------------------------------------------------------

def test_until_matches_explicit_superstep_run():
    """Termination-by-sync lands on a superstep boundary; rerunning the
    same number of explicit supersteps is bit-identical (§8: superstep
    boundaries are consistent cuts)."""
    g, upd, syncs = _setup()
    full = api.run(g, upd, syncs=syncs, scheduler="chromatic",
                   max_supersteps=200)
    # ranks start at 1.0, so total_rank starts at Nv and relaxes toward
    # the (smaller) fixed point: the halfway mark binds strictly mid-run
    target = (g.n_vertices + float(full.globals["total_rank"])) / 2
    pred = lambda gl: float(gl["total_rank"]) < target
    res_u = api.run(g, upd, syncs=syncs, scheduler="chromatic",
                    max_supersteps=200, until=pred)
    assert 0 < res_u.superstep < full.superstep, "predicate binds mid-run"
    assert float(res_u.globals["total_rank"]) < target
    res_e = api.run(g, upd, syncs=syncs, scheduler="chromatic",
                    num_supersteps=res_u.superstep)
    assert np.array_equal(np.asarray(res_u.vertex_data["rank"]),
                          np.asarray(res_e.vertex_data["rank"]))
    assert res_u.n_updates == res_e.n_updates
    # the previous superstep must NOT satisfy the predicate
    res_p = api.run(g, upd, syncs=syncs, scheduler="chromatic",
                    num_supersteps=res_u.superstep - 1)
    assert float(res_p.globals["total_rank"]) >= target


def test_until_matches_locking_engine_run():
    g, upd, syncs = _setup(eps=1e-6)
    full = api.run(g, upd, syncs=syncs, scheduler="locking", max_pending=8,
                   max_supersteps=5000)
    target = (g.n_vertices + float(full.globals["total_rank"])) / 2
    pred = lambda gl: float(gl["total_rank"]) < target
    res_u = api.run(g, upd, syncs=syncs, scheduler="locking", max_pending=8,
                    max_supersteps=5000, until=pred)
    assert 0 < res_u.superstep < full.superstep
    res_e = api.run(g, upd, syncs=syncs, scheduler="locking", max_pending=8,
                    num_supersteps=res_u.superstep)
    assert np.array_equal(np.asarray(res_u.vertex_data["rank"]),
                          np.asarray(res_e.vertex_data["rank"]))
    assert res_u.n_updates == res_e.n_updates


def test_until_respects_drain_and_max_supersteps():
    g, upd, syncs = _setup()
    never = lambda gl: False
    res = api.run(g, upd, syncs=syncs, until=never, max_supersteps=200)
    st = ChromaticEngine(g, upd, syncs=syncs, max_supersteps=200).run()
    _assert_same(res, st)          # stepping loop == fused while-loop
    assert not res.active_any


def test_trace_records_every_superstep():
    g, upd, syncs = _setup()
    res = api.run(g, upd, syncs=syncs, trace=True, max_supersteps=200)
    assert len(res.trace) == res.superstep
    steps = [r["superstep"] for r in res.trace]
    assert steps == list(range(1, res.superstep + 1))
    assert res.trace[-1]["active"] == 0
    # custom trace callables see the EngineState
    res_c = api.run(g, upd, syncs=syncs, num_supersteps=3,
                    trace=lambda st: float(st.vertex_data["rank"][0]))
    assert len(res_c.trace) == 3


def test_until_rejected_for_distributed_and_sequential_trace():
    g, upd, syncs = _setup()
    with pytest.raises(ValueError, match="single-device"):
        api.run(g, upd, n_shards=2, until=lambda gl: True)
    with pytest.raises(ValueError, match="trace"):
        api.run(g, upd, scheduler="sequential", trace=True)


def test_until_on_sequential_oracle():
    """The oracle honors the same termination-by-sync contract,
    including pre-step evaluation: a predicate already true on the
    initial sync results executes nothing, exactly like the engines."""
    g, upd, syncs = _setup()
    res = api.run(g, upd, syncs=syncs, scheduler="sequential",
                  max_supersteps=200,
                  until=lambda gl: float(gl["total_rank"]) < 48.0)
    assert float(res.globals["total_rank"]) < 48.0
    always = lambda gl: True
    res_s = api.run(g, upd, syncs=syncs, scheduler="sequential",
                    max_supersteps=200, until=always)
    res_e = api.run(g, upd, syncs=syncs, scheduler="chromatic",
                    max_supersteps=200, until=always)
    assert res_s.n_updates == res_e.n_updates == 0


def test_trace_false_means_off():
    g, upd, syncs = _setup()
    res = api.run(g, upd, syncs=syncs, trace=False, num_supersteps=2)
    assert res.trace is None
    # ... including where an active trace would be rejected
    res_s = api.run(g, upd, syncs=syncs, scheduler="sequential",
                    trace=False, max_supersteps=2)
    assert res_s.trace is None


# ----------------------------------------------------------------------
# facade == direct on an 8-virtual-device mesh (subprocess: XLA_FLAGS
# must be set before jax initializes; reuses test_locking's harness
# shape)
# ----------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro import api
    from repro.apps import pagerank
    from repro.core import (DistributedChromaticEngine,
                            DistributedLockingEngine, ShardPlan,
                            two_phase_partition)
    from repro.core.graph import zipf_edges

    nv = 80
    edges = zipf_edges(nv, alpha=2.0, max_deg=24, seed=7)
    g = pagerank.make_graph(edges, nv)
    upd = pagerank.make_update(1e-4)
    syncs = [pagerank.total_rank_sync()]
    asg = two_phase_partition(nv, edges, 8, seed=0)
    plan = ShardPlan.build(g, asg, 8)
    out = {}

    # --- chromatic, 8 shards: facade vs direct ---
    direct = DistributedChromaticEngine(g, plan, upd, syncs=syncs,
                                        max_supersteps=300).run()
    res = api.run(g, upd, syncs=syncs, scheduler="chromatic", n_shards=8,
                  partition=asg, max_supersteps=300)
    out["chrom_equal"] = bool(np.array_equal(
        np.asarray(direct["vertex_data"]["rank"]),
        np.asarray(res.vertex_data["rank"])))
    out["chrom_counts"] = [direct["n_updates"], res.n_updates,
                           direct["supersteps"], res.superstep]

    # --- locking, 8 shards: facade vs direct (binding window) ---
    directl = DistributedLockingEngine(g, plan, upd, syncs=syncs,
                                       max_pending=8,
                                       max_supersteps=20000).run()
    resl = api.run(g, upd, syncs=syncs, scheduler="locking", n_shards=8,
                   partition=asg, max_pending=8, max_supersteps=20000)
    out["lock_equal"] = bool(np.array_equal(
        np.asarray(directl["vertex_data"]["rank"]),
        np.asarray(resl.vertex_data["rank"])))
    out["lock_counts"] = [directl["n_updates"], resl.n_updates,
                          directl["supersteps"], resl.superstep]
    out["lock_stats"] = [directl["ghost_rows_sent"],
                         resl.stats["ghost_rows_sent"],
                         directl["ghost_rows_full"],
                         resl.stats["ghost_rows_full"]]

    # --- default partition is two_phase_partition over the graph's
    # stored (bucket-major) edge order, asserted at the plan level:
    # chromatic *results* are partition-invariant, so comparing ranks
    # would be vacuous ---
    eng_dp = api.build_engine(g, upd, syncs=syncs, scheduler="chromatic",
                              n_shards=8, max_supersteps=300)
    out["default_partition_matches"] = bool(np.array_equal(
        np.asarray(eng_dp.plan.assignment),
        np.asarray(two_phase_partition(nv, g.edges_np, 8, seed=0))))

    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def api_dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.distributed
def test_facade_distributed_chromatic_bitwise_equal(api_dist_results):
    r = api_dist_results
    assert r["chrom_equal"]
    du, fu, ds, fs = r["chrom_counts"]
    assert du == fu and ds == fs
    assert r["default_partition_matches"], \
        "facade default must be two_phase_partition(edges_np, seed=0)"


@pytest.mark.distributed
def test_facade_distributed_locking_bitwise_equal(api_dist_results):
    r = api_dist_results
    assert r["lock_equal"]
    du, fu, ds, fs = r["lock_counts"]
    assert du == fu and ds == fs
    d_sent, f_sent, d_full, f_full = r["lock_stats"]
    assert d_sent == f_sent and d_full == f_full, \
        "RunResult.stats must surface the versioned ghost-sync counts"
