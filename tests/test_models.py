"""Per-architecture smoke tests (reduced configs, CPU): one forward /
train step, shape + finiteness assertions, decode-step cache mechanics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import pipeline
from repro.models import model as M
from repro.optim import adamw
from repro.serve import engine as S
from repro.train.steps import make_train_step

ARCHS = configs.ARCHS


def _batch(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.arch_type == "audio":
        return {
            "frames": jnp.asarray(rng.normal(size=(b, s // 2, cfg.d_model)),
                                  jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s // 2))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s // 2))),
        }
    if cfg.arch_type == "vlm":
        p = cfg.n_frontend_tokens
        return {
            "patches": jnp.asarray(rng.normal(size=(b, p, cfg.d_model)),
                                   jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s - p))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s - p))),
        }
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get(arch).reduced()
    assert cfg.n_layers <= 8 and cfg.d_model <= 512
    assert cfg.moe is None or cfg.moe.n_experts <= 4
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, 2, 32)
    loss, mets = jax.jit(lambda p, b: M.forward(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    # one full optimizer step
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(lr=1e-3)))
    params2, opt2, mets2 = step(params, opt, batch)
    assert bool(jnp.isfinite(mets2["loss"]))
    assert bool(jnp.isfinite(mets2["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = configs.get(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, ctx = 2, 32
    state = S.init_cache(cfg, b, ctx)
    tok = jnp.zeros((b, 1), jnp.int32)
    fn = jax.jit(lambda p, t, s: S.decode_step(p, cfg, t, s))
    logits, st2 = fn(params, tok, state)
    assert logits.shape[0] == b
    assert bool(jnp.isfinite(logits[:, :cfg.vocab]).all())
    assert int(st2.cache_len[0]) == int(state.cache_len[0]) + 1
    # a second step consumes the updated cache without shape drift
    logits2, st3 = fn(params, tok, st2)
    assert bool(jnp.isfinite(logits2[:, :cfg.vocab]).all())
    if not isinstance(state.cache_k, dict):
        assert st3.cache_k.shape == state.cache_k.shape


@pytest.mark.parametrize("arch", ["qwen3-4b", "falcon-mamba-7b",
                                  "qwen3-moe-235b-a22b"])
def test_tiny_training_reduces_loss(arch):
    cfg = configs.get(arch).reduced()
    cfg = dataclasses.replace(cfg, vocab=64)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(
        cfg, adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)))
    batch = _batch(cfg, 4, 16, seed=3)   # fixed batch -> should memorize
    losses = []
    for _ in range(15):
        params, opt, mets = step(params, opt, batch)
        losses.append(float(mets["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_decode_matches_forward_logits():
    """Teacher-forced decode reproduces the training forward's next-token
    logits (cache correctness end-to-end)."""
    cfg = configs.get("qwen3-4b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, s = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))
    # forward logits at the last position
    batch = {"tokens": toks, "labels": toks}
    want = M.prefill(params, cfg, {"tokens": toks})
    # decode path: feed tokens one by one through the cache
    state = S.init_cache(cfg, b, s)
    state = dataclasses.replace(state,
                                cache_len=jnp.zeros((b,), jnp.int32))
    fn = jax.jit(lambda p, t, st: S.decode_step(p, cfg, t, st))
    for i in range(s):
        logits, state = fn(params, toks[:, i: i + 1], state)
    np.testing.assert_allclose(np.asarray(logits[:, :cfg.vocab]),
                               np.asarray(want[:, :cfg.vocab]),
                               rtol=3e-2, atol=3e-2)


def test_mamba_decode_matches_train_scan():
    """O(1) recurrent decode equals the chunked train scan step-by-step."""
    from repro.models import mamba
    cfg = configs.get("falcon-mamba-7b").reduced()
    p = mamba.init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(0)
    b, s = 2, 9
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16)
    y_train = mamba.apply_train(p, cfg, x)
    state = mamba.init_decode_state(cfg, b)
    outs = []
    for i in range(s):
        y, state = mamba.apply_decode(p, cfg, x[:, i: i + 1], state)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_train, np.float32),
                               np.asarray(y_dec, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_moe_capacity_drops_are_bounded():
    """Router load-balance: with a uniform-ish router, few tokens drop."""
    from repro.models import moe
    cfg = configs.get("phi3.5-moe-42b-a6.6b").reduced()
    p = moe.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)), jnp.bfloat16)
    y, aux = moe.apply(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) < 4.0, "aux loss should be O(1) at random init"


def test_param_counts_match_assignment():
    """Analytic counts hit the models' advertised sizes (within 3%)."""
    expect = {
        "qwen3-moe-235b-a22b": (235e9, 22e9),
        "phi3.5-moe-42b-a6.6b": (42e9, 6.6e9),
        "jamba-1.5-large-398b": (398e9, 94e9),
        "deepseek-coder-33b": (33e9, None),
        "falcon-mamba-7b": (7.3e9, None),
    }
    for name, (tot, act) in expect.items():
        pc = configs.get(name).param_count()
        assert abs(pc["total"] - tot) / tot < 0.05, name
        if act:
            assert abs(pc["active"] - act) / act < 0.05, name
