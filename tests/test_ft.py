"""Fault tolerance (ISSUE 9 / DESIGN.md §12): sharded snapshots, fault
injection, and the supervised restart loop.

The in-process tests run the M=1 degenerate plan and the single-device
engines; the 8-virtual-device kill-recovery matrix runs in a subprocess
(XLA_FLAGS before jax init) and is marked ``faults`` so CI gives it a
real multi-device job.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # optional test dep (like
    from hypothesis import given, settings   # test_coloring.py): the
    from hypothesis import strategies as st  # property test skips, the
    HAVE_HYPOTHESIS = True             # deterministic matrix still runs
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import api
from repro.apps import pagerank
from repro.ft import (CheckpointWriteFault, FaultEvent, FaultPlan,
                      SnapshotError, SupervisorGaveUp, latest_valid_snapshot,
                      load_carry, supervised, validate_snapshot,
                      write_snapshot)
from repro.ft.sync_snapshot import snapshot_as_program
from repro.train.checkpoint import (CheckpointError, restore,
                                    restore_engine_state, save,
                                    snapshot_engine_state)
from conftest import random_graph


def _problem(nv=50, ne=120, seed=3):
    edges = random_graph(nv, ne, seed=seed)
    graph, update, syncs = pagerank.build(edges, nv)
    return graph, update, syncs


def _rank(result):
    return np.asarray(result.vertex_data["rank"])


# ----------------------------------------------------------------------
# Kill/resume matrix, M=1 (the 8-device half lives in the subprocess)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["chromatic", "locking"])
def test_single_device_kill_resume_bitwise(tmp_path, scheduler):
    graph, update, syncs = _problem()
    kw = dict(syncs=syncs, scheduler=scheduler, max_supersteps=12)
    base = api.run(graph, update, **kw)
    assert base.restarts is None          # no supervision engaged
    faults = FaultPlan([FaultEvent("kill", superstep=5)])
    r = api.run(graph, update, **kw, checkpoint_every=2,
                checkpoint_dir=str(tmp_path), faults=faults)
    assert [x.error_type for x in r.restarts] == ["InjectedKill"]
    assert r.restarts[0].restored_superstep == 4
    assert r.superstep == base.superstep
    assert np.array_equal(_rank(base), _rank(r))


@pytest.mark.parametrize("scheduler", ["chromatic", "locking"])
def test_distributed_m1_kill_resume_bitwise(tmp_path, scheduler):
    graph, update, syncs = _problem()
    assign = np.zeros(graph.n_vertices, np.int64)
    kw = dict(syncs=syncs, scheduler=scheduler, max_supersteps=12,
              n_shards=1, partition=assign)
    base = api.run(graph, update, **kw)
    faults = FaultPlan([FaultEvent("kill", superstep=5)])
    r = api.run(graph, update, **kw, checkpoint_every=2,
                checkpoint_dir=str(tmp_path), faults=faults)
    assert [x.error_type for x in r.restarts] == ["InjectedKill"]
    assert r.superstep == base.superstep
    assert r.n_updates == base.n_updates
    assert np.array_equal(_rank(base), _rank(r))


def test_kill_with_no_checkpoints_restarts_from_scratch(tmp_path):
    """A kill before the first snapshot restarts from superstep 0 and
    still finishes bitwise equal (restored_superstep stays None)."""
    graph, update, syncs = _problem()
    base = api.run(graph, update, syncs=syncs, max_supersteps=8)
    faults = FaultPlan([FaultEvent("kill", superstep=1)])
    r = api.run(graph, update, syncs=syncs, max_supersteps=8,
                checkpoint_every=5, checkpoint_dir=str(tmp_path),
                faults=faults)
    assert r.restarts[0].restored_superstep is None
    assert np.array_equal(_rank(base), _rank(r))


def test_transient_and_straggle(tmp_path):
    graph, update, syncs = _problem()
    base = api.run(graph, update, syncs=syncs, max_supersteps=10)
    faults = FaultPlan([FaultEvent("transient", superstep=3),
                        FaultEvent("straggle", superstep=5,
                                   delay_s=0.001)])
    r = api.run(graph, update, syncs=syncs, max_supersteps=10,
                checkpoint_every=2, checkpoint_dir=str(tmp_path),
                faults=faults)
    # straggle delays but never restarts; transient restarts once
    assert [x.error_type for x in r.restarts] == ["TransientFault"]
    assert faults.all_fired
    assert np.array_equal(_rank(base), _rank(r))


def test_supervisor_gives_up(tmp_path):
    graph, update, syncs = _problem()
    faults = FaultPlan([FaultEvent("kill", superstep=s)
                        for s in (2, 3, 4)])
    with pytest.raises(SupervisorGaveUp, match="after 1 restart"):
        api.run(graph, update, syncs=syncs, max_supersteps=10,
                checkpoint_every=2, checkpoint_dir=str(tmp_path),
                faults=faults, max_restarts=1)


def test_until_composes_with_checkpointing(tmp_path):
    graph, update, syncs = _problem()

    def make_stop(n):      # fires at the n-th boundary check
        seen = []

        def stop(g):
            seen.append(0)
            return len(seen) >= n
        return stop

    base = api.run(graph, update, syncs=syncs, until=make_stop(4))
    r = api.run(graph, update, syncs=syncs, until=make_stop(4),
                checkpoint_every=2, checkpoint_dir=str(tmp_path))
    assert r.superstep == base.superstep == 3
    assert np.array_equal(_rank(base), _rank(r))


# ----------------------------------------------------------------------
# resume_from through the facade
# ----------------------------------------------------------------------

def test_resume_from_rebuilds_plan_and_continues_bitwise(tmp_path):
    graph, update, syncs = _problem()
    assign = np.zeros(graph.n_vertices, np.int64)
    kw = dict(syncs=syncs, scheduler="chromatic", n_shards=1,
              partition=assign)
    api.run(graph, update, **kw, num_supersteps=6, checkpoint_every=3,
            checkpoint_dir=str(tmp_path))
    snap = latest_valid_snapshot(str(tmp_path))
    assert snap is not None and snap.endswith("step_00000006")
    # no partition= passed: the plan is rebuilt from the snapshot
    resumed = api.run(graph, update, syncs=syncs, scheduler="chromatic",
                      num_supersteps=10, resume_from=snap)
    full = api.run(graph, update, **kw, num_supersteps=10)
    assert resumed.superstep == 10
    assert np.array_equal(_rank(full), _rank(resumed))


def test_resume_from_single_device_state_file(tmp_path):
    graph, update, syncs = _problem()
    r1 = api.run(graph, update, syncs=syncs, num_supersteps=5,
                 checkpoint_every=5, checkpoint_dir=str(tmp_path))
    f = os.path.join(str(tmp_path), "state_step_00000005.npz")
    assert os.path.exists(f)
    resumed = api.run(graph, update, syncs=syncs, num_supersteps=9,
                      resume_from=f)
    full = api.run(graph, update, syncs=syncs, num_supersteps=9)
    assert resumed.superstep == 9
    assert np.array_equal(_rank(full), _rank(resumed))


def test_resume_from_wrong_scheduler_or_partition_refused(tmp_path):
    graph, update, syncs = _problem()
    assign = np.zeros(graph.n_vertices, np.int64)
    api.run(graph, update, syncs=syncs, n_shards=1, partition=assign,
            num_supersteps=4, checkpoint_every=2,
            checkpoint_dir=str(tmp_path))
    snap = latest_valid_snapshot(str(tmp_path))
    with pytest.raises(ValueError, match="scheduler"):
        api.run(graph, update, syncs=syncs, scheduler="locking",
                num_supersteps=8, resume_from=snap)
    # a plan with a different partition identity must be refused at load
    eng = api.build_engine(graph, update, syncs=syncs, n_shards=1,
                           partition=assign)
    with pytest.raises(SnapshotError, match="partition fingerprint"):
        load_carry(snap, eng.init_carry(), expect_partition="deadbeef")


# ----------------------------------------------------------------------
# Snapshot integrity: atomicity, torn writes, digests
# ----------------------------------------------------------------------

def _engine_and_carry(tmp_path, nv=40):
    graph, update, syncs = _problem(nv=nv, ne=90)
    assign = np.zeros(graph.n_vertices, np.int64)
    eng = api.build_engine(graph, update, syncs=syncs, n_shards=1,
                           partition=assign)
    carry = eng.init_carry()
    carry = eng.step_chunk(carry, 3)
    return eng, carry


def test_checkpoint_write_fault_leaves_previous_snapshot_valid(tmp_path):
    eng, carry = _engine_and_carry(tmp_path)
    plan = eng.plan
    kw = dict(scheduler="chromatic",
              partition=plan.partition_fingerprint,
              assignment=plan.assignment)
    first = write_snapshot(str(tmp_path), carry, **kw)
    carry2 = eng.step_chunk(carry, 6)
    faults = FaultPlan([FaultEvent("checkpoint_fail", superstep=6)])
    with pytest.raises(CheckpointWriteFault):
        write_snapshot(str(tmp_path), carry2, **kw, faults=faults)
    # the torn attempt never published; the previous snapshot is the
    # newest valid one and still loads
    assert latest_valid_snapshot(str(tmp_path)) == first
    restored, step = load_carry(first, eng.init_carry(),
                                expect_partition=plan.partition_fingerprint)
    assert step == 3
    assert np.array_equal(np.asarray(restored["vertex_data"]["rank"]),
                          np.asarray(carry["vertex_data"]["rank"]))


def test_corrupted_and_truncated_snapshots_are_skipped(tmp_path):
    eng, carry = _engine_and_carry(tmp_path)
    plan = eng.plan
    kw = dict(scheduler="chromatic",
              partition=plan.partition_fingerprint,
              assignment=plan.assignment)
    good = write_snapshot(str(tmp_path), carry, **kw)
    carry2 = eng.step_chunk(carry, 5)
    bad = write_snapshot(str(tmp_path), carry2, **kw)
    assert latest_valid_snapshot(str(tmp_path)) == bad

    # flip bytes in a shard file: digest mismatch
    shard = os.path.join(bad, "shard_00000.npz")
    blob = bytearray(open(shard, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(shard, "wb").write(bytes(blob))
    with pytest.raises(SnapshotError, match="digest mismatch"):
        validate_snapshot(bad)
    assert latest_valid_snapshot(str(tmp_path)) == good

    # truncate the file entirely
    open(shard, "wb").close()
    with pytest.raises(SnapshotError, match="digest mismatch"):
        validate_snapshot(bad)

    # remove it: named as missing
    os.remove(shard)
    with pytest.raises(SnapshotError, match="missing file"):
        validate_snapshot(bad)

    # corrupt the manifest json
    mpath = os.path.join(good, "MANIFEST.json")
    open(mpath, "w").write("{not json")
    with pytest.raises(SnapshotError, match="unreadable manifest"):
        validate_snapshot(good)
    assert latest_valid_snapshot(str(tmp_path)) is None

    # no manifest at all (torn directory)
    os.remove(mpath)
    with pytest.raises(SnapshotError, match="no MANIFEST.json"):
        validate_snapshot(good)


def test_snapshot_identity_checks(tmp_path):
    eng, carry = _engine_and_carry(tmp_path)
    plan = eng.plan
    p = write_snapshot(str(tmp_path), carry, scheduler="chromatic",
                       partition=plan.partition_fingerprint,
                       assignment=plan.assignment)
    validate_snapshot(p, expect_partition=plan.partition_fingerprint,
                      expect_scheduler="chromatic", expect_n_shards=1)
    with pytest.raises(SnapshotError, match="scheduler"):
        validate_snapshot(p, expect_scheduler="locking")
    with pytest.raises(SnapshotError, match="shards"):
        validate_snapshot(p, expect_n_shards=8)
    with pytest.raises(SnapshotError, match="partition fingerprint"):
        validate_snapshot(p, expect_partition="0000000000000000")


# ----------------------------------------------------------------------
# Hypothesis roundtrip: sharded snapshots across dtypes and shard counts
# ----------------------------------------------------------------------

_DTYPES = [np.float32, np.int32, np.bool_, jnp.bfloat16]


def _roundtrip_once(d, m, r, dtype, step, seed):
    """write_snapshot >> load_carry is the identity on any carry-shaped
    tree — bitwise, dtype-preserving (incl. the bfloat16 recast path),
    for any shard count and superstep."""
    rng = np.random.default_rng(seed)

    def arr(*shape):
        raw = rng.standard_normal(shape) * 100
        if dtype == np.bool_:
            return raw > 0
        return jnp.asarray(raw).astype(dtype)

    carry = {
        "vertex_data": {"x": arr(m, r), "y": arr(m, r, 2)},
        "edge_data": {"w": arr(m, r + 1)},
        "active": jnp.asarray(rng.integers(0, 2, (m, r)), bool),
        "priority": jnp.asarray(rng.standard_normal((m, r)), jnp.float32),
        "globals": {"total": arr()},
        "superstep": jnp.int32(step),
        "n_updates": jnp.asarray(rng.integers(0, 99, (m,)), jnp.int32),
    }
    p = write_snapshot(str(d), carry, scheduler="chromatic",
                       partition="abc", assignment=np.zeros(4, np.int64))
    like = jax.tree.map(jnp.zeros_like, carry)
    restored, got_step = load_carry(p, like, expect_partition="abc")
    assert got_step == step
    for (ka, a), (kb, b) in zip(
            sorted(jax.tree_util.tree_flatten_with_path(carry)[0],
                   key=str),
            sorted(jax.tree_util.tree_flatten_with_path(restored)[0],
                   key=str)):
        assert jnp.asarray(a).dtype == jnp.asarray(b).dtype, str(ka)
        assert np.array_equal(np.asarray(jnp.asarray(a).astype(jnp.float32)),
                              np.asarray(jnp.asarray(b).astype(jnp.float32))
                              ), str(ka)


@pytest.mark.parametrize("dtype", _DTYPES, ids=lambda d: np.dtype(d).name)
@pytest.mark.parametrize("m", [1, 3])
def test_sharded_snapshot_roundtrip_matrix(tmp_path, dtype, m):
    _roundtrip_once(tmp_path, m, 4, dtype, step=7, seed=0)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31),
           m=st.integers(min_value=1, max_value=3),
           r=st.integers(min_value=1, max_value=5),
           dtype_idx=st.integers(min_value=0, max_value=len(_DTYPES) - 1),
           step=st.integers(min_value=0, max_value=10_000))
    def test_sharded_snapshot_roundtrip_property(tmp_path_factory, seed, m,
                                                 r, dtype_idx, step):
        d = tmp_path_factory.mktemp("snap")
        _roundtrip_once(d, m, r, _DTYPES[dtype_idx], step, seed)


# ----------------------------------------------------------------------
# train.checkpoint satellites: atomic save, CheckpointError, schema
# ----------------------------------------------------------------------

def test_atomic_save_leaves_no_tmp_residue(tmp_path):
    p = str(tmp_path / "ck.npz")
    save(p, {"a": jnp.arange(4)}, step=7)
    save(p, {"a": jnp.arange(4) * 2}, step=8)   # overwrite in place
    assert os.listdir(str(tmp_path)) == ["ck.npz"]
    tree, step = restore(p, {"a": jnp.zeros(4, jnp.int32)})
    assert step == 8 and int(np.asarray(tree["a"])[3]) == 6


def test_restore_errors_are_named(tmp_path):
    p = str(tmp_path / "ck.npz")
    with pytest.raises(CheckpointError, match="not found"):
        restore(p, {"a": jnp.zeros(2)})
    open(p, "wb").write(b"this is not a zip archive")
    with pytest.raises(CheckpointError, match="corrupt"):
        restore(p, {"a": jnp.zeros(2)})
    save(p, {"a": jnp.zeros(2)})
    with pytest.raises(CheckpointError, match="missing key 'b'"):
        restore(p, {"b": jnp.zeros(2)})
    with pytest.raises(CheckpointError, match="shape"):
        restore(p, {"a": jnp.zeros(3)})


def test_engine_snapshot_schema_and_field_guards(tmp_path):
    graph, update, syncs = _problem(nv=30, ne=60)
    eng = api.build_engine(graph, update, syncs=syncs)
    state = eng.init_state(None, None)
    p = str(tmp_path / "snap.npz")
    snapshot_engine_state(p, state)
    restored = restore_engine_state(p, state)
    assert int(restored.superstep) == int(state.superstep)

    # unversioned snapshot (pre-schema format): refused by name
    flat = dict(np.load(p))
    del flat["__schema__"]
    np.savez(p[:-4], **flat)
    with pytest.raises(CheckpointError, match="not a versioned"):
        restore_engine_state(p, state)

    # wrong schema number
    flat["__schema__"] = np.asarray(99)
    np.savez(p[:-4], **flat)
    with pytest.raises(CheckpointError, match="schema 99"):
        restore_engine_state(p, state)

    # field-set drift: the mismatched fields are named
    from repro.train import checkpoint as ckpt
    flat["__schema__"] = np.asarray(ckpt.ENGINE_SNAPSHOT_SCHEMA)
    flat["__fields__"] = np.asarray("vertex_data,active")
    np.savez(p[:-4], **flat)
    with pytest.raises(CheckpointError, match="missing.*superstep"):
        restore_engine_state(p, state)


# ----------------------------------------------------------------------
# §8: the snapshot as a GraphLab program
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1])
def test_sync_snapshot_program_matches_direct_copy(n_shards):
    graph, update, syncs = _problem(nv=30, ne=60)
    # advance the graph a bit so the snapshot isn't trivially the init
    r = api.run(graph, update, syncs=syncs, num_supersteps=3)
    import dataclasses
    moved = dataclasses.replace(graph, vertex_data=r.vertex_data)
    assign = np.zeros(graph.n_vertices, np.int64) if n_shards == 1 else None
    snap = snapshot_as_program(moved, scheduler="chromatic",
                               n_shards=n_shards, partition=assign)
    assert set(snap) == {"rank"}
    assert np.array_equal(np.asarray(snap["rank"]),
                          np.asarray(moved.vertex_data["rank"]))


# ----------------------------------------------------------------------
# FaultPlan / supervisor units
# ----------------------------------------------------------------------

def test_fault_plan_seeded_is_deterministic():
    a = FaultPlan.seeded(7, n_shards=8, max_superstep=20, n_events=3,
                         kinds=("kill", "transient"))
    b = FaultPlan.seeded(7, n_shards=8, max_superstep=20, n_events=3,
                         kinds=("kill", "transient"))
    assert [(e.kind, e.superstep, e.shard) for e in a.events] \
        == [(e.kind, e.superstep, e.shard) for e in b.events]
    assert a.next_trigger(0) == min(e.superstep for e in a.events)
    for e in a.events:
        e.fired = True
    assert a.next_trigger(0) is None and a.all_fired


def test_supervisor_backoff_and_log():
    sleeps = []
    calls = []

    def attempt(n, restarts):
        calls.append(n)
        if n < 2:
            raise CheckpointWriteFault(f"boom {n}")
        return "done"

    out, restarts = supervised(attempt, max_restarts=3,
                               backoff_base_s=0.5, backoff_factor=2.0,
                               backoff_max_s=10.0, sleep=sleeps.append)
    assert out == "done" and calls == [0, 1, 2]
    assert sleeps == [0.5, 1.0]
    assert [r.error_type for r in restarts] \
        == ["CheckpointWriteFault", "CheckpointWriteFault"]
    # non-restartable errors pass straight through
    def bad(n, restarts):
        raise RuntimeError("not injected")
    with pytest.raises(RuntimeError):
        supervised(bad, sleep=sleeps.append)


def test_api_ft_kwarg_validation():
    graph, update, syncs = _problem(nv=20, ne=40)
    with pytest.raises(ValueError, match="go together"):
        api.run(graph, update, syncs=syncs, checkpoint_every=2)
    with pytest.raises(ValueError, match="positive int"):
        api.run(graph, update, syncs=syncs, checkpoint_every=0,
                checkpoint_dir="/tmp/x")
    with pytest.raises(ValueError, match="trace=/profile="):
        api.run(graph, update, syncs=syncs, trace=True,
                faults=FaultPlan([]))
    with pytest.raises(ValueError, match="sequential oracle"):
        api.run(graph, update, syncs=syncs, scheduler="sequential",
                faults=FaultPlan([]))


# ----------------------------------------------------------------------
# 8-virtual-device kill-recovery matrix (the acceptance criterion)
# ----------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, tempfile
    import numpy as np
    from repro import api
    from repro.apps import pagerank
    from repro.core import two_phase_partition
    from repro.ft import FaultEvent, FaultPlan

    rng = np.random.default_rng(1)
    nv = 80
    edges = set()
    while len(edges) < 200:
        u, v = rng.integers(0, nv, 2)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    edges = np.array(sorted(edges))
    graph, update, syncs = pagerank.build(edges, nv)
    assign = two_phase_partition(nv, graph.edges_np, 8, seed=0)

    out = {}
    for scheduler in ("chromatic", "locking"):
        kw = dict(syncs=syncs, scheduler=scheduler, n_shards=8,
                  partition=assign, max_supersteps=12)
        # the no-fault, no-checkpoint reference
        base = api.run(graph, update, **kw)
        with tempfile.TemporaryDirectory() as d:
            faults = FaultPlan([
                FaultEvent("checkpoint_fail", superstep=4),
                FaultEvent("kill", superstep=6, shard=3),
                FaultEvent("transient", superstep=9)])
            r = api.run(graph, update, **kw, checkpoint_every=2,
                        checkpoint_dir=d, faults=faults)
        key = scheduler
        out[key + "_equal"] = bool(np.array_equal(
            np.asarray(base.vertex_data["rank"]),
            np.asarray(r.vertex_data["rank"])))
        out[key + "_supersteps"] = [base.superstep, r.superstep]
        out[key + "_n_updates"] = [base.n_updates, r.n_updates]
        out[key + "_restarts"] = [
            [x.error_type, x.restored_superstep] for x in r.restarts]
        if scheduler == "locking":
            out["ghost_stats"] = [
                [base.stats["ghost_rows_sent"], base.stats["ghost_rows_full"]],
                [r.stats["ghost_rows_sent"], r.stats["ghost_rows_full"]]]

    # resume_from across processes-worth of state: snapshot at 6 of a
    # 12-step run, resume in a fresh engine, compare
    with tempfile.TemporaryDirectory() as d:
        api.run(graph, update, syncs=syncs, scheduler="chromatic",
                n_shards=8, partition=assign, num_supersteps=6,
                checkpoint_every=6, checkpoint_dir=d)
        from repro.ft import latest_valid_snapshot
        snap = latest_valid_snapshot(d)
        resumed = api.run(graph, update, syncs=syncs,
                          scheduler="chromatic", n_shards=8,
                          num_supersteps=12, resume_from=snap)
        full = api.run(graph, update, syncs=syncs, scheduler="chromatic",
                       n_shards=8, partition=assign, num_supersteps=12)
        out["resume_equal"] = bool(np.array_equal(
            np.asarray(full.vertex_data["rank"]),
            np.asarray(resumed.vertex_data["rank"])))

    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def ft_dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.faults
@pytest.mark.parametrize("scheduler", ["chromatic", "locking"])
def test_8dev_kill_recovery_bitwise(ft_dist_results, scheduler):
    """The acceptance criterion: an 8-shard run with an injected
    checkpoint-write failure, a shard kill, and a transient host error
    auto-recovers and matches the unfaulted, uncheckpointed run
    bitwise — for both distributed engines."""
    r = ft_dist_results
    assert r[scheduler + "_equal"]
    assert r[scheduler + "_supersteps"][0] == r[scheduler + "_supersteps"][1]
    assert r[scheduler + "_n_updates"][0] == r[scheduler + "_n_updates"][1]
    errs = [e for e, _ in r[scheduler + "_restarts"]]
    assert errs == ["CheckpointWriteFault", "InjectedKill",
                    "TransientFault"]


@pytest.mark.faults
def test_8dev_ghost_version_counters_survive_restore(ft_dist_results):
    """Bitwise-equal ghost traffic stats prove the versioned-sync
    counters (version/eversion/sent_ver/esent_ver) really round-trip
    through the snapshot — without them the filter would re-ship or
    skip rows after restore."""
    base, rec = ft_dist_results["ghost_stats"]
    assert base == rec
    assert 0 < rec[0] < rec[1]


@pytest.mark.faults
def test_8dev_resume_from(ft_dist_results):
    assert ft_dist_results["resume_equal"]
