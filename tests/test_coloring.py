"""Property tests: colorings satisfy the consistency-model contracts."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core.coloring import (bipartite_coloring, distance2_coloring,
                                 greedy_coloring, single_color,
                                 verify_coloring)
from conftest import random_graph


@st.composite
def graphs(draw):
    nv = draw(st.integers(2, 40))
    ne = draw(st.integers(0, min(nv * (nv - 1) // 2, 80)))
    seed = draw(st.integers(0, 2**16))
    return nv, random_graph(nv, ne, seed)


@given(graphs())
@settings(max_examples=60, deadline=None)
def test_greedy_coloring_is_proper(g):
    nv, edges = g
    colors = greedy_coloring(nv, edges)
    assert verify_coloring(nv, edges, colors, distance=1)
    assert colors.min() >= 0


@given(graphs())
@settings(max_examples=30, deadline=None)
def test_distance2_coloring_is_proper(g):
    nv, edges = g
    colors = distance2_coloring(nv, edges)
    assert verify_coloring(nv, edges, colors, distance=2)


@given(graphs())
@settings(max_examples=30, deadline=None)
def test_greedy_color_count_bounded_by_max_degree(g):
    """Greedy uses at most max_degree + 1 colors (classic bound)."""
    nv, edges = g
    colors = greedy_coloring(nv, edges)
    deg = np.zeros(nv)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    assert colors.max() <= (deg.max() if len(edges) else 0) + 1


def test_bipartite_two_coloring():
    colors = bipartite_coloring(3, 8)
    assert list(colors) == [0, 0, 0, 1, 1, 1, 1, 1]


def test_single_color_vertex_consistency():
    assert single_color(5).max() == 0
