import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_graph(n_vertices: int, n_edges: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    edges = set()
    attempts = 0
    while len(edges) < n_edges and attempts < n_edges * 20:
        u, v = rng.integers(0, n_vertices, 2)
        attempts += 1
        if u != v:
            edges.add((min(int(u), int(v)), max(int(u), int(v))))
    return np.asarray(sorted(edges), dtype=np.int64)
