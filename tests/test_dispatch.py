"""Window-shaped adaptive kernel dispatch (DESIGN.md §8).

Three invariants of the batch-shaped path:

* **Path parity** — for every engine, {batch, bucket} x {kernel, dense}
  produce bit-identical runs.  Trailing zero-weight slots are exact
  no-ops in the FMA-guarded interpret-mode accumulation, so the
  window-shaped ``[B, W]`` launch agrees bitwise with the per-bucket
  ``[Nv_b, W_b]`` launches *and* with both dense fallbacks.
* **The dispatcher is invisible** — a hypothesis property that flipping
  the dispatch mode never changes results.
* **The cost model picks the right shape** — tiny windows route through
  ``ell_spmv_batched``, graph-sized batches through the bucket layout.

Plus the edge-data locality satellite: the bucket-major edge
renumbering is bitwise inert for every engine.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.apps import pagerank
from repro.core import (ChromaticEngine, LockingEngine, PriorityEngine,
                        bsp_engine, choose_dispatch)
from repro.core import exec as exec_mod
from repro.core.coloring import greedy_coloring
from repro.core.graph import DataGraph, zipf_edges


def _zipf_setup(nv=150, max_deg=48, seed=9, w_cap=None):
    edges = zipf_edges(nv, alpha=2.0, max_deg=max_deg, seed=seed)
    g = pagerank.make_graph(edges, nv, w_cap=w_cap)
    assert g.ell.n_buckets >= 3          # several width branches in play
    return g, pagerank.make_update(1e-6)


def _run(mode, g, upd, dispatch, use_kernel=True):
    if mode == "chromatic":
        return ChromaticEngine(g, upd, use_kernel=use_kernel,
                               dispatch=dispatch, max_supersteps=200).run()
    if mode == "priority":
        return PriorityEngine(g, upd, use_kernel=use_kernel,
                              dispatch=dispatch, k_select=16,
                              max_supersteps=8000).run()
    if mode == "locking":
        return LockingEngine(g, upd, use_kernel=use_kernel,
                             dispatch=dispatch, max_pending=16,
                             max_supersteps=8000).run()
    return bsp_engine(g, upd, use_kernel=use_kernel,
                      dispatch=dispatch).run(num_supersteps=8)


@pytest.mark.parametrize("mode", ["chromatic", "priority", "bsp", "locking"])
def test_dispatch_paths_bitwise_identical(mode):
    """batch/bucket x kernel/dense: four bit-identical runs per engine
    on a Zipf graph — the acceptance invariant of the adaptive
    dispatcher (DESIGN.md §8)."""
    g, upd = _zipf_setup()
    ref = _run(mode, g, upd, "bucket", use_kernel=True)
    for dispatch in ("batch", "bucket"):
        for use_kernel in (True, False):
            st = _run(mode, g, upd, dispatch, use_kernel)
            assert np.array_equal(np.asarray(st.vertex_data["rank"]),
                                  np.asarray(ref.vertex_data["rank"])), \
                (dispatch, use_kernel)
            assert np.array_equal(np.asarray(st.active),
                                  np.asarray(ref.active))
            assert int(st.n_updates) == int(ref.n_updates)
            assert int(st.superstep) == int(ref.superstep)


def test_auto_threshold_selects_by_window_size(monkeypatch):
    """The cost model: B * max_deg vs the sliced slot count.  A k=8
    window must launch window-shaped; a k=Nv window must fall back to
    the per-bucket row launches."""
    g, upd = _zipf_setup()
    ell = g.ell
    assert choose_dispatch("auto", 8, ell.max_deg,
                           ell.padded_slots) == "batch"
    assert choose_dispatch("auto", g.n_vertices, ell.max_deg,
                           ell.padded_slots) == "bucket"
    with pytest.raises(ValueError):
        choose_dispatch("bogus", 8, ell.max_deg, ell.padded_slots)

    calls = {"batched": 0, "bucketed": 0}
    real_b, real_r = exec_mod.ell_spmv_batched, exec_mod.ell_spmv_bucketed
    monkeypatch.setattr(exec_mod, "ell_spmv_batched",
                        lambda *a, **k: (calls.__setitem__(
                            "batched", calls["batched"] + 1),
                            real_b(*a, **k))[1])
    monkeypatch.setattr(exec_mod, "ell_spmv_bucketed",
                        lambda *a, **k: (calls.__setitem__(
                            "bucketed", calls["bucketed"] + 1),
                            real_r(*a, **k))[1])
    PriorityEngine(g, upd, k_select=8, dispatch="auto",
                   max_supersteps=10).run(num_supersteps=1)
    assert calls["batched"] and not calls["bucketed"]
    calls.update(batched=0, bucketed=0)
    PriorityEngine(g, upd, k_select=g.n_vertices, dispatch="auto",
                   max_supersteps=10).run(num_supersteps=1)
    assert calls["bucketed"] and not calls["batched"]


@pytest.mark.split
def test_auto_threshold_on_split_graph(monkeypatch):
    """Post-split cost model: the batch path's worst case is
    ``B * W_cap``, not ``B * max_deg``.  On a split graph the engines
    feed ``ell.widths[-1]`` (== W_cap) to ``choose_dispatch``, so the
    same k=8 / k=Nv pinning holds even though ``max_deg`` would have
    flipped the k=8 window to bucket under the old model."""
    g, upd = _zipf_setup(w_cap=8)
    ell = g.ell
    assert ell.is_split and ell.widths[-1] == 8 < ell.max_deg
    # the width the engines actually pass post-split
    assert choose_dispatch("auto", 8, ell.widths[-1],
                           ell.padded_slots) == "batch"
    assert choose_dispatch("auto", g.n_vertices, ell.widths[-1],
                           ell.padded_slots) == "bucket"
    # the old max_deg-based estimate misprices a mid-size window: at
    # B=32 the true batch cost (B * W_cap) undercuts the slot count but
    # B * max_deg would have flipped it to bucket
    assert choose_dispatch("auto", 32, ell.widths[-1],
                           ell.padded_slots) == "batch"
    assert choose_dispatch("auto", 32, ell.max_deg,
                           ell.padded_slots) == "bucket"

    calls = {"batched": 0, "bucketed": 0}
    real_b, real_r = exec_mod.ell_spmv_batched, exec_mod.ell_spmv_bucketed
    monkeypatch.setattr(exec_mod, "ell_spmv_batched",
                        lambda *a, **k: (calls.__setitem__(
                            "batched", calls["batched"] + 1),
                            real_b(*a, **k))[1])
    monkeypatch.setattr(exec_mod, "ell_spmv_bucketed",
                        lambda *a, **k: (calls.__setitem__(
                            "bucketed", calls["bucketed"] + 1),
                            real_r(*a, **k))[1])
    PriorityEngine(g, upd, k_select=8, dispatch="auto",
                   max_supersteps=10).run(num_supersteps=1)
    assert calls["batched"] and not calls["bucketed"]
    calls.update(batched=0, bucketed=0)
    PriorityEngine(g, upd, k_select=g.n_vertices, dispatch="auto",
                   max_supersteps=10).run(num_supersteps=1)
    assert calls["bucketed"] and not calls["batched"]


@pytest.mark.split
@pytest.mark.parametrize("mode", ["chromatic", "priority", "bsp", "locking"])
def test_split_dispatch_paths_bitwise_identical(mode):
    """The PR-4 acceptance invariant survives hub splitting: with rows
    chunked at W_cap=8, {batch, bucket} x {kernel, dense} still produce
    four bit-identical runs per engine (stage-1 partials are combined
    by the same ``segment_combine`` op on every path)."""
    g, upd = _zipf_setup(w_cap=8)
    assert g.ell.is_split
    ref = _run(mode, g, upd, "bucket", use_kernel=True)
    for dispatch in ("batch", "bucket"):
        for use_kernel in (True, False):
            st = _run(mode, g, upd, dispatch, use_kernel)
            assert np.array_equal(np.asarray(st.vertex_data["rank"]),
                                  np.asarray(ref.vertex_data["rank"])), \
                (dispatch, use_kernel)
            assert np.array_equal(np.asarray(st.active),
                                  np.asarray(ref.active))
            assert int(st.n_updates) == int(ref.n_updates)
            assert int(st.superstep) == int(ref.superstep)


def test_locking_windowed_claim_pass_matches_full_width():
    """The batch-shaped claim pass (snapped-width candidate gathers)
    grants exactly the same winner batches as the full-width pass —
    the whole run is bit-identical, updates included."""
    g, upd = _zipf_setup(nv=120, max_deg=32, seed=4)
    a = LockingEngine(g, upd, max_pending=8, dispatch="batch",
                      max_supersteps=8000).run()
    b = LockingEngine(g, upd, max_pending=8, dispatch="bucket",
                      max_supersteps=8000).run()
    assert np.array_equal(np.asarray(a.vertex_data["rank"]),
                          np.asarray(b.vertex_data["rank"]))
    assert int(a.n_updates) == int(b.n_updates)
    assert int(a.superstep) == int(b.superstep)


def _normalized_weights(nv, edges):
    deg = np.zeros(nv)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    deg = np.maximum(deg, 1)
    return np.asarray([1.0 / np.sqrt(deg[u] * deg[v]) for u, v in edges],
                      dtype=np.float32)


@pytest.mark.parametrize("mode", ["chromatic", "priority", "bsp", "locking"])
def test_edge_locality_reorder_is_bitwise_inert(mode):
    """Bucket-major edge renumbering changes where edge rows live, not
    what any engine computes: ordered vs input-ordered layouts are
    bit-identical (slot order within adjacency rows is untouched)."""
    nv = 100
    edges = zipf_edges(nv, alpha=2.0, max_deg=32, seed=5)
    w = _normalized_weights(nv, edges)
    colors = greedy_coloring(nv, edges)   # shared: coloring sees one order
    upd = pagerank.make_update(1e-6)

    def build(locality):
        g = DataGraph.from_edges(
            nv, edges, {"rank": np.ones(nv, np.float32)}, {"w": w},
            edge_locality=locality)
        return g.with_colors(colors)

    g_on, g_off = build(True), build(False)
    assert not np.array_equal(g_on.edge_perm, g_off.edge_perm)
    st_on = _run(mode, g_on, upd, "batch")
    st_off = _run(mode, g_off, upd, "batch")
    assert np.array_equal(np.asarray(st_on.vertex_data["rank"]),
                          np.asarray(st_off.vertex_data["rank"]))
    assert int(st_on.n_updates) == int(st_off.n_updates)
    # edge rows correspond through the stored permutation
    np.testing.assert_array_equal(
        np.asarray(st_on.edge_data["w"])[:-1][g_on.edge_inv_perm],
        np.asarray(st_off.edge_data["w"])[:-1])


@pytest.mark.split
@pytest.mark.parametrize("mode", ["chromatic", "priority", "bsp", "locking"])
def test_edge_locality_composes_with_split(mode):
    """Bucket-major edge renumbering walks the *virtual-row* blocks on
    a split graph — a hub's chunks get contiguous edge slots — and is
    still bitwise inert for every engine."""
    nv = 100
    edges = zipf_edges(nv, alpha=2.0, max_deg=32, seed=5)
    w = _normalized_weights(nv, edges)
    colors = greedy_coloring(nv, edges)   # shared: coloring sees one order
    upd = pagerank.make_update(1e-6)

    def build(locality):
        g = DataGraph.from_edges(
            nv, edges, {"rank": np.ones(nv, np.float32)}, {"w": w},
            w_cap=8, edge_locality=locality)
        assert g.ell.is_split
        return g.with_colors(colors)

    g_on, g_off = build(True), build(False)
    assert not np.array_equal(g_on.edge_perm, g_off.edge_perm)
    for dispatch in ("batch", "bucket"):
        st_on = _run(mode, g_on, upd, dispatch)
        st_off = _run(mode, g_off, upd, dispatch)
        assert np.array_equal(np.asarray(st_on.vertex_data["rank"]),
                              np.asarray(st_off.vertex_data["rank"])), dispatch
        assert int(st_on.n_updates) == int(st_off.n_updates)
        # edge rows correspond through the stored permutation
        np.testing.assert_array_equal(
            np.asarray(st_on.edge_data["w"])[:-1][g_on.edge_inv_perm],
            np.asarray(st_off.edge_data["w"])[:-1])


# The hypothesis property ("the dispatcher's choice never changes
# results") lives in tests/test_graph_properties.py with the other
# optional-dep property sweeps, so this module never skips wholesale.
