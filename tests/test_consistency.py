"""Sequential consistency (paper Def. 3.1): the parallel engines equal a
sequential execution of the same update tasks.

All engines are thin scheduling strategies over the shared executor core
(``repro.core.exec``), reached here exclusively through the ``repro.api``
facade — engine choice and its ground-truth replay are both one
``scheduler=`` string (DESIGN.md §9).  The ``"sequential"`` scheduler is
the oracle, replaying each strategy's RemoveNext — (superstep, color,
vertex id) for chromatic, top-k priority order (``k_select``) for the
priority engine, the min-id claim pass (``max_pending``) for locking,
phase-snapshot Jacobi semantics (``snapshot_phases``) for BSP.  Results
must agree up to float associativity of batched vs single-row arithmetic
(asserted at 1e-5 rtol; update counts match exactly where the schedule
is deterministic)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import api
from repro.apps import coem, pagerank
from repro.core import Consistency, UpdateFn, UpdateResult
from repro.core.coloring import distance2_coloring, greedy_coloring
from repro.core.graph import DataGraph
from conftest import random_graph


@pytest.mark.parametrize("mode", ["chromatic", "priority", "bsp", "locking"])
def test_engines_match_sequential_oracle(mode):
    """One oracle, four strategies — all five through the one facade."""
    edges = random_graph(50, 120, seed=3)
    g = pagerank.make_graph(edges, 50)
    syncs = [pagerank.total_rank_sync()]
    if mode == "locking":
        # eps=1e-6: legal locking schedules may diverge near priority
        # ties, so the fixed points must be pinned tighter than the
        # shared 1e-5 value assertion below
        upd = pagerank.make_update(1e-6)
        st = api.run(g, upd, syncs=syncs, scheduler="locking",
                     max_pending=8, max_supersteps=5000)
        assert not st.active_any, "engine must drain tasks"
        ref = api.run(g, upd, syncs=syncs, scheduler="sequential",
                      max_pending=8, max_supersteps=5000)
        assert ref.n_updates > 0
        # like the priority engine, the adaptive window is order-
        # sensitive to batched-vs-single-row float noise near priority
        # ties; the trajectory still converges identically.
        assert abs(st.n_updates - ref.n_updates) \
            <= max(8, ref.n_updates // 50)
    elif mode == "chromatic":
        upd = pagerank.make_update(1e-5)
        st = api.run(g, upd, syncs=syncs, scheduler="chromatic",
                     max_supersteps=60)
        assert not st.active_any, "engine must drain tasks"
        ref = api.run(g, upd, syncs=syncs, scheduler="sequential",
                      max_supersteps=60)
        assert st.n_updates == ref.n_updates
    elif mode == "priority":
        # eps=1e-6 like the locking mode: legal priority schedules may
        # diverge near ties, so the fixed points must be pinned tighter
        # than the shared 1e-5 value assertion below
        upd = pagerank.make_update(1e-6)
        st = api.run(g, upd, syncs=syncs, scheduler="priority",
                     k_select=8, max_supersteps=5000)
        assert not st.active_any, "engine must drain tasks"
        ref = api.run(g, upd, syncs=syncs, scheduler="sequential",
                      k_select=8, max_supersteps=5000)
        # the adaptive priority schedule is order-sensitive to batched-vs-
        # single-row float noise in the residuals (the engine reduces at
        # bucket widths, the oracle row by row), so the replayed schedule
        # may diverge by a couple percent of tasks near ties; the data
        # graph still converges to the same trajectory.
        assert abs(st.n_updates - ref.n_updates) \
            <= max(8, ref.n_updates // 50)
    else:
        # BSP is *not* sequentially consistent: its ground truth is the
        # phase-snapshot (Jacobi) oracle.  A negative threshold (always
        # reschedule) + fixed sweeps keeps the schedule deterministic
        # (every vertex, every superstep).  The oracle replays on the
        # engine's own (single-colored) graph.
        upd = pagerank.make_update(-1.0)
        st = api.run(g, upd, syncs=syncs, scheduler="bsp",
                     num_supersteps=30)
        ref = api.run(st.engine.graph, upd, syncs=syncs,
                      scheduler="sequential", snapshot_phases=True,
                      max_supersteps=30)
        # exact count parity (isolated vertices execute once and are
        # never rescheduled, so this is < 50 * 30)
        assert st.n_updates == ref.n_updates
    np.testing.assert_allclose(np.asarray(st.vertex_data["rank"]),
                               np.asarray(ref.vertex_data["rank"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(st.globals["total_rank"]),
                               float(ref.globals["total_rank"]), rtol=1e-5)


def test_zipf_graph_matches_sequential_oracle():
    """Sequential consistency survives the sliced-ELL layout on the
    power-law graphs it targets: the chromatic engine on a Zipf(~2)
    degree graph equals the sequential oracle, which reads the
    adjacency through the ``to_padded()`` escape hatch.  A negative
    threshold (always reschedule) + fixed sweeps keeps the schedule
    deterministic, so the update counts must match exactly even though
    engine and oracle reduce at different batch shapes."""
    from repro.core.graph import zipf_edges
    edges = zipf_edges(120, alpha=2.0, max_deg=40, seed=11)
    g = pagerank.make_graph(edges, 120)
    assert g.ell.n_buckets >= 3
    upd = pagerank.make_update(-1.0)
    st = api.run(g, upd, scheduler="chromatic", num_supersteps=12)
    ref = api.run(g, upd, scheduler="sequential", max_supersteps=12)
    np.testing.assert_allclose(np.asarray(st.vertex_data["rank"]),
                               np.asarray(ref.vertex_data["rank"]),
                               rtol=1e-5)
    assert st.n_updates == ref.n_updates


def test_coem_engine_matches_sequential():
    prob = coem.synthetic_ner(30, 20, 3, seed=2)
    upd = coem.make_update(1e-4)
    st = api.run(prob.graph, upd, scheduler="chromatic", max_supersteps=30)
    ref = api.run(prob.graph, upd, scheduler="sequential",
                  max_supersteps=30)
    np.testing.assert_allclose(np.asarray(st.vertex_data["p"]),
                               np.asarray(ref.vertex_data["p"]),
                               rtol=1e-4, atol=1e-6)
    assert st.n_updates == ref.n_updates


def _neighbor_writer():
    """An update fn requiring FULL consistency: writes neighbor data."""
    def update(scope):
        new_self = scope.v_data["x"] + 1.0
        # push half of my value onto my neighbors
        push = scope.v_data["x"][:, None] * 0.5
        new_nbr = jnp.where(scope.nbr_mask, scope.nbr_data["x"] + push,
                            scope.nbr_data["x"])
        return UpdateResult(v_data={"x": new_self},
                            nbr_data={"x": new_nbr})
    return UpdateFn(update, Consistency.FULL, name="pusher")


def test_full_consistency_needs_distance2_coloring():
    edges = random_graph(20, 40, seed=1)
    x0 = np.arange(20, dtype=np.float32)
    upd = _neighbor_writer()

    def run_with(colors):
        g = DataGraph.from_edges(20, edges, {"x": x0}).with_colors(colors)
        st = api.run(g, upd, scheduler="chromatic", num_supersteps=1)
        ref = api.run(g, upd, scheduler="sequential", max_supersteps=1)
        return (np.asarray(st.vertex_data["x"]),
                np.asarray(ref.vertex_data["x"]))

    # distance-2 coloring: parallel == sequential (full consistency holds)
    got2, want2 = run_with(distance2_coloring(20, edges))
    np.testing.assert_allclose(got2, want2, rtol=1e-6)

    # distance-1 coloring is NOT sufficient for neighbor-writing updates:
    # adjacent scopes overlap on the written vertex -> results diverge.
    got1, want1 = run_with(greedy_coloring(20, edges))
    assert not np.allclose(got1, want1)


def test_bsp_engine_is_jacobi():
    """Single-color (unsafe/BSP) execution reads pre-step values — the
    inconsistent mode of Fig. 1."""
    edges = np.asarray([[0, 1], [1, 2]])
    g = pagerank.make_graph(edges, 3)
    upd = pagerank.make_update(0.0)
    st = api.run(g, upd, scheduler="bsp", num_supersteps=1)
    # Jacobi: every vertex computed from ALL-ones neighbor ranks
    w = np.asarray(g.edge_data["w"])[:-1]
    deg_w = {0: w[0], 1: w[0] + w[1], 2: w[1]}
    expect = np.asarray([0.15 + 0.85 * deg_w[v] for v in range(3)])
    np.testing.assert_allclose(np.asarray(st.vertex_data["rank"]), expect,
                               rtol=1e-5)


def test_priority_engine_converges_to_same_fixed_point():
    edges = random_graph(40, 90, seed=5)
    g = pagerank.make_graph(edges, 40)
    upd = pagerank.make_update(1e-6)
    chrom = api.run(g, upd, scheduler="chromatic", max_supersteps=200)
    prio = api.run(g, upd, scheduler="priority", k_select=8,
                   max_supersteps=5000)
    assert not prio.active_any, "priority engine must drain tasks"
    np.testing.assert_allclose(np.asarray(prio.vertex_data["rank"]),
                               np.asarray(chrom.vertex_data["rank"]),
                               atol=2e-5)
