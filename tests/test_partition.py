"""Two-phase partitioning (paper §4.1) properties."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import (build_meta_graph, balance_meta_graph, cut_edges,
                        over_partition, two_phase_partition)
from conftest import random_graph


@st.composite
def part_cases(draw):
    nv = draw(st.integers(8, 60))
    ne = draw(st.integers(nv // 2, min(nv * 3, 120)))
    m = draw(st.sampled_from([2, 3, 4, 8]))
    seed = draw(st.integers(0, 2**16))
    return nv, random_graph(nv, ne, seed), m


@given(part_cases())
@settings(max_examples=40, deadline=None)
def test_two_phase_assigns_every_vertex(case):
    nv, edges, m = case
    asg = two_phase_partition(nv, edges, m)
    assert asg.shape == (nv,)
    assert asg.min() >= 0 and asg.max() < m


@given(part_cases())
@settings(max_examples=40, deadline=None)
def test_two_phase_balance(case):
    """LPT on the meta-graph: no machine holds more than ~2x fair share
    (holds because atoms are ~Nv/k sized with k >= 4m)."""
    nv, edges, m = case
    asg = two_phase_partition(nv, edges, m)
    counts = np.bincount(asg, minlength=m)
    fair = nv / m
    assert counts.max() <= max(2.5 * fair, fair + nv / 4 + 2)


@given(part_cases())
@settings(max_examples=20, deadline=None)
def test_over_partition_covers(case):
    nv, edges, m = case
    k = min(4 * m, nv)
    atom_of = over_partition(nv, edges, k)
    assert (atom_of >= 0).all() and atom_of.max() < k


def test_meta_graph_weights_count_cut_edges():
    edges = np.asarray([[0, 1], [1, 2], [2, 3], [3, 0]])
    atom_of = np.asarray([0, 0, 1, 1])
    meta = build_meta_graph(atom_of, edges, 2)
    assert meta.vertex_weight.tolist() == [2.0, 2.0]
    assert meta.edge_weight == {(0, 1): 2}   # edges 1-2 and 3-0 cross


def test_partition_reuse_across_cluster_sizes():
    """The paper's motivating property: one over-partitioning serves
    multiple machine counts."""
    edges = random_graph(60, 150, seed=7)
    k = 16
    atom_of = over_partition(60, edges, k)
    for m in (2, 4, 8):
        meta = build_meta_graph(atom_of, edges, k)
        machine_of = balance_meta_graph(meta, m)
        asg = machine_of[atom_of]
        counts = np.bincount(asg, minlength=m)
        assert counts.max() > 0
        assert asg.max() < m


def test_locality_partition_beats_random_on_grid():
    """BFS atoms respect locality: fewer cut edges than random cut."""
    from repro.core.graph import grid_edges_3d
    from repro.core import random_partition
    nv, edges = grid_edges_3d(4, 6, 6)
    two = cut_edges(two_phase_partition(nv, edges, 4), edges)
    rnd = cut_edges(random_partition(nv, 4), edges)
    assert two < rnd
