"""Executor core: the Pallas aggregator fast path is bit-identical to
the dense-scope path, and is actually exercised.

Both paths reduce neighborhoods through the same ``ell_spmv`` kernel
arithmetic (dense scopes via ``ell_fold`` over the materialized values),
so whole engine runs must agree bit-for-bit — asserted with
``np.array_equal``, not allclose (interpret mode on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.apps import coem, pagerank
from repro.core import ChromaticEngine, PriorityEngine, bsp_engine
from repro.core import exec as exec_mod
from repro.kernels import ref
from repro.kernels.ell_spmv import ell_fold, ell_spmv
from conftest import random_graph


def _pagerank_setup():
    edges = random_graph(60, 150, seed=0)
    g = pagerank.make_graph(edges, 60)
    return g, pagerank.make_update(1e-6)


def test_apps_declare_aggregators():
    assert pagerank.make_update().aggregator is not None
    assert coem.make_update().aggregator is not None


@pytest.mark.parametrize("engine_cls", [ChromaticEngine, PriorityEngine])
def test_pagerank_kernel_path_bit_identical(engine_cls):
    g, upd = _pagerank_setup()
    kwargs = dict(max_supersteps=5000) if engine_cls is PriorityEngine \
        else dict(max_supersteps=100)
    st_k = engine_cls(g, upd, use_kernel=True, **kwargs).run()
    st_d = engine_cls(g, upd, use_kernel=False, **kwargs).run()
    assert np.array_equal(np.asarray(st_k.vertex_data["rank"]),
                          np.asarray(st_d.vertex_data["rank"]))
    assert int(st_k.n_updates) == int(st_d.n_updates)
    assert int(st_k.superstep) == int(st_d.superstep)


def test_coem_kernel_path_bit_identical():
    prob = coem.synthetic_ner(60, 40, 3, seed=2)
    upd = coem.make_update(1e-4)
    st_k = ChromaticEngine(prob.graph, upd, max_supersteps=40,
                           use_kernel=True).run()
    st_d = ChromaticEngine(prob.graph, upd, max_supersteps=40,
                           use_kernel=False).run()
    assert np.array_equal(np.asarray(st_k.vertex_data["p"]),
                          np.asarray(st_d.vertex_data["p"]))
    assert int(st_k.n_updates) == int(st_d.n_updates)


def test_bsp_kernel_path_bit_identical():
    g, upd = _pagerank_setup()
    st_k = bsp_engine(g, upd, use_kernel=True).run(num_supersteps=5)
    st_d = bsp_engine(g, upd, use_kernel=False).run(num_supersteps=5)
    assert np.array_equal(np.asarray(st_k.vertex_data["rank"]),
                          np.asarray(st_d.vertex_data["rank"]))


def test_kernel_path_is_actually_dispatched(monkeypatch):
    """use_kernel=True must route through the bucketed kernel entry
    (no silent fallback to the dense scope path)."""
    calls = []
    real = exec_mod.ell_spmv_bucketed

    def counting(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    monkeypatch.setattr(exec_mod, "ell_spmv_bucketed", counting)
    g, upd = _pagerank_setup()
    ChromaticEngine(g, upd, use_kernel=True).run(num_supersteps=1)
    assert calls, "aggregator fast path was not dispatched"
    n_kernel_calls = len(calls)
    calls.clear()
    ChromaticEngine(g, upd, use_kernel=False).run(num_supersteps=1)
    assert not calls, "use_kernel=False must not call the fast path"
    assert n_kernel_calls >= 1


def _zipf_pagerank_setup():
    """Power-law degree graph: the skew regime the sliced-ELL layout
    targets (hub vertex >> mean degree -> several active buckets)."""
    from repro.core.graph import zipf_edges
    edges = zipf_edges(150, alpha=2.0, max_deg=48, seed=9)
    g = pagerank.make_graph(edges, 150)
    assert g.ell.n_buckets >= 3          # the test must exercise buckets
    return g, pagerank.make_update(1e-6)


@pytest.mark.parametrize("mode", ["chromatic", "priority", "bsp", "locking"])
def test_zipf_kernel_path_bit_identical(mode):
    """Dense-vs-kernel bitwise parity on a Zipf(alpha~2) degree graph —
    the acceptance invariant of the sliced-ELL refactor (DESIGN.md §7):
    one compiled accumulation per bucket keeps every engine's two
    dispatch paths bit-for-bit equal even with heavy degree skew."""
    from repro.core import LockingEngine, bsp_engine
    g, upd = _zipf_pagerank_setup()

    def run(use_kernel):
        if mode == "chromatic":
            return ChromaticEngine(g, upd, use_kernel=use_kernel,
                                   max_supersteps=200).run()
        if mode == "priority":
            return PriorityEngine(g, upd, use_kernel=use_kernel, k_select=16,
                                  max_supersteps=8000).run()
        if mode == "locking":
            return LockingEngine(g, upd, use_kernel=use_kernel,
                                 max_pending=16, max_supersteps=8000).run()
        return bsp_engine(g, upd, use_kernel=use_kernel).run(num_supersteps=8)

    st_k, st_d = run(True), run(False)
    assert np.array_equal(np.asarray(st_k.vertex_data["rank"]),
                          np.asarray(st_d.vertex_data["rank"]))
    assert np.array_equal(np.asarray(st_k.active), np.asarray(st_d.active))
    assert int(st_k.n_updates) == int(st_d.n_updates)


def test_ell_spmv_bucketed_matches_monolithic():
    """The width-specialized per-bucket launches compute the same
    function as one padded-width launch (trailing slots carry weight
    exactly 0).  Equality is to float tolerance, not bitwise: different
    launch *widths* compile with different excess-precision decisions
    on CPU, which is exactly why the engines' two dispatch paths both
    reduce at the per-bucket shapes (DESIGN.md §7) — that
    engine-level parity IS asserted bitwise, above."""
    from repro.core.graph import zipf_edges
    from repro.kernels.ell_spmv import ell_spmv_bucketed
    edges = zipf_edges(200, alpha=2.0, max_deg=32, seed=4)
    g = pagerank.make_graph(edges, 200)
    ell, p = g.ell, g.to_padded()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(200, 5)), jnp.float32)
    w_full = jnp.where(p.nbr_mask, g.edge_data["w"][p.edge_ids],
                       0.0).astype(jnp.float32)
    w_blocks = [jnp.where(m, g.edge_data["w"][e], 0.0).astype(jnp.float32)
                for m, e in zip(ell.nbr_mask, ell.edge_ids)]
    y_mono = np.asarray(ell_spmv(p.nbrs, w_full, x, interpret=True))
    y_b = np.asarray(ell_spmv_bucketed(ell.nbrs, w_blocks, x,
                                       interpret=True))
    np.testing.assert_allclose(y_b[np.asarray(ell.inv_perm)], y_mono,
                               rtol=1e-6, atol=1e-7)


def test_ell_spmv_row_mask_matches_ref():
    rng = np.random.default_rng(3)
    nv, d, rows, f = 90, 7, 120, 5
    nbrs = jnp.asarray(rng.integers(0, rows, (nv, d)), jnp.int32)
    w = jnp.asarray(rng.random((nv, d)) * (rng.random((nv, d)) < 0.7),
                    jnp.float32)
    x = jnp.asarray(rng.normal(size=(rows, f)), jnp.float32)
    mask = jnp.asarray(rng.random(nv) < 0.6)
    got = ell_spmv(nbrs, w, x, row_mask=mask, interpret=True)
    want = ref.ell_spmv_ref(nbrs, w, x, row_mask=mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # masked rows are exactly zero; unmasked rows exactly match the
    # unmasked kernel (the row gate multiplies by exactly 1.0)
    full = np.asarray(ell_spmv(nbrs, w, x, interpret=True))
    m = np.asarray(mask)
    assert np.all(np.asarray(got)[~m] == 0.0)
    assert np.array_equal(np.asarray(got)[m], full[m])


def test_ell_fold_matches_ell_spmv_bitwise():
    """The dense-fallback reduction is the same kernel arithmetic."""
    rng = np.random.default_rng(11)
    for nv, d, f, rows in [(37, 6, 1, 37), (19, 9, 1, 60), (64, 8, 16, 64)]:
        nbrs = jnp.asarray(rng.integers(0, rows, (nv, d)), jnp.int32)
        w = jnp.asarray(rng.random((nv, d)).astype(np.float32))
        x = jnp.asarray(rng.normal(size=(rows, f)).astype(np.float32))
        vals = x[nbrs]                      # the dense-scope gather
        y_kernel = np.asarray(ell_spmv(nbrs, w, x, interpret=True))
        y_fold = np.asarray(ell_fold(w, vals, interpret=True))
        assert np.array_equal(y_kernel, y_fold)


def test_masked_neighbor_sum_matches_ref():
    """The public helper for hand-written updates: both value ranks."""
    rng = np.random.default_rng(5)
    from repro.core import masked_neighbor_sum
    b, d, f = 23, 6, 4
    w = jnp.asarray(rng.random((b, d)).astype(np.float32))
    mask = jnp.asarray(rng.random((b, d)) < 0.7)
    vals3 = jnp.asarray(rng.normal(size=(b, d, f)).astype(np.float32))
    want3 = np.asarray(jnp.where(mask, w, 0.0)[..., None] * vals3).sum(axis=1)
    got3 = np.asarray(masked_neighbor_sum(w, vals3, mask))
    np.testing.assert_allclose(got3, want3, rtol=1e-5, atol=1e-6)
    vals2 = vals3[..., 0]                     # [B, D] -> [B]
    got2 = np.asarray(masked_neighbor_sum(w, vals2, mask))
    assert got2.shape == (b,)
    np.testing.assert_allclose(got2, want3[:, 0], rtol=1e-5, atol=1e-6)


def test_lite_scope_skips_nbr_data():
    """The aggregator path materializes lite scopes (no [B, D, F] gather)."""
    from repro.core.update import gather_scopes
    g, _ = _pagerank_setup()
    ids = jnp.arange(8, dtype=jnp.int32)
    lite = gather_scopes(g, g.vertex_data, g.edge_data, ids, {},
                         with_nbr_data=False)
    assert lite.nbr_data is None
    full = gather_scopes(g, g.vertex_data, g.edge_data, ids, {})
    assert full.nbr_data is not None
