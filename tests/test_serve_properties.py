"""Property tests for the online serving subsystem (DESIGN.md §13).

The central property: for ANY interleaving of mutation batches (edge
inserts + vertex-label injections), incremental dirty-scope recompute
on the live engine reaches the same fixed point as a from-scratch
rebuild of the final graph.  Connected components keeps the check
bitwise: int32 min over a confluent semilattice has one fixed point.

Label injections are drawn strictly decreasing (a global negative
counter): every new injection is smaller than anything already
propagated, so a stale propagation of an overwritten label is always
dominated and last-write state determines the fixed point — without
this, "rebuild from the final vertex data" would not be well-defined.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep; skip, don't error
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.slow      # hypothesis sweeps: own CI job

from conftest import random_graph
from repro import api
from repro.apps import cc
from repro.core.graph import input_order_edges, rebuild_compacted


@st.composite
def mutation_traces(draw):
    nv = draw(st.integers(8, 24))
    ne = draw(st.integers(6, 40))
    seed = draw(st.integers(0, 2**16))
    edges = random_graph(nv, ne, seed)
    if len(edges) == 0:
        edges = np.asarray([[0, 1]], np.int64)
    existing = {tuple(e) for e in edges}
    n_batches = draw(st.integers(1, 3))
    batches = []
    for _ in range(n_batches):
        inserts = []
        for _ in range(draw(st.integers(0, 3))):
            u = draw(st.integers(0, nv - 2))
            v = draw(st.integers(u + 1, nv - 1))
            if (u, v) not in existing:
                existing.add((u, v))
                inserts.append((u, v))
        injects = draw(st.lists(st.integers(0, nv - 1), max_size=2))
        batches.append((np.asarray(inserts, np.int64).reshape(-1, 2),
                        injects))
    return nv, edges, batches


def _run_trace(nv, edges, batches, scheduler):
    graph, update, _ = cc.build(edges, nv, slack=3)
    kw = ({"dispatch": "batch", "max_pending": 16,
           "max_supersteps": 20_000} if scheduler == "locking" else {})
    serving = api.serve(graph, update, scheduler=scheduler, slack=3, **kw)
    serving.recompute()

    counter = [-1]                 # strictly decreasing injections
    injected = np.arange(nv, dtype=np.int32)   # last-write state
    all_edges = edges
    for inserts, injects in batches:
        if len(inserts):
            serving.add_edges(inserts)
            all_edges = np.vstack([all_edges, inserts])
        for v in injects:
            serving.update_vertex_data(
                [v], {"label": np.asarray([counter[0]], np.int32)})
            injected[v] = counter[0]
            counter[0] -= 1
        serving.recompute()

    inc = np.asarray(serving.graph.vertex_data["label"])
    # from-scratch: final structure + last-write injected labels
    g2, u2, _ = cc.build(all_edges, nv, labels=injected)
    res = api.run(g2, u2, scheduler=scheduler, **kw)
    ref = np.asarray(res.vertex_data["label"])
    oracle = cc.reference_components(all_edges, nv, labels=injected)
    assert np.array_equal(ref, oracle)
    assert np.array_equal(inc, ref), (inc, ref)


@given(mutation_traces())
@settings(max_examples=8, deadline=None)
def test_interleaved_mutations_chromatic_bitwise(trace):
    _run_trace(*trace, scheduler="chromatic")


@given(mutation_traces())
@settings(max_examples=8, deadline=None)
def test_interleaved_mutations_locking_bitwise(trace):
    _run_trace(*trace, scheduler="locking")


@given(mutation_traces())
@settings(max_examples=12, deadline=None)
def test_compaction_roundtrip_property(trace):
    """rebuild_compacted == the graph from_edges would have built: the
    input-order edge list (+ extras) survives slack exhaustion."""
    nv, edges, batches = trace
    graph, _, _ = cc.build(edges, nv, slack=2)
    extras = np.vstack([b[0] for b in batches]).reshape(-1, 2) \
        if any(len(b[0]) for b in batches) else np.zeros((0, 2), np.int64)
    g2 = rebuild_compacted(graph, extra_edges=extras if len(extras) else None)
    ein, _ = input_order_edges(g2)
    want = np.vstack([edges, extras]) if len(extras) else edges
    assert np.array_equal(ein, want)
    assert g2.slack == graph.slack
    assert np.array_equal(ein[g2.edge_perm], g2.edges_np)


@given(mutation_traces())
@settings(max_examples=6, deadline=None)
def test_snapshot_isolation_property(trace):
    """A snapshot pinned before any batch never changes, whatever the
    interleaving that follows it."""
    nv, edges, batches = trace
    graph, update, _ = cc.build(edges, nv, slack=3)
    serving = api.serve(graph, update, scheduler="chromatic", slack=3)
    serving.recompute()
    pinned = serving.snapshot()
    before = np.asarray(pinned.read_vertex(np.arange(nv), "label")).copy()
    n_edges_before = pinned.n_edges
    counter = [-1]
    for inserts, injects in batches:
        if len(inserts):
            serving.add_edges(inserts)
        for v in injects:
            serving.update_vertex_data(
                [v], {"label": np.asarray([counter[0]], np.int32)})
            counter[0] -= 1
        serving.recompute()
    assert np.array_equal(
        np.asarray(pinned.read_vertex(np.arange(nv), "label")), before)
    assert pinned.n_edges == n_edges_before
