"""End-to-end behaviour tests for the framework as a system."""
import dataclasses
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import als
from repro.core import (ChromaticEngine, DistributedChromaticEngine,
                        ShardPlan, two_phase_partition)


@pytest.mark.slow
def test_quickstart_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__), "..",
                                      "examples", "quickstart.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "second most popular page" in proc.stdout


def test_e2e_als_pipeline_with_checkpoint(tmp_path):
    """data -> graph -> engine (+sync) -> checkpoint -> restore -> resume."""
    from repro.train import checkpoint as ck
    prob = als.synthetic_netflix(40, 30, d=4, density=0.3, noise=0.05)
    upd = als.make_update(4, lam=0.02)
    eng = ChromaticEngine(prob.graph, upd, syncs=[als.rmse_sync()],
                          max_supersteps=10)
    st = eng.run(num_supersteps=10)
    path = str(tmp_path / "factors.npz")
    ck.snapshot_engine_state(path, st)
    like = {"vertex_data": st.vertex_data, "edge_data": st.edge_data,
            "active": st.active, "priority": st.priority}
    restored, step = ck.restore(path, like)
    assert step == 10
    # resume from the snapshot: rebuild graph with restored data
    g2 = prob.graph.replace_data(vertex_data=restored["vertex_data"],
                                 edge_data=restored["edge_data"])
    eng2 = ChromaticEngine(g2, upd, syncs=[als.rmse_sync()],
                           max_supersteps=10)
    st2 = eng2.run(num_supersteps=5)
    rmse_before = als.dataset_rmse(prob, st.vertex_data)
    rmse_after = als.dataset_rmse(prob, st2.vertex_data)
    assert rmse_after <= rmse_before + 1e-3


def test_engine_termination_on_empty_task_set():
    """Alg. 2: the engine stops when T drains (not at max_supersteps)."""
    from repro.apps import pagerank
    from conftest import random_graph
    edges = random_graph(30, 60, seed=9)
    g = pagerank.make_graph(edges, 30)
    eng = ChromaticEngine(g, pagerank.make_update(eps=1e-3),
                          max_supersteps=1000)
    st = eng.run()
    assert int(st.superstep) < 1000
    assert not bool(st.active.any())


def test_initial_task_subset():
    """Alg. 2 takes an *initial task set*: only scheduled vertices (and
    their transitive reschedules) execute."""
    from repro.apps import pagerank
    # chain component {0,1,2} + pair {3,4}; the pair is an exact fixed
    # point of the update, the chain is not
    edges = np.asarray([[0, 1], [1, 2], [3, 4]])
    g = pagerank.make_graph(edges, 5)
    act = np.zeros(5, bool)
    act[0] = True   # only the chain seeded (via vertex 0)
    eng = ChromaticEngine(g, pagerank.make_update(eps=1e-6),
                          max_supersteps=100)
    st = eng.run(active=jnp.asarray(act))
    ranks = np.asarray(st.vertex_data["rank"])
    assert ranks[3] == 1.0 and ranks[4] == 1.0   # never scheduled
    assert ranks[0] != 1.0 and ranks[1] != 1.0   # chain updated


@pytest.mark.slow
def test_dryrun_entry_on_production_mesh():
    """Integration: one real (arch x shape) lower+compile on the 16x16
    mesh, in a subprocess (needs 512 host devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "stablelm-3b", "--shape", "decode_32k"],
        env=env, capture_output=True, text=True, timeout=1200,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, (proc.stdout + proc.stderr)[-2000:]
    assert "1/1 combinations lowered and compiled" in proc.stdout
