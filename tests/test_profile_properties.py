"""Hypothesis sweeps for the trace cost model (DESIGN.md §11)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep; skip, don't error
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.slow      # hypothesis sweeps: own CI job

from repro.core.exec import choose_dispatch
from repro.profile import CostModel, fit_cost_model


@st.composite
def traces(draw):
    """Arbitrary warm launch traces: a few widths, noisy wall times."""
    widths = draw(st.lists(st.sampled_from([2, 4, 8, 16, 32, 64, 128]),
                           min_size=1, max_size=4, unique=True))
    records = []
    for w in widths:
        n = draw(st.integers(1, 6))
        for _ in range(n):
            records.append({
                "kind": "launch", "mode": "batch", "width": w,
                "rows": draw(st.integers(1, 4096)),
                "wall_us": draw(st.floats(0.0, 1e6, allow_nan=False)),
            })
    return records


@given(traces())
@settings(max_examples=60, deadline=None)
def test_fitted_model_is_monotone_in_slot_count(records):
    """For ANY trace — including pure noise and single-point widths —
    the fitted curve at fixed W never decreases as rows grow, and the
    pooled fallback obeys the same clamp.  This is what licenses
    handing an arbitrary field-recorded trace to ``choose_dispatch``:
    a bad fit can bias the batch/bucket crossover, never invert the
    within-width ordering the static rule guarantees."""
    model = fit_cost_model(records)
    widths = sorted({int(r["width"]) for r in records}) + [256]  # pooled
    rows = [1, 2, 8, 64, 512, 4096, 100_000]
    for w in widths:
        ts = [model.predict(w, b) for b in rows]
        assert all(t is not None and t >= 0 for t in ts), w
        assert all(t1 - t0 >= -1e-6 for t0, t1 in zip(ts, ts[1:])), (w, ts)


@given(traces(), st.integers(1, 4096), st.sampled_from([2, 8, 32, 128]),
       st.integers(1, 10**6))
@settings(max_examples=60, deadline=None)
def test_any_fitted_model_resolves_to_a_legal_mode(records, b, w, slots):
    """choose_dispatch under any fitted model returns one of the two
    executable paths — and the empty model returns the static pick."""
    model = fit_cost_model(records)
    launches = ((2, 17), (w, 5))
    got = choose_dispatch("auto", b, w, slots, cost_model=model,
                          bucket_launches=launches)
    assert got in ("batch", "bucket")
    static = choose_dispatch("auto", b, w, slots)
    assert choose_dispatch("auto", b, w, slots, cost_model=CostModel(),
                           bucket_launches=launches) == static
