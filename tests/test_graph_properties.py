"""Property tests on the DataGraph container and MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep; skip, don't error
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.slow      # hypothesis sweeps: own CI job

from repro.core.graph import (DataGraph, _build_ell_loop, bipartite_edges,
                              grid_edges_3d)
from conftest import random_graph


@st.composite
def graphs(draw):
    nv = draw(st.integers(2, 30))
    ne = draw(st.integers(1, 60))
    seed = draw(st.integers(0, 2**16))
    return nv, random_graph(nv, ne, seed)


@given(graphs())
@settings(max_examples=40, deadline=None)
def test_ell_structure_roundtrip(g):
    """Every edge appears exactly twice (once per endpoint), is_src marks
    exactly one side, padded slots are masked."""
    nv, edges = g
    if len(edges) == 0:
        return
    dg = DataGraph.from_edges(nv, edges,
                              {"x": np.zeros(nv, np.float32)},
                              {"w": np.arange(len(edges), dtype=np.float32)})
    padded = dg.to_padded()       # flat view of the sliced-ELL buckets
    nbrs = np.asarray(padded.nbrs)
    mask = np.asarray(padded.nbr_mask)
    eids = np.asarray(padded.edge_ids)
    issrc = np.asarray(padded.is_src)
    seen = {}
    for v in range(nv):
        for j in range(dg.max_deg):
            if not mask[v, j]:
                assert eids[v, j] == dg.n_edges   # pad edge row
                continue
            e = eids[v, j]
            seen.setdefault(int(e), []).append((v, bool(issrc[v, j])))
    assert len(seen) == len(edges)
    for e, ends in seen.items():
        assert len(ends) == 2
        verts = {v for v, _ in ends}
        assert verts == {int(edges[e][0]), int(edges[e][1])}
        srcs = [s for _, s in ends]
        assert sorted(srcs) == [False, True]   # exactly one src side
    # degrees consistent with mask
    np.testing.assert_array_equal(np.asarray(dg.degree), mask.sum(1))


@given(graphs())
@settings(max_examples=40, deadline=None)
def test_sliced_ell_roundtrip_property(g):
    """Property form of the storage refactor's contract: the bucketed
    layout's ``to_padded()`` equals the original loop builder's padded
    ELL output on arbitrary random graphs."""
    nv, edges = g
    if len(edges) == 0:
        return
    dg = DataGraph.from_edges(nv, edges, {"x": np.zeros(nv, np.float32)})
    want = _build_ell_loop(nv, edges, dg.max_deg)
    for a, b in zip(dg.to_padded(), want):
        np.testing.assert_array_equal(np.asarray(a), b)
    # buckets tile the vertex set exactly once
    perm = np.asarray(dg.ell.perm)
    assert sorted(perm[perm < nv].tolist()) == list(range(nv))


@pytest.mark.split
@given(graphs(), st.sampled_from([2, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_split_unsplit_roundtrip_property(g, w_cap):
    """Hub splitting is storage-only (DESIGN.md §10): for arbitrary
    random graphs and caps, the split layout's ``to_padded()`` is
    bit-identical to the unsplit layout's, and summing each owner's
    virtual-row slot aggregates reproduces the per-row aggregate
    bit-identically (same adds, same order)."""
    nv, edges = g
    if len(edges) == 0:
        return
    vd = {"x": np.zeros(nv, np.float32)}
    g0 = DataGraph.from_edges(nv, edges, vd, edge_locality=False)
    gs = DataGraph.from_edges(nv, edges, vd, w_cap=w_cap,
                              edge_locality=False)
    for a, b in zip(gs.to_padded(), g0.to_padded()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if not gs.ell.is_split:
        return                      # max_deg <= cap: stored unsplit
    # per-row aggregate parity: sum of x[nbr]*w over each vrow's slots,
    # combined per owner, equals the unsplit per-row reduction exactly
    from repro.kernels.ell_spmv import segment_combine
    rng = np.random.default_rng(nv * 1000 + len(edges))
    # small-integer features: every partial and total sum is exactly
    # representable in float32, so reassociating chunk partials is
    # bitwise-exact, not merely allclose
    x = jnp.asarray(rng.integers(-8, 8, size=(nv + 1, 1)), jnp.float32)
    ell = gs.ell
    parts = []
    for b in range(ell.n_buckets):
        nb = jnp.minimum(ell.nbrs[b], nv)
        wts = jnp.where(ell.nbr_mask[b], 1.0, 0.0)
        parts.append((x[nb][..., 0] * wts).sum(axis=1))
    y_pos = jnp.concatenate(parts)                 # bucketed row order
    y_vrow = y_pos[jnp.asarray(ell.inv_perm)]      # virtual-row order
    y_own = segment_combine(y_vrow, ell.owner_of_vrow, nv)
    p0 = g0.to_padded()
    w0 = jnp.where(p0.nbr_mask, 1.0, 0.0)
    y0 = (x[jnp.minimum(p0.nbrs, nv)][..., 0] * w0).sum(axis=1)
    np.testing.assert_array_equal(np.asarray(y_own), np.asarray(y0))


def test_bipartite_and_grid_helpers():
    nv, edges = bipartite_edges(3, 4, np.asarray([[0, 0], [2, 3]]))
    assert nv == 7
    assert edges.tolist() == [[0, 3], [2, 6]]
    nv, edges = grid_edges_3d(2, 2, 2)
    assert nv == 8
    assert len(edges) == 12   # 3 * 2^2 faces


@given(st.integers(1, 4), st.integers(2, 32), st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_moe_dispatch_conservation(b, s, seed):
    """MoE with capacity >= k*s/e never drops and is a convex combination:
    output for a token equals sum_k gate_k * expert_k(x) exactly for
    identity-ish experts."""
    import dataclasses
    from repro import configs
    from repro.models import moe
    cfg = configs.get("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(
            cfg.moe.n_experts)))  # cap == s: nothing can drop
    p = moe.init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    y, aux = moe.apply(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # oracle: dense computation over all experts
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, cfg.moe.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    act = jax.nn.silu
    def expert(e, t):
        h = act(t @ p["w_gate"][e]) * (t @ p["w_up"][e])
        return h @ p["w_down"][e]
    want = jnp.zeros_like(y)
    for bi in range(b):
        for si in range(s):
            acc = jnp.zeros((cfg.d_model,), y.dtype)
            for kk in range(cfg.moe.top_k):
                e = int(eidx[bi, si, kk])
                acc = acc + gate[bi, si, kk] * expert(e, x[bi, si])
            want = want.at[bi, si].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_vocab_padding_masks_logits():
    import dataclasses
    from repro import configs
    from repro.models import model as M
    cfg = dataclasses.replace(configs.get("seamless-m4t-medium").reduced(),
                              vocab=300)   # 300 -> padded to 512
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    assert params["embed"].shape[0] == 512
    batch = {
        "frames": jnp.zeros((1, 8, cfg.d_model), jnp.float32),
        "tokens": jnp.zeros((1, 8), jnp.int32),
    }
    logits = M.prefill(params, cfg, batch)
    assert float(logits[:, 300:].max()) <= -1e8   # padded ids masked


@given(seed=st.sampled_from([0, 1, 2, 3, 4]), k=st.sampled_from([2, 5, 8]))
@settings(max_examples=8, deadline=None)
def test_dispatcher_choice_never_changes_results(seed, k):
    """Property (DESIGN.md §8): the dispatch mode is a pure performance
    knob — for any graph and window size, batch- and bucket-shaped
    execution of the priority engine are bit-identical, task set and
    priorities included."""
    from repro.apps import pagerank
    from repro.core import PriorityEngine
    from repro.core.graph import zipf_edges
    edges = zipf_edges(40, alpha=2.0, max_deg=16, seed=seed)
    g = pagerank.make_graph(edges, 40)
    upd = pagerank.make_update(1e-5)
    runs = [PriorityEngine(g, upd, k_select=k, dispatch=d,
                           max_supersteps=3000).run(num_supersteps=6)
            for d in ("batch", "bucket")]
    assert np.array_equal(np.asarray(runs[0].vertex_data["rank"]),
                          np.asarray(runs[1].vertex_data["rank"]))
    assert np.array_equal(np.asarray(runs[0].active),
                          np.asarray(runs[1].active))
    assert np.array_equal(np.asarray(runs[0].priority),
                          np.asarray(runs[1].priority))
